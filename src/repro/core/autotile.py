"""TPU-native realization of the paper's cache-conscious decomposition:
the run-time decomposer chooses Pallas block shapes (the partitions), the
grid (the task vector) and the traversal order (the schedule).

Mapping (DESIGN.md §2):

  TCL                -> usable VMEM budget of the target chip
  phi_c line padding -> (sublane x lane) register-tile padding + x2 double
                        buffering (Pallas pipelines HBM->VMEM block copies)
  np binary search   -> identical search (Algorithm 1 + §2.1.1), with
                        phi_tpu as the footprint estimator
  CC / SRRC          -> grid traversal order: output-stationary row-major
                        (CC) vs. serpentine operand-reuse order (SRRC)

The *horizontal* (cache-neglectful) baseline of the paper corresponds to not
tiling at all -- leaving placement to XLA's default lowering. Benchmarks and
the perf log compare the two, mirroring the paper's §4 study.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core.decompose import (
    NoValidDecomposition,
    find_optimal_np,
    make_phi_tpu,
)
from repro.core.distribution import RowBlockDistribution, matmul_domain
from repro.hw.tpu import TPUSpec, chip_spec


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _round_down(x: int, mult: int) -> int:
    return max(mult, (x // mult) * mult)


def _align_block(size: int, dim: int, mult: int) -> int:
    """Align a proposed block extent to a hardware multiple, clamped to the
    (padded) problem dimension."""
    if dim <= mult:
        return _round_up(dim, 8)  # tiny dim: pad to sublane granule only
    return min(_round_up(size, mult), _round_up(dim, mult))


# ---------------------------------------------------------------------------
# Matmul tile planning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MatmulTilePlan:
    """Blocked C[m,n] = A[m,k] @ B[k,n] plan for a Pallas kernel."""

    m: int
    k: int
    n: int
    bm: int
    bk: int
    bn: int
    order: str                  # "cc" | "srrc"
    np: int                     # the paper-search partition count that seeded it
    est_vmem_bytes: int
    strategy: str               # "cache_conscious" | "horizontal"
    source: str = "analytic"    # "analytic" | "tuned" (measured sweep winner)

    @property
    def grid(self) -> Tuple[int, int, int]:
        # (i over M, j over N, kk over K); kk innermost = output-stationary.
        return (
            math.ceil(self.m / self.bm),
            math.ceil(self.n / self.bn),
            math.ceil(self.k / self.bk),
        )

    @property
    def n_tasks(self) -> int:
        gi, gj, gk = self.grid
        return gi * gj * gk


def _matmul_vmem_bytes(bm: int, bk: int, bn: int, dtype_bytes: int) -> int:
    """Working set of one grid step: double-buffered A and B blocks, an f32
    accumulator (output-stationary), and the output block."""
    a = bm * bk * dtype_bytes * 2
    b = bk * bn * dtype_bytes * 2
    acc = bm * bn * 4
    out = bm * bn * dtype_bytes * 2
    return a + b + acc + out


def plan_matmul(
    m: int,
    k: int,
    n: int,
    dtype_bytes: int = 2,
    spec: Optional[TPUSpec] = None,
    order: str = "cc",
    n_workers: int = 1,
    vmem_fraction: float = 1.0,
) -> MatmulTilePlan:
    """Cache-conscious matmul tile plan via the paper's binary search.

    A thin wrapper over the hierarchical planner (``repro.plan``): runs a
    single-chip ``plan_run`` on this chip's HBM -> VMEM -> VREG hierarchy
    and returns the VMEM sub-plan's tile plan.  The search itself
    (``_search_matmul_tiles``) is what the planner executes at every VMEM
    level, so a standalone ``plan_matmul`` and the leaf of a full mesh-wide
    plan agree by construction:

    1. Run §2.1.1's search on the Fig. 3 composite domain (A, B, C square
       block grids) against the chip's usable VMEM with ``phi_tpu``.
    2. Convert np -> raw block extents and align them to MXU/lane multiples
       (the phi_c "cache line adjustment", TPU-style).
    3. Shrink-to-fit if alignment pushed the working set over budget.
    """
    from repro.core.plan import PlanPolicy, Workload, plan_run

    spec = spec or chip_spec()
    hp = plan_run(
        spec.hierarchy(),
        Workload(matmul=(m, k, n), dtype_bytes=dtype_bytes),
        PlanPolicy(order=order, n_workers=n_workers,
                   vmem_fraction=vmem_fraction, spec=spec),
    )
    return hp.tile_plan()


def _search_matmul_tiles(
    m: int,
    k: int,
    n: int,
    dtype_bytes: int,
    spec: TPUSpec,
    order: str,
    n_workers: int,
    budget: int,
) -> MatmulTilePlan:
    """The §2.1.1 search + TPU alignment against an explicit VMEM budget
    (the planner supplies the budget from the hierarchy's VMEM level)."""
    sub = spec.sublane(dtype_bytes)
    phi = make_phi_tpu(sublane=sub, lane=spec.lane, buffering=2)

    domain = matmul_domain(m, n, k, element_size=dtype_bytes)
    try:
        np_ = find_optimal_np(budget, spec.lane, domain, n_workers, phi)
    except NoValidDecomposition:
        # Degenerate problems (a dim smaller than one register tile): a
        # single minimal block is the only choice.
        np_ = max(1, n_workers)

    side = max(1, round(math.isqrt(np_)))
    bm = _align_block(math.ceil(m / side), m, spec.mxu)
    bk = _align_block(math.ceil(k / side), k, spec.mxu)
    bn = _align_block(math.ceil(n / side), n, spec.mxu)

    # Shrink-to-fit after alignment (halve the largest extent first; never
    # drop below one MXU tile / sublane granule).
    def floor_unit(dim: int) -> int:
        return spec.mxu if dim > spec.mxu else 8

    while _matmul_vmem_bytes(bm, bk, bn, dtype_bytes) > budget:
        candidates = [(bm, "m"), (bk, "k"), (bn, "n")]
        size, which = max(candidates)
        unit = floor_unit({"m": m, "k": k, "n": n}[which])
        if size <= unit:
            break  # cannot shrink further; kernel wrapper will fall back
        if which == "m":
            bm = _round_down(size // 2, unit)
        elif which == "k":
            bk = _round_down(size // 2, unit)
        else:
            bn = _round_down(size // 2, unit)

    return MatmulTilePlan(
        m=m, k=k, n=n, bm=bm, bk=bk, bn=bn,
        order=order, np=np_,
        est_vmem_bytes=_matmul_vmem_bytes(bm, bk, bn, dtype_bytes),
        strategy="cache_conscious",
    )


def apply_tuned_matmul(
    tile: MatmulTilePlan,
    dtype_bytes: int,
    spec: TPUSpec,
    budget: int,
) -> Tuple[MatmulTilePlan, Optional[dict]]:
    """Replace an analytic tile plan's block extents with a matching sweep
    winner from ``experiments/tuning.json`` (precedence analytic < tuned).

    The tuned extents re-pass the exact invariants the analytic search
    guarantees -- 8-alignment, clamped to the padded problem dims, the
    ``_matmul_vmem_bytes`` working set within ``budget`` -- so a stale or
    foreign entry can never produce a plan the analytic path could not.
    Returns ``(plan, tuning_detail)`` where the detail carries the measured
    provenance (or None when the analytic choice stands).
    """
    from repro.tune.cache import bucket_matmul, lookup_tuned

    entry = lookup_tuned("matmul_cc", spec.name,
                         bucket_matmul(tile.m, tile.k, tile.n, dtype_bytes))
    if entry is None:
        return tile, None
    block = entry.get("block", {})
    ext = [block.get(x) for x in ("bm", "bk", "bn")]
    if not all(isinstance(v, int) and v >= 8 and v % 8 == 0 for v in ext):
        return tile, None

    def cap(v: int, dim: int) -> int:
        unit = spec.mxu if dim > spec.mxu else 8
        return min(v, _round_up(dim, unit))

    bm = cap(ext[0], tile.m)
    bk = cap(ext[1], tile.k)
    bn = cap(ext[2], tile.n)
    est = _matmul_vmem_bytes(bm, bk, bn, dtype_bytes)
    if est > budget:
        return tile, None
    tuned = dataclasses.replace(tile, bm=bm, bk=bk, bn=bn,
                                est_vmem_bytes=est, source="tuned")
    detail = {
        "speedup": entry.get("speedup", 1.0),
        "median_us": entry.get("median_us", 0.0),
        "analytic_us": entry.get("analytic_us", 0.0),
        "analytic_block": entry.get("analytic_block", {}),
        "fingerprint": entry.get("fingerprint", ""),
    }
    return tuned, detail


def plan_matmul_cached(
    m: int,
    k: int,
    n: int,
    dtype_bytes: int = 2,
    order: str = "cc",
    n_workers: int = 1,
    vmem_fraction: float = 1.0,
) -> MatmulTilePlan:
    """Memoized plan for callers that re-plan the same block shape on every
    trace.  Delegates to the hierarchical planner's single memoizer
    (``repro.plan.leaf_matmul_plan``) so there is exactly one plan cache."""
    from repro.core.plan import leaf_matmul_plan

    return leaf_matmul_plan(m, k, n, dtype_bytes=dtype_bytes, order=order,
                            n_workers=n_workers, vmem_fraction=vmem_fraction)


def plan_matmul_horizontal(
    m: int, k: int, n: int, dtype_bytes: int = 2, n_workers: int = 1,
    spec: Optional[TPUSpec] = None,
) -> MatmulTilePlan:
    """The paper's horizontal baseline: one row-slab partition per worker,
    no cache sizing. (Used by benchmarks; on TPU this is equivalent to XLA's
    default un-tiled lowering and typically exceeds VMEM.)"""
    spec = spec or chip_spec()
    bm = math.ceil(m / max(1, n_workers))
    return MatmulTilePlan(
        m=m, k=k, n=n, bm=bm, bk=k, bn=n,
        order="cc", np=max(1, n_workers),
        est_vmem_bytes=_matmul_vmem_bytes(bm, k, n, dtype_bytes),
        strategy="horizontal",
    )


# ---------------------------------------------------------------------------
# Attention tile planning (flash-style streaming over the KV sequence)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionTilePlan:
    q_len: int
    kv_len: int
    head_dim: int
    block_q: int
    block_kv: int
    np: int
    est_vmem_bytes: int
    source: str = "analytic"    # "analytic" | "tuned", "+clamped" suffix when
                                # the kernel shrank a block to the sequence

    @property
    def grid(self) -> Tuple[int, int]:
        return (
            math.ceil(self.q_len / self.block_q),
            math.ceil(self.kv_len / self.block_kv),
        )


def _attn_vmem_bytes(bq: int, bkv: int, d: int, dtype_bytes: int) -> int:
    q = bq * d * dtype_bytes * 2
    kv = 2 * bkv * d * dtype_bytes * 2          # K and V, double-buffered
    scores = bq * bkv * 4                        # f32 logits block
    acc = bq * d * 4 + 2 * bq * 4                # f32 out acc + m/l stats
    out = bq * d * dtype_bytes * 2
    return q + kv + scores + acc + out


def plan_attention(
    q_len: int,
    kv_len: int,
    head_dim: int,
    dtype_bytes: int = 2,
    spec: Optional[TPUSpec] = None,
    vmem_fraction: float = 1.0,
    use_tuned: bool = True,
) -> AttentionTilePlan:
    """Decompose the KV sequence so one (K, V) partition plus the Q-side
    working set fits VMEM -- the paper's decomposition with the KV stream as
    the domain. block_q is then grown to the largest aligned extent that
    keeps the step within budget (more MXU work per loaded KV block).

    With ``use_tuned`` (the default) a matching measured winner from
    ``experiments/tuning.json`` overrides the analytic blocks -- precedence
    analytic < tuned -- after re-passing this function's own VMEM filter;
    any miss or invalid entry leaves the analytic choice standing.
    """
    spec = spec or chip_spec()
    budget = int(spec.usable_vmem * vmem_fraction)
    sub = spec.sublane(dtype_bytes)
    phi = make_phi_tpu(sublane=sub, lane=spec.lane, buffering=2)

    # Stage 1 (paper search): partition K and V (kv_len x d row blocks).
    kv_domain = [
        RowBlockDistribution(kv_len, head_dim, dtype_bytes),  # K
        RowBlockDistribution(kv_len, head_dim, dtype_bytes),  # V
    ]
    # Reserve half the budget for the Q-side working set.
    try:
        np_ = find_optimal_np(budget // 2, spec.lane, kv_domain, 1, phi)
    except NoValidDecomposition:
        np_ = 1
    block_kv = _align_block(math.ceil(kv_len / np_), kv_len, spec.lane)
    block_kv = min(block_kv, _round_up(kv_len, sub))

    # Stage 2: largest aligned block_q that fits.
    bq = _round_up(min(q_len, 2048), sub)
    while bq > sub and _attn_vmem_bytes(bq, block_kv, head_dim, dtype_bytes) > budget:
        bq = _round_down(bq // 2, sub)
    while _attn_vmem_bytes(bq, block_kv, head_dim, dtype_bytes) > budget and block_kv > spec.lane:
        block_kv = _round_down(block_kv // 2, spec.lane)

    plan = AttentionTilePlan(
        q_len=q_len, kv_len=kv_len, head_dim=head_dim,
        block_q=min(bq, _round_up(q_len, sub)), block_kv=block_kv, np=np_,
        est_vmem_bytes=_attn_vmem_bytes(bq, block_kv, head_dim, dtype_bytes),
    )
    if use_tuned:
        plan = _apply_tuned_attention(plan, dtype_bytes, spec, budget)
    return plan


def _apply_tuned_attention(plan: AttentionTilePlan, dtype_bytes: int,
                           spec: TPUSpec, budget: int) -> AttentionTilePlan:
    """Replace the analytic blocks with a matching sweep winner, keeping the
    invariants the analytic path guarantees (sublane alignment, clamp to the
    padded sequence, VMEM fit)."""
    from repro.tune.cache import bucket_attention, lookup_tuned

    entry = lookup_tuned(
        "flash_attention", spec.name,
        bucket_attention(plan.q_len, plan.kv_len, plan.head_dim,
                         dtype_bytes))
    if entry is None:
        return plan
    block = entry.get("block", {})
    bq_t, bkv_t = block.get("block_q"), block.get("block_kv")
    if not (isinstance(bq_t, int) and isinstance(bkv_t, int)
            and bq_t >= 8 and bkv_t >= 8 and bq_t % 8 == 0
            and bkv_t % 8 == 0):
        return plan
    sub = spec.sublane(dtype_bytes)
    bq_t = min(bq_t, _round_up(plan.q_len, sub))
    bkv_t = min(bkv_t, _round_up(plan.kv_len, sub))
    est = _attn_vmem_bytes(bq_t, bkv_t, plan.head_dim, dtype_bytes)
    if est > budget:
        return plan
    return dataclasses.replace(plan, block_q=bq_t, block_kv=bkv_t,
                               est_vmem_bytes=est, source="tuned")


def clamp_attention_plan(plan: AttentionTilePlan, q_len: int,
                         kv_len: int,
                         dtype_bytes: int = 2) -> AttentionTilePlan:
    """The effective plan ``flash_attention`` runs: blocks shrunk to the
    actual sequence (the kernel's ``max(8, min(block, seq))`` clamp).  When
    the clamp changes the choice the returned plan records it -- ``source``
    gains a ``+clamped`` suffix -- so sweeps and logs measure the block
    actually executed, never the diverged paper choice."""
    bq = max(8, min(plan.block_q, q_len))
    bkv = max(8, min(plan.block_kv, kv_len))
    if (bq, bkv) == (plan.block_q, plan.block_kv):
        return plan
    return dataclasses.replace(
        plan, block_q=bq, block_kv=bkv,
        est_vmem_bytes=_attn_vmem_bytes(bq, bkv, plan.head_dim, dtype_bytes),
        source=plan.source + "+clamped")
