"""Cache-Conscious Run-time Decomposition (Paulino & Delgado, 2015) -- core.

The paper's contribution as a composable runtime module:

  * ``hierarchy``    -- platform-independent memory-hierarchy model (§3.1)
  * ``distribution`` -- the Distribution<T> interface (Table 1)
  * ``decompose``    -- Algorithm 1 + binary search for np + phi functions (§2.1)
  * ``schedule``     -- CC / SRRC task clustering (§2.2), LLSC affinity (§2.3)
  * ``engine``       -- synchronization-free execution engine (§2.4)
  * ``autotile``     -- the TPU-native realization: decomposer -> Pallas tile
                        plans (DESIGN.md §2)
"""

from repro.core.decompose import (
    Decomposer,
    DecompositionPlan,
    NoValidDecomposition,
    find_optimal_np,
    make_phi_tpu,
    phi_conservative,
    phi_simple,
    validate_np,
)
from repro.core.distribution import (
    Array1DDistribution,
    Array2DBlockDistribution,
    CompositeDomain,
    Distribution,
    RowBlockDistribution,
    StencilDistribution,
    matmul_domain,
    matmul_task_grid,
)
from repro.core.engine import Engine, RunResult, StageTimes
from repro.core.hierarchy import (
    MemoryLevel,
    paper_system_a,
    paper_system_i,
    read_linux_hierarchy,
    tpu_hierarchy,
)
from repro.core.schedule import (
    cc_range,
    cc_schedule,
    cc_worker_tasks,
    grid_order,
    lowest_level_shared_cache_groups,
    ring_stream_order,
    srrc_cluster_size,
    srrc_schedule,
    srrc_worker_tasks,
)

__all__ = [k for k in dir() if not k.startswith("_")]
