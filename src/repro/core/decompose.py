"""Cache-conscious domain decomposition (paper §2.1).

Implements Algorithm 1 (``validate_np``), the binary search for the optimal
number of partitions (§2.1.1), and the phi footprint estimators (§2.1.2):

  * ``phi_simple``       -- raw partition bytes (paper phi_s)
  * ``phi_conservative`` -- cache-line-aware estimate (paper phi_c)
  * ``phi_tpu``          -- TPU-native variant: pads block dims to the
                            (sublane x lane) register tile and accounts for
                            Pallas double buffering (DESIGN.md §2)
  * ``phi_mesh``         -- mesh-level variant: per-chip shard bytes padded
                            to the sharding granule, HBM as the TCL
                            (DESIGN.md §2, used by ``repro.dist.sharding``)

Paper-exact behaviour is covered by tests reproducing the §2.1.2 worked
example (np=256, 1024x1024 int32 matmul, 64 KiB TCL -> phi_s = 49152 valid,
phi_c = 98304 invalid) and the §4.4.4 breakdown (N=2000, TCL=128 KiB,
8 workers -> np=400, 8000 tasks).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.core.distribution import CompositeDomain, Distribution
from repro.core.hierarchy import MemoryLevel

PhiFn = Callable[[int, Distribution, int], float]


# ---------------------------------------------------------------------------
# phi functions (§2.1.2)
# ---------------------------------------------------------------------------

def phi_simple(cache_line_size: int, dist: Distribution, np_: int) -> float:
    """phi_s: elementSize x floor(avgPartitionSize + 0.5) bytes."""
    del cache_line_size
    return dist.get_element_size() * math.floor(
        dist.get_average_partition_size(np_) + 0.5
    )


def phi_conservative(cache_line_size: int, dist: Distribution, np_: int) -> float:
    """phi_c: adjusts the first dimension to cache-line boundaries and adds
    one extra line per row for misalignment.

    We implement the §2.1.2 formula exactly as used in the paper's own worked
    example (first-dimension size expressed in *elements*):

      phi_c = lineSize * (avgPartSize*elemSize / avgFirstDim)
                       * (ceil(avgFirstDim / lineSize) + 1)

    Note: Table 2 restates the formula with F in bytes, which contradicts the
    worked example (98304 bytes for the 1024^2/np=256 case). The worked
    example is authoritative for reproduction; we follow it.
    """
    first_dim = dist.get_average_first_dim_size(np_)
    if first_dim <= 0:
        return phi_simple(cache_line_size, dist, np_)
    part_bytes = dist.get_average_partition_size(np_) * dist.get_element_size()
    rows_bytes = part_bytes / first_dim  # bytes "per unit of first dim"
    lines_per_row = math.ceil(first_dim / cache_line_size) + 1
    return cache_line_size * rows_bytes * lines_per_row


def make_phi_tpu(
    sublane: int = 8,
    lane: int = 128,
    buffering: int = 2,
) -> PhiFn:
    """TPU-native footprint estimator (DESIGN.md §2).

    The VMEM-residency granule is the (sublane, lane) register tile; a block
    whose trailing dim is not a multiple of ``lane`` (or whose leading dim is
    not a multiple of ``sublane``) is padded up by Mosaic. Pallas's software
    pipeline keeps ``buffering`` copies of every streamed block resident
    (double buffering by default), playing the role of phi_c's "extra cache
    line for misalignment" -- a deterministic, structural overhead rather
    than a probabilistic one.
    """

    def phi_tpu(cache_line_size: int, dist: Distribution, np_: int) -> float:
        del cache_line_size
        first = max(1.0, dist.get_average_first_dim_size(np_))
        part = dist.get_average_partition_size(np_)
        other = part / first  # product of leading dims
        padded_first = math.ceil(first / lane) * lane
        padded_other = math.ceil(other / sublane) * sublane
        return buffering * padded_first * padded_other * dist.get_element_size()

    return phi_tpu


def make_phi_mesh(granule_bytes: Optional[int] = None,
                  overhead: float = 1.0) -> PhiFn:
    """Mesh-level footprint estimator (DESIGN.md §2).

    At the outermost level the "partition" is one chip's shard of a logical
    tensor and the TCL is the chip's HBM. The cache-line analogue is the
    sharding granule (one (sublane x lane) register tile per shard boundary
    -- XLA pads uneven shards up to it), so the per-chip shard is rounded up
    to ``granule_bytes`` (defaulting to the hierarchy's cache-line field).
    ``overhead`` scales the estimate for transient copies the runtime keeps
    alive alongside the resident shard (gradient buckets, all-gather
    destinations) -- the structural analogue of phi_c's extra line.
    """

    def phi_mesh(cache_line_size: int, dist: Distribution, np_: int) -> float:
        g = max(1, granule_bytes or cache_line_size or 1)
        shard = dist.get_element_size() * dist.get_average_partition_size(np_)
        return overhead * math.ceil(shard / g) * g

    return phi_mesh


#: Default mesh-level phi: granule from the hierarchy, no overhead factor.
phi_mesh = make_phi_mesh()


# ---------------------------------------------------------------------------
# Algorithm 1: validate a candidate np
# ---------------------------------------------------------------------------

def validate_np(
    tcl_per_core: int,
    cache_line_size: int,
    dists: Sequence[Distribution],
    np_: int,
    phi: PhiFn = phi_simple,
) -> int:
    """Paper Algorithm 1. Returns 1 (valid), 0 (try larger), -1 (hopeless)."""
    total_partition_size = 0.0
    for dist in dists:
        status = dist.validate(np_)
        if status <= 0:
            return status
        total_partition_size += phi(cache_line_size, dist, np_)
    return 1 if total_partition_size <= tcl_per_core else 0


# ---------------------------------------------------------------------------
# Binary search for the optimal np (§2.1.1)
# ---------------------------------------------------------------------------

class NoValidDecomposition(Exception):
    pass


def _next_structurally_valid(
    dists: Sequence[Distribution], np_: int, limit: int
) -> Optional[int]:
    """Smallest np' >= np_ whose *structural* validation is not 0 for every
    distribution. Returns None if a -1 is hit or the limit is passed.
    (Handles non-monotone structural constraints such as perfect squares.)"""
    cand = np_
    while cand <= limit:
        worst = 1
        for d in dists:
            s = d.validate(cand)
            if s < 0:
                return None
            worst = min(worst, s)
        if worst > 0:
            return cand
        cand += 1
    return None


def find_optimal_np(
    tcl_per_core: int,
    cache_line_size: int,
    domain: Sequence[Distribution] | CompositeDomain,
    n_workers: int,
    phi: PhiFn = phi_simple,
    max_np: int = 1 << 30,
) -> int:
    """Binary search of §2.1.1: start at ``n_workers`` and double until a
    valid solution appears (or all larger values are invalid), then narrow to
    the *smallest* valid np. Smallest np <=> largest per-partition size that
    still fits the TCL, which the paper shows is optimal for the given
    parameters. ``n_workers`` lower-bounds np so every worker gets work.
    """
    dists = list(domain)
    np_ = max(1, n_workers)

    # Phase 1: exponential growth, clamped so max_np itself is probed even
    # when it is not on the n_workers * 2^k sequence (a 6-chip data axis
    # must try np=6, not stop after 4).
    hi: Optional[int] = None
    cand = np_
    while cand <= max_np:
        status = validate_np(tcl_per_core, cache_line_size, dists, cand, phi)
        if status < 0:
            raise NoValidDecomposition(
                f"no decomposition with np >= {np_} fits TCL={tcl_per_core}"
            )
        if status == 1:
            hi = cand
            break
        if cand == max_np:
            break
        cand = min(cand * 2, max_np)
    if hi is None:
        raise NoValidDecomposition(
            f"no valid np found in [{np_}, {max_np}] for TCL={tcl_per_core}"
        )

    # Phase 2: narrow to the smallest valid np in [n_workers, hi].
    #
    # The doubling phase only probes n_workers * 2^k, so the smallest valid
    # np may lie anywhere below hi (e.g. the paper's §4.4.4 case: workers=8,
    # doubling reaches hi=1024 but the optimum is np=400). Structural
    # validity (perfect squares, ...) is not monotone, but the *fit*
    # constraint is monotone over structurally-valid values (larger np =>
    # smaller average partitions), so we binary-search the predicate
    # P(x) := fits(first structurally-valid candidate >= x), with candidates
    # above ``hi`` treated as fitting (hi itself fits).
    best = hi
    lo_s, hi_s = max(1, n_workers), hi
    while lo_s < hi_s:
        mid = (lo_s + hi_s) // 2
        probe = _next_structurally_valid(dists, mid, hi)
        if probe is None or probe >= hi:
            ok, cand = True, hi
        else:
            ok = validate_np(tcl_per_core, cache_line_size, dists, probe, phi) == 1
            cand = probe
        if ok:
            best = min(best, cand)
            hi_s = mid
        else:
            lo_s = probe + 1
    return best


# ---------------------------------------------------------------------------
# High-level decomposer
# ---------------------------------------------------------------------------

@dataclass
class DecompositionPlan:
    """Result of the cache-conscious decomposition of one composite domain."""

    np: int                       # partitions per sub-domain
    tcl_bytes: int                # TCL_PER_CORE used
    cache_line_size: int
    partition_bytes: float        # estimated footprint of one composite partition
    regions: List[List[tuple]]    # per sub-domain: list of index regions
    strategy: str = "cache_conscious"

    @property
    def n_partitions(self) -> int:
        return self.np


class Decomposer:
    """Run-time cache-conscious decomposer (the paper's core contribution).

    Given a memory hierarchy and a TCL selector, decomposes composite domains
    so each composite partition fits the TCL per core. ``strategy`` may be
    ``"cache_conscious"`` (the paper's proposal) or ``"horizontal"`` (the
    classical baseline: np == nWorkers, cache-neglectful), enabling the
    comparative study of §4 from a single code path.
    """

    def __init__(
        self,
        hierarchy: MemoryLevel,
        tcl: str | int = "L1",
        phi: PhiFn = phi_simple,
        strategy: str = "cache_conscious",
    ) -> None:
        self.hierarchy = hierarchy
        self.phi = phi
        self.strategy = strategy
        if isinstance(tcl, int):
            self._tcl_name = None
            self.tcl_bytes = tcl
            self.cache_line = 64
            for lvl in hierarchy.cache_levels():
                self.cache_line = lvl.cache_line_size or 64
                break
        else:
            lvl = hierarchy.find(tcl)
            if lvl is None:
                raise KeyError(f"no level named {tcl!r} in hierarchy")
            self._tcl_name = tcl
            self.tcl_bytes = lvl.per_core_size()
            self.cache_line = lvl.cache_line_size or 64

    def decompose(
        self, domain: Sequence[Distribution] | CompositeDomain, n_workers: int
    ) -> DecompositionPlan:
        """Decompose one composite domain against this decomposer's TCL.

        A thin wrapper over the hierarchical planner (``repro.plan``): runs
        ``plan_run`` with the search restricted to the TCL level (an
        explicit byte budget gets a synthetic single-level hierarchy) and
        reads ``np`` off that level's sub-plan -- the same Algorithm-1 /
        §2.1.1 search the planner executes at every host-cache level.
        """
        from repro.core.plan import PlanPolicy, Workload, plan_run

        dists = list(domain)
        if self._tcl_name is not None:
            hierarchy, tcl_name = self.hierarchy, self._tcl_name
        else:
            hierarchy = MemoryLevel(
                size=self.tcl_bytes, siblings=[[0]],
                cache_line_size=self.cache_line, child=None, name="TCL")
            tcl_name = "TCL"
        hp = plan_run(
            hierarchy,
            Workload(domain=tuple(dists)),
            PlanPolicy(strategy=self.strategy, n_workers=n_workers,
                       cache_phi=self.phi, tcl=tcl_name),
        )
        sub = hp.level(tcl_name)
        np_ = sub.np
        return DecompositionPlan(
            np=np_,
            tcl_bytes=self.tcl_bytes,
            cache_line_size=self.cache_line,
            partition_bytes=sub.partition_bytes,
            regions=[d.partition(np_) for d in dists],
            strategy=self.strategy,
        )
