"""Task scheduling for cache-consciously decomposed computations (paper §2.2).

Two static clustering strategies are provided:

  * **Contiguous Clustering (CC)** -- worker ``i`` of ``n`` receives the
    contiguous task range ``[i*m/n, (i+1)*m/n)``; when ``m`` is not a multiple
    of ``n`` the first ``r = m mod n`` workers receive one extra task
    (paper §2.2.1, Fig. 4).

  * **Sibling Round-Robin Clustering (SRRC)** -- task clusters sized by the
    LLC/TCL ratio are dealt round-robin to *groups of workers sharing an LLC*;
    within a cluster, tasks are dealt round-robin to the group's workers;
    remainder clusters plus the trailing tasks that could not form a cluster
    are merged into a special *CC cluster* scheduled with CC across all
    workers (paper §2.2.2, Figs. 5-6).

Both schedules are *synchronization-free* (paper §2.4): every worker's index
set is locally computable from its rank alone; ``worker_tasks`` functions are
pure arithmetic over the shared task vector and are property-tested for
disjointness + full coverage.

The TPU analogue of a schedule is a *grid traversal order*; see
``grid_order`` at the bottom (used by ``core.autotile``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple


# ---------------------------------------------------------------------------
# Contiguous Clustering (§2.2.1)
# ---------------------------------------------------------------------------

def cc_range(rank: int, n_workers: int, n_tasks: int) -> Tuple[int, int]:
    """[start, stop) of the contiguous task range of ``rank`` under CC."""
    base, rem = divmod(n_tasks, n_workers)
    start = rank * base + min(rank, rem)
    stop = start + base + (1 if rank < rem else 0)
    return start, stop


def cc_worker_tasks(rank: int, n_workers: int, n_tasks: int) -> List[int]:
    start, stop = cc_range(rank, n_workers, n_tasks)
    return list(range(start, stop))


def cc_schedule(n_workers: int, n_tasks: int) -> List[List[int]]:
    return [cc_worker_tasks(r, n_workers, n_tasks) for r in range(n_workers)]


# ---------------------------------------------------------------------------
# Sibling Round-Robin Clustering (§2.2.2)
# ---------------------------------------------------------------------------

def srrc_cluster_size(llc_size: int, tcl_size: int, cores_per_llc: int) -> int:
    """clusterSize = LLC/TCL + (cores(LLC) - (LLC/TCL mod cores(LLC))).

    The paper states the second term "ensures a proper distribution of the
    work when in the presence of remainder"; we therefore apply it only when
    a remainder exists (equivalently, pad LLC/TCL up to the next multiple of
    cores(LLC)), which matches the stated intent while avoiding a gratuitous
    +cores(LLC) when the ratio already divides evenly.
    """
    s = max(1, llc_size // max(1, tcl_size))
    c = max(1, cores_per_llc)
    return s + ((c - (s % c)) % c)


@dataclass
class SRRCSchedule:
    """Materialized SRRC assignment.

    ``worker_groups[g]`` lists the worker ranks of group ``g`` (one group per
    LLC copy); ``assignment[w]`` is the ordered task list of worker ``w``.
    """

    cluster_size: int
    n_full_clusters: int        # clusters dealt round-robin to groups
    cc_cluster_start: int       # first task index of the merged CC cluster
    worker_groups: List[List[int]]
    assignment: List[List[int]]


def srrc_schedule(
    n_tasks: int,
    llc_size: int,
    tcl_size: int,
    worker_groups: Sequence[Sequence[int]],
) -> SRRCSchedule:
    """Build the SRRC schedule (paper §2.2.2).

    ``worker_groups`` partitions worker ranks into groups whose cores share
    an LLC (the Lowest-Level-Shared-Cache affinity of §2.3 guarantees the
    workers actually run there).
    """
    groups = [list(g) for g in worker_groups]
    n_w = len(groups)
    cores_per_llc = max(len(g) for g in groups)
    csize = srrc_cluster_size(llc_size, tcl_size, cores_per_llc)

    n_c = n_tasks // csize                      # clusters that can be formed
    n_rr = n_c - (n_c % n_w)                    # dealt round-robin (j < ...)
    cc_start = n_rr * csize                     # remainder clusters + tail -> CC

    n_workers = sum(len(g) for g in groups)
    assignment: List[List[int]] = [[] for _ in range(n_workers)]

    # Cluster-assignment level: cluster j -> group (j mod n_w).
    for j in range(n_rr):
        group = groups[j % n_w]
        base = j * csize
        # Task-assignment level: round-robin within the group (Fig. 6).
        for t in range(csize):
            worker = group[t % len(group)]
            assignment[worker].append(base + t)

    # Remainder: merged CC cluster over all workers (paper: "scheduled
    # according to the CC strategy").
    tail = n_tasks - cc_start
    if tail > 0:
        for rank in range(n_workers):
            lo, hi = cc_range(rank, n_workers, tail)
            assignment[rank].extend(range(cc_start + lo, cc_start + hi))

    return SRRCSchedule(
        cluster_size=csize,
        n_full_clusters=n_rr,
        cc_cluster_start=cc_start,
        worker_groups=groups,
        assignment=assignment,
    )


def srrc_worker_tasks(
    rank: int,
    n_tasks: int,
    llc_size: int,
    tcl_size: int,
    worker_groups: Sequence[Sequence[int]],
) -> Iterator[int]:
    """Synchronization-free per-worker index stream (paper §2.4): computed
    from ``rank`` alone with two loops (across clusters, within cluster),
    without materializing other workers' assignments."""
    groups = [list(g) for g in worker_groups]
    n_w = len(groups)
    gid = next(i for i, g in enumerate(groups) if rank in g)
    pos = groups[gid].index(rank)
    gsize = len(groups[gid])
    cores_per_llc = max(len(g) for g in groups)
    csize = srrc_cluster_size(llc_size, tcl_size, cores_per_llc)
    n_c = n_tasks // csize
    n_rr = n_c - (n_c % n_w)
    # Loop 1: my group's clusters.
    for j in range(gid, n_rr, n_w):
        base = j * csize
        # Loop 2: my round-robin slots within the cluster.
        for t in range(pos, csize, gsize):
            yield base + t
    # CC cluster remainder.
    cc_start = n_rr * csize
    tail = n_tasks - cc_start
    if tail > 0:
        n_workers = sum(len(g) for g in groups)
        lo, hi = cc_range(rank, n_workers, tail)
        for t in range(cc_start + lo, cc_start + hi):
            yield t


# ---------------------------------------------------------------------------
# Worker-core affinity (§2.3)
# ---------------------------------------------------------------------------

def lowest_level_shared_cache_groups(hierarchy) -> List[List[int]]:
    """Lowest-Level-Shared-Cache affinity mapping: workers may float among
    the cores under their lowest shared cache level. Returns the sibling
    groups of that level (one group per cache copy)."""
    lvl = hierarchy.lowest_shared_cache()
    if lvl is None:
        return [[c] for c in range(hierarchy.n_cores)]
    return [list(g) for g in lvl.siblings]


# ---------------------------------------------------------------------------
# Ring streaming order (DESIGN.md §5: CC / SRRC -> interconnect schedule)
# ---------------------------------------------------------------------------

def ring_stream_order(p: int, strategy: str = "cc") -> List[Tuple[int, ...]]:
    """Per-step chunk-owner offsets for streaming a ``p``-chunk ring.

    The mesh-level analogue of the CC/SRRC choice (DESIGN.md §5): a ring
    collective visits every chip's chunk once, and the *order* of visits is
    a schedule over the interconnect exactly as ``grid_order`` is one over a
    Pallas grid.  Offsets are relative to the consuming rank: at step ``s``
    a chip holds the chunk originally owned by ``(rank - offset) % p``.

      * ``cc``   -- one ICI direction: ``[(0,), (1,), ..., (p-1,)]``; the
                    single resident chunk hops forward each step (the
                    contiguous order of §2.2.1).
      * ``srrc`` -- serpentine, both ICI directions concurrently:
                    ``[(s, -s mod p) for s]``; each step consumes the
                    forward half-chunk of ``rank - s`` and the backward
                    half-chunk of ``rank + s``, so consecutive visits
                    alternate sides of the consumer the way §2.2.2's
                    serpentine traversal alternates row direction -- and
                    both interconnect directions carry traffic every step.

    Returns one tuple per step: length-1 under ``cc``, length-2
    ``(fwd_offset, bwd_offset)`` under ``srrc``.  Each direction covers all
    ``p`` offsets exactly once and advances one hop per step (the only
    orders a physical ring can realize); ``repro.dist.overlap.plan_ring``
    turns this into concrete ``ppermute`` permutation lists at plan time.
    """
    if p < 1:
        raise ValueError(f"ring needs p >= 1, got {p}")
    if strategy == "cc":
        return [(s,) for s in range(p)]
    if strategy == "srrc":
        return [(s, (-s) % p) for s in range(p)]
    raise ValueError(f"unknown strategy {strategy!r} (one of 'cc', 'srrc')")


# ---------------------------------------------------------------------------
# TPU grid traversal (DESIGN.md §2: CC / SRRC -> grid order)
# ---------------------------------------------------------------------------

def grid_order(grid: Tuple[int, ...], strategy: str = "cc") -> List[Tuple[int, ...]]:
    """Sequential visit order of a Pallas grid under a scheduling strategy.

    ``cc``    -- row-major (last dim innermost): contiguous output tiles,
                 K-reduction innermost keeps the accumulator block resident
                 (output-stationary), the spatial-locality goal of CC.
    ``srrc``  -- serpentine over the leading two dims: consecutive tasks
                 share an operand block (the row of A-blocks / column of
                 B-blocks), the reuse-through-sharing goal of SRRC. On a
                 megacore the two TensorCores split the leading dim, sharing
                 HBM-resident operands the way sibling cores share an LLC.
    """
    import itertools

    cells = list(itertools.product(*[range(g) for g in grid]))
    if strategy == "cc" or len(grid) < 2:
        return cells
    if strategy == "srrc":
        out = []
        lead = grid[0]
        rest = [range(g) for g in grid[1:]]
        import itertools as it
        for i in range(lead):
            tail = list(it.product(*rest))
            if i % 2 == 1:
                tail = tail[::-1]
            out.extend((i,) + t for t in tail)
        return out
    raise ValueError(f"unknown strategy {strategy!r}")
