"""Synchronization-free execution engine (paper §2.4).

Tasks produced by the decomposition stage are stored *contiguously in a
shared vector*; each worker computes its own index set locally (from its rank
and the scheduling policy) and iterates the shared vector without any
synchronization -- possible because the schedules hand every worker a
disjoint, locally-computable set.

Workers are OS threads (JAX/NumPy kernels release the GIL, so on multi-core
hosts this parallelizes for real); on a single-core container the engine
still exercises the full code path and -- crucially for the paper's claims --
the *cache behaviour* of streaming TCL-sized partitions vs. horizontal slabs
is real, since it is a property of the memory-access pattern, not of the
thread count.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

from repro.core.decompose import DecompositionPlan, Decomposer
from repro.core.distribution import CompositeDomain
from repro.core.hierarchy import MemoryLevel
from repro.core.schedule import (
    cc_worker_tasks,
    lowest_level_shared_cache_groups,
    srrc_schedule,
)

# A task is (computation instance, associated partition): we represent the
# partition as the tuple of per-sub-domain regions, and the computation as a
# user callable applied to them.
Task = Any
Computation = Callable[..., Any]


@dataclass
class StageTimes:
    """Per-stage wall times for the Fig. 10 breakdown."""

    decomposition: float = 0.0
    scheduling: float = 0.0
    execution: float = 0.0
    reduction: float = 0.0

    @property
    def total(self) -> float:
        return self.decomposition + self.scheduling + self.execution + self.reduction


@dataclass
class RunResult:
    results: List[Any]
    times: StageTimes
    n_tasks: int
    np: int


class Engine:
    """Decompose -> schedule -> execute -> reduce, with per-stage timing.

    ``schedule`` in {"cc", "srrc"}; ``strategy`` in {"cache_conscious",
    "horizontal"} selects the paper's proposal vs. the classical baseline.
    """

    def __init__(
        self,
        hierarchy: MemoryLevel,
        n_workers: int,
        tcl: str | int = "L1",
        schedule: str = "cc",
        strategy: str = "cache_conscious",
        phi=None,
        parallel: bool = True,
    ) -> None:
        from repro.core.decompose import phi_simple

        self.hierarchy = hierarchy
        self.n_workers = n_workers
        self.schedule = schedule
        self.parallel = parallel
        self.decomposer = Decomposer(
            hierarchy, tcl=tcl, phi=phi or phi_simple, strategy=strategy
        )

    # ------------------------------------------------------------------ run
    def run(
        self,
        domain: CompositeDomain | Sequence,
        compute: Computation,
        make_tasks: Optional[Callable[[DecompositionPlan], List[Task]]] = None,
        reduce_fn: Optional[Callable[[List[Any]], Any]] = None,
    ) -> RunResult:
        """Execute ``compute`` over the decomposed ``domain``.

        ``make_tasks(plan)`` builds the shared task vector from the plan
        (defaults to zipping the per-sub-domain regions); ``compute(task)``
        is the user-defined computation; ``reduce_fn`` merges the ordered
        per-task results (identity by default).
        """
        times = StageTimes()

        t0 = time.perf_counter()
        plan = self.decomposer.decompose(domain, self.n_workers)
        times.decomposition = time.perf_counter() - t0

        t0 = time.perf_counter()
        if make_tasks is None:
            tasks: List[Task] = list(zip(*plan.regions))
        else:
            tasks = make_tasks(plan)
        per_worker = self._assign(len(tasks))
        times.scheduling = time.perf_counter() - t0

        t0 = time.perf_counter()
        results: List[Any] = [None] * len(tasks)

        def work(rank: int) -> None:
            # Synchronization-free: disjoint indices, shared vectors.
            for idx in per_worker[rank]:
                results[idx] = compute(tasks[idx])

        if self.parallel and self.n_workers > 1:
            threads = [
                threading.Thread(target=work, args=(r,)) for r in range(self.n_workers)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for r in range(self.n_workers):
                work(r)
        times.execution = time.perf_counter() - t0

        t0 = time.perf_counter()
        out = results if reduce_fn is None else reduce_fn(results)
        times.reduction = time.perf_counter() - t0

        return RunResult(
            results=out if isinstance(out, list) else [out],
            times=times,
            n_tasks=len(tasks),
            np=plan.np,
        )

    # ----------------------------------------------------------- scheduling
    def _assign(self, n_tasks: int) -> List[List[int]]:
        if self.schedule == "cc":
            return [
                cc_worker_tasks(r, self.n_workers, n_tasks)
                for r in range(self.n_workers)
            ]
        if self.schedule == "srrc":
            groups = self._worker_groups()
            llc = self.hierarchy.llc()
            llc_size = llc.size if llc is not None else self.decomposer.tcl_bytes
            sched = srrc_schedule(
                n_tasks, llc_size, self.decomposer.tcl_bytes, groups
            )
            return sched.assignment
        raise ValueError(f"unknown schedule {self.schedule!r}")

    def _worker_groups(self) -> List[List[int]]:
        """Map workers onto LLSC core groups (paper §2.3): worker ranks are
        dealt to sibling groups proportionally to each group's core count."""
        core_groups = lowest_level_shared_cache_groups(self.hierarchy)
        n_cores = sum(len(g) for g in core_groups)
        groups: List[List[int]] = []
        rank = 0
        for g in core_groups:
            take = max(1, round(self.n_workers * len(g) / n_cores))
            take = min(take, self.n_workers - rank)
            if take <= 0:
                continue
            groups.append(list(range(rank, rank + take)))
            rank += take
        while rank < self.n_workers:  # leftovers -> last group
            groups[-1].append(rank)
            rank += 1
        return [g for g in groups if g]
