"""``repro.plan`` -- one recursive planner for the whole memory hierarchy.

The paper's contribution is a *run-time system* that decomposes a data
parallel computation against the memory hierarchy.  This module is the
single entry point that realizes it end to end: ``plan_run`` walks a
``MemoryLevel`` tree from the outermost level inward and runs the paper's
Algorithm-1 / §2.1.1 search once **per level**, with that level's phi:

  ============  ==============  =====================================
  level         phi             TCL (budget) of the search
  ============  ==============  =====================================
  DCN           ``phi_mesh``    one host's ICI domain (all its HBMs)
  ICI           ``phi_mesh``    one chip's HBM
  VMEM          ``phi_tpu``     the chip's usable VMEM (tile search)
  L3/L2/L1      ``phi_simple``  the cache's per-core share
                / ``phi_c``
  ============  ==============  =====================================

Each level's chosen ``np`` threads *down* as the next level's worker count
(the search lower bound): the partition count is a single global quantity
the walk refines level by level -- the paper's nested decomposition,
realized as one API.  At interconnect (mesh) levels the raw ``np*`` is
additionally *quantized* to the smallest mesh-axis divisor >= ``np*``
(ROADMAP: FSDP degree quantization); both values are recorded in the
sub-plan.

The result is a ``HierarchicalPlan``: a serializable (``to_json`` /
``from_json``) tree of per-level ``LevelPlan`` records that every consumer
reads instead of re-planning -- ``dist.sharding`` derives the FSDP degree
from the ICI sub-plan, ``dist.pipeline`` maps stages onto the DCN sub-plan,
``dist.overlap`` / ``kernels.matmul_cc`` pull their ``MatmulTilePlan`` from
the VMEM leaf, and ``benchmarks/run.py --only plan`` / ``launch/dryrun.py``
print the full tree.

The legacy entry points (``dist.sharding.mesh_decomposition``,
``core.autotile.plan_matmul``, ``core.decompose.Decomposer.decompose``) are
thin wrappers over single-level ``plan_run`` calls.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

from repro.core.autotile import MatmulTilePlan
from repro.core.decompose import (
    NoValidDecomposition,
    PhiFn,
    _next_structurally_valid,
    find_optimal_np,
    make_phi_mesh,
    phi_simple,
    validate_np,
)
from repro.core.distribution import (
    Array1DDistribution,
    Distribution,
    ReplicatedDistribution,
)
from repro.core.hierarchy import MemoryLevel

__all__ = [
    "MESH_LEVEL_NAMES",
    "PAGE_ALIGN",
    "PAGE_BUFFERING",
    "PAGE_LEVEL_NAMES",
    "HierarchicalPlan",
    "LevelPlan",
    "PlanError",
    "PlanPolicy",
    "Workload",
    "leaf_matmul_plan",
    "plan_run",
    "quantize_divisor",
]


class PlanError(RuntimeError):
    """A structurally inadmissible plan for the caller's context (e.g. a
    decode plan with a DCN level handed to a single-replica engine).
    Carries the offending level name and the full plan so callers can
    report or re-plan instead of string-matching an assert message."""

    def __init__(self, message: str, *, level: Optional[str] = None,
                 plan: Optional["HierarchicalPlan"] = None):
        super().__init__(message)
        self.level = level
        self.plan = plan

#: Interconnect level names: the level *below* holds the copies the search
#: partitions against (per-host ICI domains under DCN, per-chip HBMs under
#: ICI), so the budget is one child copy and np quantizes to its extent.
MESH_LEVEL_NAMES = ("DCN", "ICI")

#: Fallback sharding granule: one (sublane x lane) f32 register tile.
DEFAULT_GRANULE = 8 * 128 * 4

#: Levels whose leaf budget a decode KV *page* is fit against (the TPU
#: scratchpad, or the per-core L2 share on the CPU path).
PAGE_LEVEL_NAMES = ("VMEM", "L2")

#: KV pages are sized in whole sublane groups of tokens: the cache's
#: sequence dim is the second-minor dim of each (page_tokens, head_dim)
#: register tile, so a page that is not a sublane multiple pads up anyway.
PAGE_ALIGN = 8

#: Streaming pages are double-buffered (the next page's DMA overlaps the
#: current page's attention math), so two pages are resident at once.
PAGE_BUFFERING = 2


# ---------------------------------------------------------------------------
# Inputs: what to plan (Workload) and how (PlanPolicy)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Workload:
    """What is being decomposed, one description for every level.

    ``state_bytes``/``replicated_bytes`` feed the interconnect levels (the
    shardable training/serving state and the per-copy pinned reserve --
    activations, non-shardable buffers).  ``matmul`` is the per-chip local
    ``C[m,n] = A[m,k] @ B[k,n]`` the VMEM level tiles.  ``domain`` is a
    paper-style ``Distribution`` composite for host-cache levels (the CPU
    path).  ``overhead`` is the ``phi_mesh`` transient-copy factor
    (gradient buckets, all-gather destinations -- ``ModelConfig.overhead``).

    The decode (serving) workload adds the KV-cache terms (``repro.serve``):
    ``kv_bytes_per_token`` is the *global* per-token KV footprint (bytes x
    heads x layers), ``kv_layers``/``kv_heads`` its layer count and
    shardable head extent, ``max_tokens`` the per-sequence resident-token
    bound.  Mesh levels then choose the KV head sharding (recorded as
    ``detail["kv_shard"]``), and the ``PAGE_LEVEL_NAMES`` leaf runs the
    page search: partition one sequence's resident KV token range until
    one partition -- a *page* -- fits the leaf budget double-buffered.
    """

    state_bytes: int = 0
    replicated_bytes: int = 0
    matmul: Optional[Tuple[int, int, int]] = None
    dtype_bytes: int = 2
    overhead: float = 1.0
    domain: Optional[Tuple[Distribution, ...]] = None
    kv_bytes_per_token: int = 0
    kv_layers: int = 1
    kv_heads: int = 0
    max_tokens: int = 0


@dataclass(frozen=True)
class PlanPolicy:
    """How to search.

    ``n_workers`` seeds the outermost level (1 allows full replication, the
    mesh default); ``max_np`` caps a level's partition count by name (e.g.
    the FSDP capacity of the data axes at "ICI"); ``quantize`` enables the
    divisor quantization at mesh levels; ``tcl`` restricts the host-cache
    search to one named level (the ``Decomposer`` wrapper -- other cache
    levels become pass-through containers); ``cache_phi`` is the footprint
    estimator for host-cache levels; ``spec`` carries the MXU/lane/sublane
    alignment constants for the VMEM tile search.
    """

    strategy: str = "cache_conscious"   # | "horizontal"
    n_workers: int = 1
    quantize: bool = True
    max_np: Mapping[str, int] = field(default_factory=dict)
    tcl: Optional[str] = None
    cache_phi: PhiFn = phi_simple
    order: str = "cc"
    vmem_fraction: float = 1.0
    spec: Optional[Any] = None          # hw.tpu.TPUSpec
    use_tuned: bool = True              # consult experiments/tuning.json
                                        # (precedence analytic < tuned)


# ---------------------------------------------------------------------------
# Outputs: one LevelPlan per level, folded into a HierarchicalPlan tree
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LevelPlan:
    """One level's share of the nested decomposition.

    ``np_raw`` is the Algorithm-1 result; ``np`` the realized count after
    divisor quantization (equal at non-mesh levels).  ``extent`` is the
    realizable cap (child copies at mesh levels, 0 = unbounded at cache
    levels).  ``detail`` is a JSON-safe, kind-specific payload (the tile
    plan fields at VMEM, shard bytes at mesh levels).
    """

    level: str
    kind: str                    # mesh | cache | tile | container | leaf
    phi: str = ""
    budget_bytes: int = 0
    granule_bytes: int = 0
    n_workers: int = 1
    extent: int = 1
    np_raw: int = 1
    np: int = 1
    partition_bytes: float = 0.0
    fits: bool = True
    detail: Mapping[str, Any] = field(default_factory=dict)

    @property
    def replicated(self) -> bool:
        return self.np_raw <= 1


_LEVEL_FIELDS = ("level", "kind", "phi", "budget_bytes", "granule_bytes",
                 "n_workers", "extent", "np_raw", "np", "partition_bytes",
                 "fits")


@dataclass(frozen=True)
class HierarchicalPlan:
    """Serializable tree of per-level sub-plans (outermost level first)."""

    plan: LevelPlan
    child: Optional["HierarchicalPlan"] = None

    # ------------------------------------------------------------- traversal
    def nodes(self) -> Iterator["HierarchicalPlan"]:
        node: Optional[HierarchicalPlan] = self
        while node is not None:
            yield node
            node = node.child

    def levels(self) -> List[LevelPlan]:
        return [n.plan for n in self.nodes()]

    def find(self, name: str) -> Optional["HierarchicalPlan"]:
        for n in self.nodes():
            if n.plan.level == name:
                return n
        return None

    def level(self, name: str) -> Optional[LevelPlan]:
        sub = self.find(name)
        return sub.plan if sub is not None else None

    def leaf(self) -> LevelPlan:
        node = self
        while node.child is not None:
            node = node.child
        return node.plan

    def tile_plan(self) -> Optional[MatmulTilePlan]:
        """The VMEM level's ``MatmulTilePlan`` (None if no tile level)."""
        for lp in self.levels():
            if lp.kind == "tile":
                return MatmulTilePlan(**lp.detail["tile"])
        return None

    def page_plan(self) -> Optional[Mapping[str, Any]]:
        """The decode workload's KV page record (None if no page level):
        ``{"page_tokens", "page_bytes", "tok_bytes", "kv_shard", ...}`` --
        the leaf ``repro.serve`` sizes its paged KV cache from."""
        for lp in self.levels():
            if lp.kind == "page":
                return lp.detail["page"]
        return None

    def page_table(self) -> Optional[Mapping[str, Any]]:
        """The page level's pool geometry (None if no page level):
        ``{"pages_per_slot", "pages_total", "slots_bound"}`` -- the bounds
        the paged engine's ``PagePool`` must respect.  ``pages_per_slot``
        caps one sequence (``ceil(max_tokens / page_tokens)``);
        ``pages_total`` is how many *logical* pages (global token-bytes)
        the innermost mesh level's HBM leftover can hold after the
        replicated reserve, accounting for KV replication over the
        unsharded part of the model axis (0 = no mesh level to bound it)."""
        for lp in self.levels():
            if lp.kind == "page":
                return lp.detail.get("page_table")
        return None

    def prefix_budget(self) -> Optional[int]:
        """The mesh-level HBM leftover, in the scheduler's LOGICAL bytes
        (global per-token KV x tokens), that the cross-request prefix
        cache may keep resident (None if no page level; see
        ``serve/prefix.py``).  Recorded by the page level as
        ``detail["page_table"]["prefix_budget_bytes"]``; plans serialized
        before the field existed fall back to the equivalent
        ``pages_total`` x global page bytes product."""
        ptab = self.page_table()
        if ptab is None:
            return None
        if "prefix_budget_bytes" in ptab:
            return int(ptab["prefix_budget_bytes"])
        page = self.page_plan() or {}
        global_page = (int(page.get("page_tokens", 0))
                       * int(page.get("tok_bytes", 0))
                       * int(page.get("layers", 1))
                       * int(page.get("kv_shard", 1)))
        return int(ptab.get("pages_total", 0)) * global_page

    def chunk_tokens(self) -> Optional[int]:
        """The prefill CHUNK length -- the page level's ``page_tokens``
        (None if no page level).  The page is, by construction, the
        VMEM-fitting double-buffered slice of one sequence's KV stream,
        so it is also the natural unit to decompose prefill *time* into:
        the engine cuts prompts into chunks of this many tokens and
        interleaves them with decode ticks."""
        page = self.page_plan()
        return int(page["page_tokens"]) if page else None

    def replicas(self) -> int:
        """The DCN level's realized partition count for a decode workload
        -- the number of serving replicas the fleet stands up (1 when the
        plan has no DCN level).  ``repro.cluster`` is the consumer: the
        DCN level places whole replicas (request-level data parallelism,
        ``detail["placement"] == "replicas"``), so the cluster's width is
        the planner's outermost decision, not a config file's."""
        dcn = self.level("DCN")
        if dcn is None:
            return 1
        return int(dcn.detail.get("replicas", dcn.np))

    def kv_shard(self) -> int:
        """The KV head sharding degree the innermost mesh level chose for a
        decode workload (1 when no mesh level carries one)."""
        shard = 1
        for lp in self.levels():
            if lp.kind == "mesh" and "kv_shard" in lp.detail:
                shard = int(lp.detail["kv_shard"])
        return shard

    # ------------------------------------------------------------------ JSON
    def to_dict(self) -> dict:
        d: Dict[str, Any] = {f: getattr(self.plan, f) for f in _LEVEL_FIELDS}
        d["detail"] = dict(self.plan.detail)
        d["child"] = self.child.to_dict() if self.child is not None else None
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "HierarchicalPlan":
        kw = {f: d[f] for f in _LEVEL_FIELDS}
        child = d.get("child")
        return cls(
            plan=LevelPlan(detail=dict(d.get("detail") or {}), **kw),
            child=cls.from_dict(child) if child else None,
        )

    @classmethod
    def from_json(cls, s: str) -> "HierarchicalPlan":
        return cls.from_dict(json.loads(s))

    # --------------------------------------------------------------- display
    def describe(self) -> List[str]:
        """One printable line per level, indented by depth (the tree the
        CI dry plan and ``benchmarks/run.py --only plan`` print)."""
        lines = []
        for depth, lp in enumerate(self.levels()):
            ind = "  " * depth
            if lp.kind == "mesh":
                lines.append(
                    f"{ind}{lp.level}[mesh] np_raw={lp.np_raw} "
                    f"quantized={lp.np} extent={lp.extent} "
                    f"workers={lp.n_workers} budget={_fmt(lp.budget_bytes)} "
                    f"shard={_fmt(int(lp.detail.get('shard_bytes', 0)))} "
                    f"fits={lp.fits} phi={lp.phi}")
            elif lp.kind == "tile":
                t = lp.detail["tile"]
                lines.append(
                    f"{ind}{lp.level}[tile] block={t['bm']}x{t['bk']}x"
                    f"{t['bn']} np={lp.np} workers={lp.n_workers} "
                    f"vmem={_fmt(t['est_vmem_bytes'])}/"
                    f"{_fmt(lp.budget_bytes)} order={t['order']} "
                    f"fits={lp.fits} phi={lp.phi} "
                    f"src={t.get('source', 'analytic')}")
            elif lp.kind == "page":
                pg = lp.detail["page"]
                lines.append(
                    f"{ind}{lp.level}[page] page_tokens={pg['page_tokens']} "
                    f"page={_fmt(pg['page_bytes'])} x{pg['buffering']} "
                    f"kv_shard={pg['kv_shard']} np={lp.np} "
                    f"budget={_fmt(lp.budget_bytes)} fits={lp.fits} "
                    f"phi={lp.phi} src={pg.get('source', 'analytic')}")
            elif lp.kind == "cache":
                lines.append(
                    f"{ind}{lp.level}[cache] np={lp.np} "
                    f"workers={lp.n_workers} budget={_fmt(lp.budget_bytes)} "
                    f"part={_fmt(int(lp.partition_bytes))} fits={lp.fits} "
                    f"phi={lp.phi}")
            elif lp.kind == "leaf":
                lines.append(
                    f"{ind}{lp.level}[leaf] granule={lp.granule_bytes}B "
                    f"size={_fmt(lp.budget_bytes)}")
            else:
                lines.append(
                    f"{ind}{lp.level}[container] size={_fmt(lp.budget_bytes)}")
        return lines


def _fmt(b: float) -> str:
    for unit, s in (("GiB", 1 << 30), ("MiB", 1 << 20), ("KiB", 1 << 10)):
        if b >= s:
            return f"{b / s:.1f}{unit}"
    return f"{int(b)}B"


# ---------------------------------------------------------------------------
# FSDP degree quantization (ROADMAP open item)
# ---------------------------------------------------------------------------


def quantize_divisor(np_raw: int, extent: int, multiple_of: int = 1) -> int:
    """Smallest divisor of ``extent`` >= ``np_raw`` (and a multiple of
    ``multiple_of``).

    A mesh axis can only realize shard counts that divide its extent
    (uneven shards force GSPMD's padded layouts); the legacy rules rounded
    any 1 < np* < extent all the way up to full-axis sharding.  The planner
    instead quantizes to the nearest realizable degree: np*=5 on an 8-chip
    axis -> 8, np*=5 on a 12-chip axis -> 6, and np*=3 on a 12-chip axis
    stays 3 -- collectives stay as cheap as the memory budget allows.

    ``multiple_of`` carries the level above's partition count: this level's
    partitions refine the outer ones only when the outer count divides the
    inner, otherwise a partition would straddle an outer-copy (host)
    boundary.  Falls back to ignoring the constraint when no such divisor
    exists (e.g. the cap cut the extent below it).
    """
    np_raw = max(1, np_raw)
    if extent <= 0:
        return np_raw
    multiple_of = max(1, multiple_of)
    for d in range(1, extent + 1):
        if extent % d == 0 and d >= np_raw and d % multiple_of == 0:
            return d
    if multiple_of > 1:
        return quantize_divisor(np_raw, extent, 1)
    return extent


# ---------------------------------------------------------------------------
# Per-kind level planners
# ---------------------------------------------------------------------------


def _granule_below(level: MemoryLevel) -> int:
    for lvl in level.levels():
        if lvl.cache_line_size is not None:
            return lvl.cache_line_size
    return DEFAULT_GRANULE


def _classify(level: MemoryLevel, workload: Workload,
              policy: PlanPolicy) -> str:
    if level.name in MESH_LEVEL_NAMES and level.child is not None:
        return "mesh"
    if level.name == "VMEM" and workload.matmul is not None:
        return "tile"
    if (workload.kv_bytes_per_token > 0 and workload.matmul is None
            and workload.domain is None and level.name in PAGE_LEVEL_NAMES):
        return "page"
    if workload.domain is not None:
        if policy.tcl is not None:
            if level.name == policy.tcl:
                return "cache"
        elif level.cache_line_size is not None and level.name != "VREG":
            return "cache"
    if level.child is None:
        return "leaf"
    return "container"


def _record_level(level: MemoryLevel, kind: str, n_workers: int) -> LevelPlan:
    return LevelPlan(
        level=level.name or kind,
        kind=kind,
        budget_bytes=level.per_core_size(),
        granule_bytes=level.cache_line_size or 0,
        n_workers=n_workers,
        extent=max(1, len(level.siblings)),
    )


def _plan_mesh_level(level: MemoryLevel, workload: Workload,
                     policy: PlanPolicy, n_workers: int) -> LevelPlan:
    """Algorithm 1 with one child copy as the TCL (HBM under ICI, a host's
    ICI domain under DCN) -- ``dist.sharding.mesh_decomposition`` run at an
    arbitrary interconnect level."""
    child = level.child
    budget = child.size
    granule = _granule_below(child)
    extent = max(1, len(child.siblings))
    cap = policy.max_np.get(level.name)
    if cap:
        extent = min(extent, max(1, cap))
    phi = make_phi_mesh(overhead=workload.overhead)
    if workload.kv_heads > 0 and level.name == "DCN":
        # Decode workload at the DCN level: the placement unit is a whole
        # REPLICA (request-level data parallelism), not a KV head slice --
        # heads shard over the ICI below, and DCN's hosts each hold a full
        # model copy plus one share of the fleet's resident KV stream.
        # ``state_bytes`` is one replica's shardable KV, so the fleet
        # demand is ``state * extent``; Algorithm 1 partitions it against
        # one host's ICI domain, seeded by the caller's requested replica
        # count (``PlanPolicy.n_workers``) -- memory pressure can only
        # RAISE the replica count, never shrink it below the request.
        fleet = [Array1DDistribution(
            length=max(1, workload.state_bytes) * extent, element_size=1)]
        if workload.replicated_bytes:
            fleet.append(ReplicatedDistribution(workload.replicated_bytes))
        try:
            np_raw = find_optimal_np(budget, granule, fleet, n_workers, phi,
                                     max_np=extent)
            fits = True
        except NoValidDecomposition:
            np_raw, fits = extent, False
        np_q = (quantize_divisor(np_raw, extent, multiple_of=n_workers)
                if policy.quantize else np_raw)
        part = sum(phi(granule, d, np_q) for d in fleet)
        return LevelPlan(
            level=level.name, kind="mesh", phi="phi_mesh",
            budget_bytes=budget, granule_bytes=granule,
            n_workers=max(1, n_workers), extent=extent,
            np_raw=np_raw, np=np_q, partition_bytes=part, fits=fits,
            detail={
                "tcl_level": child.name,
                "sharded_bytes": workload.state_bytes * extent,
                "replicated_bytes": workload.replicated_bytes,
                "shard_bytes": -(-max(1, workload.state_bytes) * extent
                                 // np_q),
                "overhead": workload.overhead,
                "placement": "replicas",
                "replicas": np_q,
            },
        )
    dists: List[Distribution] = [
        Array1DDistribution(length=max(1, workload.state_bytes),
                            element_size=1)
    ]
    if workload.replicated_bytes:
        dists.append(ReplicatedDistribution(workload.replicated_bytes))
    if policy.strategy == "horizontal":
        np_raw = min(extent, max(1, n_workers))
        fits = validate_np(budget, granule, dists, np_raw, phi) == 1
    else:
        try:
            np_raw = find_optimal_np(budget, granule, dists, n_workers, phi,
                                     max_np=extent)
            fits = True
        except NoValidDecomposition:
            np_raw, fits = extent, False
    # Quantize to a realizable divisor that is also a multiple of the level
    # above's partition count (n_workers) -- inner partitions must refine
    # the outer ones, never straddle a host boundary.
    #
    # A decode workload (kv_heads > 0) partitions the KV cache over its
    # heads instead: the only degrees one mesh axis realizes for a cache
    # tensor are "unsharded" and "the whole axis" (GSPMD NamedSharding --
    # sub-axis sharding is the same open ROADMAP item as FSDP sub-axis
    # degrees), and the head count must divide evenly, so the shard degree
    # snaps to the axis extent when the heads fill it and to 1 otherwise.
    if workload.kv_heads > 0:
        head_extent = (extent if extent > 1
                       and workload.kv_heads % extent == 0 else 1)
        np_q = (head_extent if (np_raw > 1 and policy.quantize
                                and head_extent > 1)
                else (1 if policy.quantize else np_raw))
        if np_q < np_raw:
            fits = validate_np(budget, granule, dists, np_q, phi) == 1
    else:
        np_q = (quantize_divisor(np_raw, extent, multiple_of=n_workers)
                if policy.quantize else np_raw)
    part = sum(phi(granule, d, np_q) for d in dists)
    shard = -(-max(1, workload.state_bytes) // np_q)
    detail: Dict[str, Any] = {
        "tcl_level": child.name,
        "sharded_bytes": workload.state_bytes,
        "replicated_bytes": workload.replicated_bytes,
        "shard_bytes": shard,
        "overhead": workload.overhead,
    }
    if workload.kv_heads > 0:
        detail["kv_heads"] = workload.kv_heads
        detail["kv_shard"] = np_q
    return LevelPlan(
        level=level.name, kind="mesh", phi="phi_mesh",
        budget_bytes=budget, granule_bytes=granule,
        n_workers=max(1, n_workers), extent=extent,
        np_raw=np_raw, np=np_q, partition_bytes=part, fits=fits,
        detail=detail,
    )


def _plan_tile_level(level: MemoryLevel, workload: Workload,
                     policy: PlanPolicy, n_workers: int) -> LevelPlan:
    """The chip-level tile search (``core.autotile``) as one plan level."""
    from repro.core import autotile

    spec = policy.spec or _default_spec()
    m, k, n = workload.matmul
    budget = int(level.per_core_size() * policy.vmem_fraction)
    tuning = None
    if policy.strategy == "horizontal":
        tile = autotile.plan_matmul_horizontal(
            m, k, n, dtype_bytes=workload.dtype_bytes,
            n_workers=n_workers, spec=spec)
    else:
        tile = autotile._search_matmul_tiles(
            m, k, n, workload.dtype_bytes, spec, policy.order,
            n_workers, budget)
        if policy.use_tuned:
            tile, tuning = autotile.apply_tuned_matmul(
                tile, workload.dtype_bytes, spec, budget)
    detail: Dict[str, Any] = {"tile": {f: getattr(tile, f) for f in (
        "m", "k", "n", "bm", "bk", "bn", "order", "np",
        "est_vmem_bytes", "strategy", "source")}}
    if tuning is not None:
        detail["tuning"] = tuning
    return LevelPlan(
        level=level.name, kind="tile", phi="phi_tpu",
        budget_bytes=budget,
        granule_bytes=level.cache_line_size or DEFAULT_GRANULE,
        n_workers=max(1, n_workers), extent=max(1, tile.n_tasks),
        np_raw=tile.np, np=tile.np,
        partition_bytes=float(tile.est_vmem_bytes),
        fits=tile.est_vmem_bytes <= budget,
        detail=detail,
    )


def _plan_page_level(level: MemoryLevel, workload: Workload,
                     policy: PlanPolicy, n_workers: int,
                     kv_shard: int = 1,
                     mesh_budget_bytes: int = 0) -> LevelPlan:
    """The decode KV page search (``repro.serve``): Algorithm 1 over one
    sequence's resident token range.

    The streamed working set of one decode attention step is one layer's
    KV slice of one sequence after head sharding, so the domain element is
    ``kv_bytes_per_token / (kv_layers * kv_shard)`` bytes and the search
    partitions ``max_tokens`` of them until one partition -- a *page*,
    sublane-aligned and double-buffered -- fits the leaf budget.  The
    smallest np that fits gives the largest page, i.e. the fewest
    page-boundary crossings per token, exactly the paper's "largest
    partition that still fits the TCL" optimality argument.
    """
    budget = int(level.per_core_size() * policy.vmem_fraction)
    granule = level.cache_line_size or DEFAULT_GRANULE
    layers = max(1, workload.kv_layers)
    tok_bytes = max(1, -(-workload.kv_bytes_per_token
                         // (layers * max(1, kv_shard))))
    tokens = max(PAGE_ALIGN, workload.max_tokens)
    dist = Array1DDistribution(length=tokens, element_size=tok_bytes)

    def phi_page(_line: int, d: Distribution, np_: int) -> float:
        toks = -(-math.ceil(d.get_average_partition_size(np_))
                 // PAGE_ALIGN) * PAGE_ALIGN
        return float(PAGE_BUFFERING * toks * d.get_element_size())

    try:
        # The mesh partitioning was already consumed by the per-shard
        # element size (``/ kv_shard``): the page search covers ONE
        # sequence's per-chip stream, so it starts at a single partition
        # rather than inheriting the mesh np as a lower bound -- a
        # per-shard slice that fits whole gets exactly one page.
        np_raw = find_optimal_np(budget, granule, [dist], 1,
                                 phi_page, max_np=tokens)
        fits = True
    except NoValidDecomposition:
        # Even a single sublane group of tokens overflows the leaf: page at
        # the alignment floor and record the miss.
        np_raw, fits = -(-tokens // PAGE_ALIGN), False
    per_partition = -(-tokens // np_raw)
    page_tokens = -(-per_partition // PAGE_ALIGN) * PAGE_ALIGN
    source = "analytic"
    tuning = None
    if policy.use_tuned and fits:
        tuned_pt, tuning = _tuned_page_tokens(policy, tok_bytes, tokens,
                                              budget)
        if tuned_pt is not None:
            page_tokens, source = tuned_pt, "tuned"
    page_bytes = page_tokens * tok_bytes
    n_pages = -(-tokens // page_tokens)
    # Pool geometry (the paged engine's bounds, DESIGN.md §8): one logical
    # page costs ``page_tokens x kv_bytes_per_token`` GLOBAL bytes; the
    # innermost mesh level's per-chip HBM leftover after the replicated
    # reserve holds ``free x kv_shard`` logical bytes per data shard (one
    # logical byte is stored once per model-axis replica group, i.e.
    # ``extent / kv_shard`` copies across the ``extent``-chip domain).
    global_page_bytes = page_tokens * max(1, workload.kv_bytes_per_token)
    per_chip_free = max(0, mesh_budget_bytes - workload.replicated_bytes)
    pages_total = (per_chip_free * max(1, kv_shard)) // global_page_bytes \
        if mesh_budget_bytes else 0
    return LevelPlan(
        level=level.name, kind="page", phi="phi_page",
        budget_bytes=budget, granule_bytes=granule,
        n_workers=max(1, n_workers), extent=n_pages,
        np_raw=np_raw, np=n_pages,
        partition_bytes=float(PAGE_BUFFERING * page_bytes), fits=fits,
        detail={"page": {
            "page_tokens": page_tokens,
            "page_bytes": page_bytes,
            "tok_bytes": tok_bytes,
            "tokens": tokens,
            "layers": layers,
            "kv_shard": max(1, kv_shard),
            "align": PAGE_ALIGN,
            "buffering": PAGE_BUFFERING,
            "source": source,
        }, "page_table": {
            "pages_per_slot": n_pages,
            "pages_total": int(pages_total),
            "slots_bound": int(pages_total // n_pages) if pages_total else 0,
            # The mesh-level HBM leftover in LOGICAL bytes (global token
            # bytes, like the scheduler's budget): what the prefix cache
            # (serve/prefix.py) may keep resident across requests.
            "prefix_budget_bytes": int(
                per_chip_free * max(1, kv_shard)) if mesh_budget_bytes
            else 0,
        }, **({"tuning": tuning} if tuning is not None else {})},
    )


def _tuned_page_tokens(policy: PlanPolicy, tok_bytes: int, tokens: int,
                       budget: int) -> Tuple[Optional[int], Optional[dict]]:
    """A measured ``page_tokens`` winner for this decode shape, re-checked
    against the page level's own invariants (sublane alignment, the
    double-buffered page within the leaf budget); ``(None, None)`` leaves
    the analytic page standing."""
    from repro.tune.cache import bucket_paged, lookup_tuned

    spec = policy.spec or _default_spec()
    entry = lookup_tuned("paged_attention", spec.name,
                         bucket_paged(tok_bytes, tokens))
    if entry is None:
        return None, None
    pt = entry.get("block", {}).get("page_tokens")
    if not (isinstance(pt, int) and pt >= PAGE_ALIGN
            and pt % PAGE_ALIGN == 0):
        return None, None
    pt = min(pt, -(-tokens // PAGE_ALIGN) * PAGE_ALIGN)
    if PAGE_BUFFERING * pt * tok_bytes > budget:
        return None, None
    return pt, {
        "speedup": entry.get("speedup", 1.0),
        "median_us": entry.get("median_us", 0.0),
        "analytic_us": entry.get("analytic_us", 0.0),
        "analytic_block": entry.get("analytic_block", {}),
        "fingerprint": entry.get("fingerprint", ""),
    }


def _plan_cache_level(level: MemoryLevel, workload: Workload,
                      policy: PlanPolicy, n_workers: int) -> LevelPlan:
    """The paper's host-cache search (``Decomposer``) as one plan level."""
    dists = list(workload.domain)
    budget = level.per_core_size()
    line = level.cache_line_size or 64
    phi = policy.cache_phi
    if policy.strategy == "horizontal":
        np_raw = _next_structurally_valid(dists, max(1, n_workers), 1 << 30)
        if np_raw is None:
            raise NoValidDecomposition("horizontal: nWorkers not admissible")
        fits = validate_np(budget, line, dists, np_raw, phi) == 1
    else:
        np_raw = find_optimal_np(budget, line, dists, n_workers, phi)
        fits = True
    part = sum(phi(line, d, np_raw) for d in dists)
    return LevelPlan(
        level=level.name, kind="cache",
        phi=getattr(phi, "__name__", "phi"),
        budget_bytes=budget, granule_bytes=line,
        n_workers=max(1, n_workers), extent=0,
        np_raw=np_raw, np=np_raw, partition_bytes=part, fits=fits,
    )


def _default_spec():
    from repro.hw.tpu import chip_spec

    return chip_spec()


# ---------------------------------------------------------------------------
# The recursive walk
# ---------------------------------------------------------------------------


def plan_run(hierarchy: MemoryLevel, workload: Workload,
             policy: PlanPolicy = PlanPolicy()) -> HierarchicalPlan:
    """Decompose ``workload`` against the whole ``hierarchy``.

    Walks the level chain outermost-in.  At interconnect levels the search
    partitions state against one child copy; the child copy level itself
    (e.g. HBM under ICI) is consumed by that search, so the plan shows one
    node per *decision* -- ``DCN -> ICI/HBM -> VMEM -> VREG`` is a 4-level
    plan over a 5-level memory chain.  Each level's realized ``np`` threads
    down as the next level's worker count; crossing from the mesh into a
    chip divides it by the chip count (each chip's residual share of the
    global partitioning -- one partition -- seeds the tile search).
    """
    nodes: List[LevelPlan] = []
    np_thread = max(1, policy.n_workers)
    kv_shard = 1
    mesh_budget = 0
    level: Optional[MemoryLevel] = hierarchy
    while level is not None:
        kind = _classify(level, workload, policy)
        if kind == "mesh":
            node = _plan_mesh_level(level, workload, policy, np_thread)
            nodes.append(node)
            np_thread = node.np
            if node.detail.get("placement") == "replicas":
                # Replica placement partitions REQUESTS across the fleet,
                # not one request's state: each replica re-runs the inner
                # walk as a full single-host instance, so the fleet width
                # must not thread down as the next level's worker count.
                np_thread = 1
            if "kv_shard" in node.detail:
                kv_shard = int(node.detail["kv_shard"])
            mesh_budget = node.budget_bytes      # innermost mesh level wins
            nxt = level.child
            if nxt is not None and nxt.name not in MESH_LEVEL_NAMES:
                copies = max(1, len(nxt.siblings))   # the consumed TCL level
                np_thread = max(1, -(-np_thread // copies))
                nxt = nxt.child
            level = nxt
            continue
        if kind == "tile":
            node = _plan_tile_level(level, workload, policy, np_thread)
            nodes.append(node)
            np_thread = node.np
        elif kind == "page":
            node = _plan_page_level(level, workload, policy, np_thread,
                                    kv_shard, mesh_budget_bytes=mesh_budget)
            nodes.append(node)
            np_thread = node.np_raw
        elif kind == "cache":
            node = _plan_cache_level(level, workload, policy, np_thread)
            nodes.append(node)
            np_thread = node.np
        else:
            nodes.append(_record_level(level, kind, np_thread))
        level = level.child

    hp: Optional[HierarchicalPlan] = None
    for node in reversed(nodes):
        hp = HierarchicalPlan(plan=node, child=hp)
    return hp


# ---------------------------------------------------------------------------
# Cached leaf extraction (the overlap / kernel consumers)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=512)
def leaf_matmul_plan(
    m: int,
    k: int,
    n: int,
    dtype_bytes: int = 2,
    order: str = "cc",
    n_workers: int = 1,
    vmem_fraction: float = 1.0,
) -> MatmulTilePlan:
    """Memoized VMEM-leaf tile plan for a local ``(m, k) @ (k, n)`` block.

    ``dist.overlap``'s ring kernels and ``kernels.matmul_cc`` pull their
    ``MatmulTilePlan`` from here -- one single-chip ``plan_run`` per
    (shape, dtype), reused across every ring step and retrace (the planner
    successor of ``autotile.plan_matmul_cached``).
    """
    spec = _default_spec()
    hp = plan_run(
        spec.hierarchy(),
        Workload(matmul=(m, k, n), dtype_bytes=dtype_bytes),
        PlanPolicy(order=order, n_workers=n_workers,
                   vmem_fraction=vmem_fraction, spec=spec),
    )
    return hp.tile_plan()
