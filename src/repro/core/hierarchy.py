"""Platform-independent representation of a memory hierarchy (paper §3.1).

The paper represents a node's memory hierarchy as nested JSON objects with
fields ``size``, ``cacheLineSize``, ``siblings`` and ``child`` (Listing 1).
We reproduce that schema exactly, add a reader for Linux's
``/sys/devices/system/cpu`` (the paper's proof-of-concept tool), and extend it
with *TPU presets* where the levels are HBM -> VMEM -> VREG and the
"cache line" is the (sublane x lane) register tile (see DESIGN.md §2).
"""

from __future__ import annotations

import glob
import json
import os
import re
from dataclasses import dataclass, field
from typing import Iterator, List, Optional


@dataclass
class MemoryLevel:
    """One level of the hierarchy (paper §3.1).

    Attributes:
      size: size in bytes of each individual memory element at this level.
      cache_line_size: coherence-line size in bytes (None for non-cache levels
        such as RAM/HBM -- the paper omits the field there).
      siblings: array of arrays of sibling core ids sharing each copy.
      child: the lower (closer-to-core) level, or None at the bottom.
      name: human-readable tag (not part of the paper schema; serialized
        under ``"name"`` for debuggability, ignored on load if absent).
    """

    size: int
    siblings: List[List[int]]
    cache_line_size: Optional[int] = None
    child: Optional["MemoryLevel"] = None
    name: str = ""

    # ------------------------------------------------------------------ JSON
    def to_dict(self) -> dict:
        d: dict = {"siblings": self.siblings, "size": self.size}
        if self.cache_line_size is not None:
            d["cacheLineSize"] = self.cache_line_size
        if self.name:
            d["name"] = self.name
        d["child"] = self.child.to_dict() if self.child is not None else None
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "MemoryLevel":
        child = d.get("child")
        return cls(
            size=int(d["size"]),
            siblings=[list(map(int, s)) for s in d["siblings"]],
            cache_line_size=(int(d["cacheLineSize"]) if d.get("cacheLineSize") else None),
            child=cls.from_dict(child) if child else None,
            name=d.get("name", ""),
        )

    @classmethod
    def from_json(cls, s: str) -> "MemoryLevel":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------- traversal
    def levels(self) -> Iterator["MemoryLevel"]:
        """Iterate from this (outermost) level down to the innermost."""
        lvl: Optional[MemoryLevel] = self
        while lvl is not None:
            yield lvl
            lvl = lvl.child

    def find(self, name: str) -> Optional["MemoryLevel"]:
        for lvl in self.levels():
            if lvl.name == name:
                return lvl
        return None

    # ------------------------------------------------------------ properties
    @property
    def cores_per_copy(self) -> int:
        """Number of cores sharing each copy of this level (paper: cores(LLC))."""
        if not self.siblings:
            return 1
        return max(len(s) for s in self.siblings)

    @property
    def n_cores(self) -> int:
        return sum(len(s) for s in self.siblings) if self.siblings else 1

    def per_core_size(self) -> int:
        """TCL_PER_CORE of Algorithm 1: each core's share of one copy."""
        return self.size // max(1, self.cores_per_copy)

    # ---------------------------------------------------------------- caches
    def cache_levels(self) -> List["MemoryLevel"]:
        return [l for l in self.levels() if l.cache_line_size is not None]

    def llc(self) -> Optional["MemoryLevel"]:
        """Last Level Cache: the outermost cache level (paper §2.2.2)."""
        caches = self.cache_levels()
        return caches[0] if caches else None

    def lowest_shared_cache(self) -> Optional["MemoryLevel"]:
        """The innermost cache still shared by >1 core (paper §2.3 affinity)."""
        shared = [l for l in self.cache_levels() if l.cores_per_copy > 1]
        return shared[-1] if shared else self.llc()


# ---------------------------------------------------------------------------
# Linux sysfs reader (paper §3.1 proof-of-concept tool)
# ---------------------------------------------------------------------------

def _parse_cpu_list(s: str) -> List[int]:
    """Parse a sysfs cpu list like ``0-3,8,10-11`` into ids."""
    out: List[int] = []
    for part in s.strip().split(","):
        if not part:
            continue
        if "-" in part:
            a, b = part.split("-")
            out.extend(range(int(a), int(b) + 1))
        else:
            out.append(int(part))
    return out


def _parse_size(s: str) -> int:
    s = s.strip()
    m = re.match(r"^(\d+)\s*([KMG]?)B?$", s, re.IGNORECASE)
    if not m:
        return int(s)
    mult = {"": 1, "K": 1024, "M": 1024 ** 2, "G": 1024 ** 3}[m.group(2).upper()]
    return int(m.group(1)) * mult


def read_linux_hierarchy(sysfs_root: str = "/sys/devices/system/cpu") -> MemoryLevel:
    """Build the JSON hierarchy from a Linux installation (paper §3.1).

    Mirrors the paper's tool: walks ``cpuN/cache/indexM`` entries, groups by
    level, and nests them RAM -> LLC -> ... -> L1d. Instruction caches are
    skipped (the paper's Listing 1 shows data/unified caches only).
    """
    cpu_dirs = sorted(
        glob.glob(os.path.join(sysfs_root, "cpu[0-9]*")),
        key=lambda p: int(re.search(r"cpu(\d+)$", p).group(1)),
    )
    if not cpu_dirs:
        raise FileNotFoundError(f"no cpus under {sysfs_root}")

    # level -> {"size": int, "line": int, "groups": {frozenset(cores)}}
    levels: dict = {}
    for cpu_dir in cpu_dirs:
        for idx in sorted(glob.glob(os.path.join(cpu_dir, "cache", "index[0-9]*"))):
            def rd(fname: str) -> str:
                try:
                    with open(os.path.join(idx, fname)) as f:
                        return f.read().strip()
                except OSError:
                    return ""

            typ = rd("type")
            if typ == "Instruction":
                continue
            lvl = int(rd("level") or 0)
            if lvl == 0:
                continue
            entry = levels.setdefault(
                lvl,
                {"size": _parse_size(rd("size") or "0"),
                 "line": int(rd("coherency_line_size") or 64),
                 "groups": set()},
            )
            shared = rd("shared_cpu_list")
            if shared:
                entry["groups"].add(frozenset(_parse_cpu_list(shared)))

    all_cores = sorted(
        int(re.search(r"cpu(\d+)$", p).group(1)) for p in cpu_dirs
    )

    # RAM on top (size from /proc/meminfo when available).
    ram_bytes = 0
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemTotal:"):
                    ram_bytes = int(line.split()[1]) * 1024
                    break
    except OSError:
        pass

    child: Optional[MemoryLevel] = None
    for lvl in sorted(levels):  # L1 innermost first
        e = levels[lvl]
        siblings = sorted([sorted(g) for g in e["groups"]]) or [[c] for c in all_cores]
        child = MemoryLevel(
            size=e["size"], siblings=siblings, cache_line_size=e["line"],
            child=child, name=f"L{lvl}",
        )
    return MemoryLevel(
        size=ram_bytes or (1 << 33), siblings=[all_cores], cache_line_size=None,
        child=child, name="RAM",
    )


# ---------------------------------------------------------------------------
# Reference hierarchies
# ---------------------------------------------------------------------------

def paper_system_a() -> MemoryLevel:
    """System A of the paper §4.1: 2x quad-core AMD Opteron 2376.

    64 KiB L1d / core, 512 KiB L2 / core, 6 MiB L3 / processor.
    """
    cores = list(range(8))
    groups = [cores[:4], cores[4:]]
    per_core = [[c] for c in cores]
    l1 = MemoryLevel(64 * 1024, per_core, 64, None, "L1")
    l2 = MemoryLevel(512 * 1024, per_core, 64, l1, "L2")
    l3 = MemoryLevel(6 * 1024 * 1024, groups, 64, l2, "L3")
    return MemoryLevel(8 << 30, [cores], None, l3, "RAM")


def paper_system_i() -> MemoryLevel:
    """System I of the paper §4.1: 2x dual-core hyperthreaded Intel Xeon.

    32 KiB L1d / core, 256 KiB L2 / core, 8 MiB L3 / processor.
    Hardware threads: 2 per core -> 8 "workers" over 4 physical cores.
    """
    cores = list(range(8))  # hardware threads
    per_core = [[0, 1], [2, 3], [4, 5], [6, 7]]  # HT pairs share L1/L2
    groups = [cores[:4], cores[4:]]
    l1 = MemoryLevel(32 * 1024, per_core, 64, None, "L1")
    l2 = MemoryLevel(256 * 1024, per_core, 64, l1, "L2")
    l3 = MemoryLevel(8 * 1024 * 1024, groups, 64, l2, "L3")
    return MemoryLevel(8 << 30, [cores], None, l3, "RAM")


def tpu_hierarchy(
    hbm_bytes: int,
    vmem_bytes: int,
    lane_tile_bytes: int = 8 * 128 * 4,
    n_cores: int = 1,
    mesh_devices: int = 0,
    ici_bytes: Optional[int] = None,
    hosts: int = 1,
    dcn_bytes: Optional[int] = None,
) -> MemoryLevel:
    """TPU memory hierarchy in the paper's schema (DESIGN.md §2).

    HBM plays the RAM role (shared by the chip's cores), VMEM the TCL role
    (per-core scratchpad), and the "cache line" analogue is the
    (sublane x lane) register tile -- the minimal granule at which data is
    staged into VREGs, hence the unit footprints must be padded to.

    With ``mesh_devices > 0`` the device mesh becomes the outermost memory
    level (DESIGN.md §2): the interconnect ("ICI") holds the whole logical
    array, each chip's HBM is one *copy* of the target cache level (the
    "cores" of this level are chips), and the sharding granule -- one
    (sublane x lane) register tile per shard boundary -- plays the cache-line
    role. The per-chip sub-hierarchy (VMEM/VREG) hangs below unchanged, so
    the same ``Decomposer``/``find_optimal_np`` machinery that sizes Pallas
    blocks against VMEM sizes parameter shards against per-chip HBM.

    With ``hosts > 1`` the data-center network becomes one more level above
    the ICI (DESIGN.md §6): each host's ICI domain (``mesh_devices`` chips)
    is one *copy* of the DCN's target level, exactly as each chip's HBM is
    one copy of the ICI's.  ``mesh_devices`` is then the per-host chip
    count; the ``siblings`` of the ICI level group the global chip ids by
    host.  The recursive planner (``repro.plan``) walks DCN -> ICI -> VMEM
    -> VREG with the same Algorithm-1 search at every level.
    """
    if hosts > 1 and mesh_devices <= 0:
        raise ValueError("hosts > 1 requires mesh_devices > 0")
    cores = list(range(n_cores))
    vreg = MemoryLevel(1024, [[c] for c in cores], lane_tile_bytes, None, "VREG")
    vmem = MemoryLevel(vmem_bytes, [[c] for c in cores], lane_tile_bytes, vreg, "VMEM")
    if mesh_devices <= 0:
        return MemoryLevel(hbm_bytes, [cores], None, vmem, "HBM")
    hosts = max(1, hosts)
    chips = list(range(hosts * mesh_devices))
    hbm = MemoryLevel(
        size=hbm_bytes,
        siblings=[[c] for c in chips],
        cache_line_size=lane_tile_bytes,
        child=vmem,
        name="HBM",
    )
    ici_size = ici_bytes or mesh_devices * hbm_bytes
    ici = MemoryLevel(
        size=ici_size,
        siblings=[chips[h * mesh_devices:(h + 1) * mesh_devices]
                  for h in range(hosts)],
        cache_line_size=None,
        child=hbm,
        name="ICI",
    )
    if hosts <= 1:
        return ici
    return MemoryLevel(
        size=dcn_bytes or hosts * ici_size,
        siblings=[chips],
        cache_line_size=None,
        child=ici,
        name="DCN",
    )
