"""The ``Distribution<T>`` interface of the paper (Table 1) and concrete
distribution algorithms.

A *distribution* encodes the problem-specific knowledge required by the
runtime to decompose one sub-domain: how to split it into ``np`` partitions,
whether ``np`` is structurally admissible, and the geometric quantities the
phi footprint estimators need (element size, average partition size, average
first-dimension length).

``validate(np)`` follows the paper's tri-state contract:
  < 0  -- no solution exists for any value >= np
  = 0  -- np is not a valid solution, but larger values may be
  > 0  -- np is a valid solution
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple


class Distribution:
    """Paper Table 1. Subclasses implement one sub-domain's decomposition."""

    # -- structural admissibility ------------------------------------------
    def validate(self, np_: int) -> int:
        raise NotImplementedError

    # -- geometry for the phi estimators ------------------------------------
    def get_element_size(self) -> int:
        raise NotImplementedError

    def get_indivisible_size(self, np_: int) -> int:
        return 1

    def get_average_partition_size(self, np_: int) -> float:
        raise NotImplementedError

    def get_average_first_dim_size(self, np_: int) -> float:
        # Paper footnote 2: default for non-multidimensional structures.
        return 1.0

    # -- actual partitioning -----------------------------------------------
    def partition(self, np_: int) -> List[Tuple[slice, ...]]:
        """Split the domain into ``np_`` index regions (tuples of slices).

        The paper returns ``T[]``; we return index regions so the engine can
        apply them to any array-like payload without copying here.
        """
        raise NotImplementedError

    @property
    def total_elements(self) -> int:
        raise NotImplementedError


# ---------------------------------------------------------------------------


def _split_counts(total: int, parts: int) -> List[int]:
    """Sizes of ``parts`` near-equal chunks; first ``total % parts`` chunks get
    one extra unit (paper §2.1: 'distributing the remainder units among the
    regular-sized partitions, causing an unbalancing of, at most, one
    indivisible unit')."""
    base, rem = divmod(total, parts)
    return [base + (1 if i < rem else 0) for i in range(parts)]


def _split_slices(total: int, parts: int) -> List[slice]:
    out, off = [], 0
    for c in _split_counts(total, parts):
        out.append(slice(off, off + c))
        off += c
    return out


@dataclass
class Array1DDistribution(Distribution):
    """Contiguous split of a 1-D domain (files, vectors, Fourier ranges)."""

    length: int
    element_size: int
    indivisible: int = 1  # e.g. cipher block size for Crypt

    def validate(self, np_: int) -> int:
        if np_ <= 0:
            return 0
        units = self.length // self.indivisible
        return 1 if np_ <= units else -1

    def get_element_size(self) -> int:
        return self.element_size

    def get_indivisible_size(self, np_: int) -> int:
        return self.indivisible

    def get_average_partition_size(self, np_: int) -> float:
        return self.length / np_

    def get_average_first_dim_size(self, np_: int) -> float:
        return self.length / np_  # a 1-D partition is a single row

    def partition(self, np_: int) -> List[Tuple[slice, ...]]:
        units = self.length // self.indivisible
        out = []
        for s in _split_slices(units, np_):
            out.append((slice(s.start * self.indivisible,
                              min(s.stop * self.indivisible, self.length)),))
        return out

    @property
    def total_elements(self) -> int:
        return self.length


@dataclass
class ReplicatedDistribution(Distribution):
    """A sub-domain pinned whole to every worker.

    At the mesh level (DESIGN.md §2) this models replicated state --
    activations kept per chip, small norms/bias tensors, non-shardable
    buffers: partitioning the rest of the domain harder does not shrink it,
    so ``get_average_partition_size`` ignores ``np``. It contributes a
    constant term to the phi footprint, exactly like the paper's
    "other state competing for the TCL" observation (§4.4.2).
    """

    nbytes: int

    def validate(self, np_: int) -> int:
        return 1 if np_ >= 1 else 0

    def get_element_size(self) -> int:
        return 1

    def get_average_partition_size(self, np_: int) -> float:
        return float(self.nbytes)

    def partition(self, np_: int) -> List[Tuple[slice, ...]]:
        return [(slice(0, self.nbytes),) for _ in range(np_)]

    @property
    def total_elements(self) -> int:
        return self.nbytes


@dataclass
class RowBlockDistribution(Distribution):
    """Horizontal slabs of whole rows of a 2-D row-major array.

    This is the paper's *horizontal* (cache-neglectful) strategy when
    ``np == nWorkers``, and also a useful cache-conscious distribution for
    row-streaming computations (e.g. matrix transpose source).
    """

    rows: int
    cols: int
    element_size: int

    def validate(self, np_: int) -> int:
        if np_ <= 0:
            return 0
        return 1 if np_ <= self.rows else -1

    def get_element_size(self) -> int:
        return self.element_size

    def get_average_partition_size(self, np_: int) -> float:
        return self.rows * self.cols / np_

    def get_average_first_dim_size(self, np_: int) -> float:
        return float(self.cols)

    def partition(self, np_: int) -> List[Tuple[slice, ...]]:
        return [(s, slice(0, self.cols)) for s in _split_slices(self.rows, np_)]

    @property
    def total_elements(self) -> int:
        return self.rows * self.cols


@dataclass
class Array2DBlockDistribution(Distribution):
    """Square-grid block decomposition of a 2-D array (paper Listing 2).

    ``validate`` forces ``np`` to be a perfect square so the array is split
    into as many blocks per column as per row.
    """

    rows: int
    cols: int
    element_size: int

    def validate(self, np_: int) -> int:
        if np_ <= 0:
            return 0
        r = round(math.isqrt(np_))
        if r * r != np_:
            # Not a perfect square: invalid, but larger squares exist...
            rnext = math.isqrt(np_) + 1
            if rnext > min(self.rows, self.cols):
                return -1
            return 0
        if r > min(self.rows, self.cols):
            return -1
        return 1

    def get_element_size(self) -> int:
        return self.element_size

    def get_average_partition_size(self, np_: int) -> float:
        r = round(math.sqrt(np_))
        return (self.rows * self.cols) / float(r * r)

    def get_average_first_dim_size(self, np_: int) -> float:
        # Row-major: the first (contiguous) dimension of a block is its
        # column extent (paper Listing 2 returns numColumns/rsqrt).
        r = round(math.sqrt(np_))
        return self.cols / r

    def grid_side(self, np_: int) -> int:
        return round(math.sqrt(np_))

    def partition(self, np_: int) -> List[Tuple[slice, ...]]:
        r = self.grid_side(np_)
        row_sl = _split_slices(self.rows, r)
        col_sl = _split_slices(self.cols, r)
        return [(rs, cs) for rs in row_sl for cs in col_sl]

    @property
    def total_elements(self) -> int:
        return self.rows * self.cols


@dataclass
class StencilDistribution(Distribution):
    """Block decomposition with neighbourhood constraints (paper §2.1).

    For a radius-``halo`` stencil each partition must span at least
    ``2*halo + 1`` elements per dimension (the paper's 3x3 example has
    halo=1). Partitions are blocks of the interior; the engine supplies
    halo-extended reads.
    """

    rows: int
    cols: int
    element_size: int
    halo: int = 1

    def _min_side(self) -> int:
        return 2 * self.halo + 1

    def validate(self, np_: int) -> int:
        if np_ <= 0:
            return 0
        r = round(math.isqrt(np_))
        if r * r != np_:
            rnext = math.isqrt(np_) + 1
            if (self.rows // rnext) < self._min_side() or (self.cols // rnext) < self._min_side():
                return -1
            return 0
        if (self.rows // r) < self._min_side() or (self.cols // r) < self._min_side():
            return -1
        return 1

    def get_element_size(self) -> int:
        return self.element_size

    def get_indivisible_size(self, np_: int) -> int:
        return self._min_side()

    def get_average_partition_size(self, np_: int) -> float:
        # A partition's working set includes its halo ring.
        r = round(math.sqrt(np_))
        br = self.rows / r + 2 * self.halo
        bc = self.cols / r + 2 * self.halo
        return br * bc

    def get_average_first_dim_size(self, np_: int) -> float:
        r = round(math.sqrt(np_))
        return self.cols / r + 2 * self.halo

    def partition(self, np_: int) -> List[Tuple[slice, ...]]:
        r = round(math.sqrt(np_))
        return [
            (rs, cs)
            for rs in _split_slices(self.rows, r)
            for cs in _split_slices(self.cols, r)
        ]

    def halo_region(self, region: Tuple[slice, ...]) -> Tuple[slice, ...]:
        rs, cs = region
        return (
            slice(max(0, rs.start - self.halo), min(self.rows, rs.stop + self.halo)),
            slice(max(0, cs.start - self.halo), min(self.cols, cs.stop + self.halo)),
        )

    @property
    def total_elements(self) -> int:
        return self.rows * self.cols


# ---------------------------------------------------------------------------
# Composite domains (paper §2.1: a domain D = union of sub-domains D_i)
# ---------------------------------------------------------------------------


@dataclass
class CompositeDomain:
    """A domain built from multiple sub-domains, each with its own
    distribution (paper §2.1). A partition of the composite comprises one
    partition of each sub-domain."""

    dists: Sequence[Distribution]

    def __iter__(self):
        return iter(self.dists)

    def __len__(self):
        return len(self.dists)


def matmul_domain(n: int, m: int, k: int, element_size: int) -> CompositeDomain:
    """The paper's Fig. 3 block decomposition for C[n,m] = A[n,k] @ B[k,m]:
    three square-blocked sub-domains (A, B and the output C)."""
    return CompositeDomain(
        dists=[
            Array2DBlockDistribution(n, k, element_size),   # A
            Array2DBlockDistribution(k, m, element_size),   # B
            Array2DBlockDistribution(n, m, element_size),   # C
        ]
    )


def matmul_task_grid(np_: int) -> List[Tuple[int, int, int]]:
    """Tasks for the blocked matmul of Fig. 3: each C block (i, j) must be
    combined with the sqrt(np) (A, B) block pairs along k -> sqrt(np)^3 tasks
    (the paper's 1024^2 example with 16x16 blocks yields 16^3 = 4096 tasks)."""
    side = round(math.sqrt(np_))
    return [(i, j, kk) for i in range(side) for j in range(side) for kk in range(side)]
