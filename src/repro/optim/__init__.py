from repro.optim.adamw import (
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    lr_schedule,
)
from repro.optim.compress import (
    compress_gradient,
    decompress_gradient,
    ef_state_init,
)

__all__ = [k for k in dir() if not k.startswith("_")]
