"""Gradient compression for cross-pod all-reduce.

Two schemes, applied *before* the data-parallel mean (XLA then all-reduces
the compressed representation across the slow inter-pod links):

  * ``bf16``    -- cast gradients to bf16 for the reduce (2x wire bytes).
  * ``int8_ef`` -- per-tensor symmetric int8 quantization with error
                   feedback: the quantization residual is carried to the
                   next step (Seide et al. 2014 / 1-bit Adam lineage), which
                   keeps convergence unaffected to first order (4x wire
                   bytes).

In SPMD/pjit form we cannot intercept XLA's own all-reduce, so compression
is expressed as quantize -> (all-reduce happens on the quantized values via
the psum the caller performs or XLA inserts) -> dequantize; the roofline
collective term reflects the reduced payload when enabled because the
reduced tensor *is* the int8/bf16 one.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def ef_state_init(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_gradient(
    grads: PyTree, scheme: str, ef: Optional[PyTree] = None
) -> Tuple[PyTree, Optional[PyTree], Optional[PyTree]]:
    """Returns (wire_grads, scales, new_ef)."""
    if scheme == "none":
        return grads, None, ef
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), None, ef
    if scheme == "int8_ef":
        assert ef is not None

        def q(g, e):
            g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            qi = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            resid = g32 - qi.astype(jnp.float32) * scale
            return qi, scale, resid.astype(jnp.bfloat16)

        out = jax.tree.map(q, grads, ef)
        istuple = lambda x: isinstance(x, tuple)
        wire = jax.tree.map(lambda t: t[0], out, is_leaf=istuple)
        scales = jax.tree.map(lambda t: t[1], out, is_leaf=istuple)
        new_ef = jax.tree.map(lambda t: t[2], out, is_leaf=istuple)
        return wire, scales, new_ef
    raise ValueError(f"unknown compression scheme {scheme!r}")


def decompress_gradient(wire: PyTree, scheme: str,
                        scales: Optional[PyTree] = None) -> PyTree:
    if scheme == "none":
        return wire
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), wire)
    if scheme == "int8_ef":
        return jax.tree.map(
            lambda qi, s: qi.astype(jnp.float32) * s, wire, scales)
    raise ValueError(scheme)
