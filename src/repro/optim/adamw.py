"""AdamW with mixed-precision state, global-norm clipping and warmup+cosine
schedule. Pure pytree functions (no optax dependency); optimizer moments can
be kept in bf16 (``state_dtype``) -- a distributed-memory optimization that
roughly halves optimizer HBM at <0.1% quality cost at these scales.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array            # ()
    mu: PyTree                 # first moment
    nu: PyTree                 # second moment


def adamw_init(params: PyTree, state_dtype=jnp.float32) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, state_dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: TrainConfig):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(1, cfg.warmup_steps), 1.0)
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return cfg.learning_rate * warm * (0.1 + 0.9 * cos)
    return fn


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def adamw_update(
    params: PyTree,
    grads: PyTree,
    state: OptState,
    cfg: TrainConfig,
) -> Tuple[PyTree, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(jnp.float32)
        upd = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * p32
        return ((p32 - lr * upd).astype(p.dtype),
                m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_mu, new_nu), {
        "lr": lr, "grad_norm": gnorm}
