"""``repro.tune`` -- empirical autotuning around the plan's analytic tiles.

``repro.tune.cache`` is the persisted artifact (``experiments/tuning.json``)
and its lookup API; ``repro.tune.sweep`` is the measurement harness.  The
planner (``core.plan`` / ``core.autotile`` / ``models.mamba2``) consults the
cache with precedence analytic < tuned; the ``repro-tune`` CLI
(``repro.launch.tune``) runs the sweeps end to end.
"""

from repro.tune.cache import (
    TUNING_ENV,
    TuningEntry,
    entry_key,
    hw_fingerprint,
    load_tuning,
    lookup_tuned,
    record_tuned,
    tuning_path,
)
from repro.tune.sweep import (
    Candidate,
    SweepResult,
    default_sweeps,
    run_sweeps,
    sweep_attention,
    sweep_matmul,
    sweep_paged,
    sweep_ssd,
    time_callable,
)

__all__ = [
    "TUNING_ENV",
    "TuningEntry",
    "Candidate",
    "SweepResult",
    "default_sweeps",
    "entry_key",
    "hw_fingerprint",
    "load_tuning",
    "lookup_tuned",
    "record_tuned",
    "run_sweeps",
    "sweep_attention",
    "sweep_matmul",
    "sweep_paged",
    "sweep_ssd",
    "time_callable",
    "tuning_path",
]
