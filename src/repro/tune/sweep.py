"""Empirical neighborhood sweep around the planner's analytic tiles.

The paper's runtime picks block shapes *analytically* (``phi_tpu`` inside
Algorithm 1); this module adds the empirical half of Rasch's
analytic-plus-autotuning argument (PAPERS.md): for each Pallas kernel the
analytic block is the **center** of a small neighborhood -- power-of-two,
sublane/MXU-aligned perturbations of each block extent -- every candidate
is pre-filtered through the *same* VMEM working-set model the planner uses
(``_matmul_vmem_bytes`` / ``_attn_vmem_bytes`` / ``phi_page``'s buffered
page / ``ssd_workset_bytes``), the survivors are timed with warmup +
``block_until_ready`` medians, and the winner is persisted to
``experiments/tuning.json`` (``repro.tune.cache``) for the planner to
consult on the next run.

``dry=True`` stops after enumeration + VMEM filtering (the CI smoke: the
candidate set is proven budget-clean without timing anything).  On CPU the
kernels run in Pallas interpret mode -- CPU medians count as the perf
trajectory until hardware shows up (ROADMAP).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

from repro.core.autotile import (
    AttentionTilePlan,
    MatmulTilePlan,
    _align_block,
    _attn_vmem_bytes,
    _matmul_vmem_bytes,
    _round_down,
    _round_up,
    _search_matmul_tiles,
    clamp_attention_plan,
    plan_attention,
)
from repro.hw.tpu import TPUSpec, chip_spec
from repro.tune.cache import (
    TuningEntry,
    bucket_attention,
    bucket_matmul,
    bucket_paged,
    bucket_ssd,
    hw_fingerprint,
    record_tuned,
)

__all__ = [
    "Candidate",
    "SweepResult",
    "default_sweeps",
    "run_sweeps",
    "sweep_attention",
    "sweep_matmul",
    "sweep_paged",
    "sweep_ssd",
    "time_callable",
]


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------


def time_callable(fn: Callable[[], Any], warmup: int = 2,
                  iters: int = 5) -> float:
    """Median wall seconds of ``fn()`` after ``warmup`` discarded calls,
    each call synchronized with ``block_until_ready`` (jax dispatch is
    async; un-synchronized timings measure nothing)."""
    import jax

    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclass
class Candidate:
    """One swept block assignment: the extents, its working-set estimate
    under the planner's model, and (after timing) the measured median."""

    block: Dict[str, int]
    est_vmem_bytes: int
    fits: bool
    median_us: Optional[float] = None

    @property
    def label(self) -> str:
        return "/".join(f"{k}={v}" for k, v in sorted(self.block.items()))


@dataclass
class SweepResult:
    kernel: str
    bucket: str
    workload: Dict[str, Any]
    budget_bytes: int
    center: Dict[str, int]
    candidates: List[Candidate] = field(default_factory=list)  # fit only
    rejected: int = 0            # enumerated but over the VMEM budget
    entry: Optional[TuningEntry] = None      # None on a dry run

    @property
    def winner(self) -> Optional[Candidate]:
        timed = [c for c in self.candidates if c.median_us is not None]
        return min(timed, key=lambda c: c.median_us) if timed else None

    @property
    def analytic_us(self) -> Optional[float]:
        for c in self.candidates:
            if c.block == self.center and c.median_us is not None:
                return c.median_us
        return None


def _finish(result: SweepResult, spec: TPUSpec, dry: bool,
            make_fn: Callable[[Candidate], Callable[[], Any]],
            warmup: int, iters: int,
            workload: Mapping[str, Any]) -> SweepResult:
    """Time every fitting candidate and fold the winner into an entry."""
    if dry:
        return result
    for cand in result.candidates:
        fn = make_fn(cand)
        cand.median_us = time_callable(fn, warmup=warmup, iters=iters) * 1e6
    win = result.winner
    analytic_us = result.analytic_us
    if win is None or analytic_us is None:
        return result
    result.entry = TuningEntry(
        kernel=result.kernel,
        arch=spec.name,
        bucket=result.bucket,
        fingerprint=hw_fingerprint(),
        block=dict(win.block),
        analytic_block=dict(result.center),
        median_us=round(win.median_us, 3),
        analytic_us=round(analytic_us, 3),
        speedup=round(analytic_us / max(win.median_us, 1e-9), 4),
        workload=dict(workload),
    )
    return result


def _dedup_fitting(raw: List[Dict[str, int]], est: Callable[[Mapping], int],
                   budget: int) -> (List[Candidate], int):
    seen, fitting, rejected = set(), [], 0
    for block in raw:
        key = tuple(sorted(block.items()))
        if key in seen:
            continue
        seen.add(key)
        e = est(block)
        if e <= budget:
            fitting.append(Candidate(block=block, est_vmem_bytes=e,
                                     fits=True))
        else:
            rejected += 1
    return fitting, rejected


def _dtype_of(dtype_bytes: int):
    import jax.numpy as jnp

    return {2: jnp.bfloat16, 4: jnp.float32}.get(dtype_bytes, jnp.float32)


# ---------------------------------------------------------------------------
# matmul_cc
# ---------------------------------------------------------------------------


def _extent_options(center: int, dim: int, spec: TPUSpec) -> List[int]:
    """Power-of-two perturbations of one block extent: half and double the
    center, re-aligned to the same MXU/sublane granule the analytic search
    uses, clamped to the (padded) problem dim."""
    unit = spec.mxu if dim > spec.mxu else 8
    opts = {center}
    opts.add(_round_down(center // 2, unit))
    opts.add(_align_block(center * 2, dim, spec.mxu))
    return sorted(o for o in opts if o >= 1)


def sweep_matmul(m: int, k: int, n: int, dtype_bytes: int = 2,
                 spec: Optional[TPUSpec] = None, order: str = "cc",
                 vmem_fraction: float = 1.0, warmup: int = 1,
                 iters: int = 5, dry: bool = False,
                 interpret: Optional[bool] = None) -> SweepResult:
    spec = spec or chip_spec()
    budget = int(spec.usable_vmem * vmem_fraction)
    center = _search_matmul_tiles(m, k, n, dtype_bytes, spec, order, 1,
                                  budget)
    raw = [
        {"bm": bm, "bk": bk, "bn": bn}
        for bm in _extent_options(center.bm, m, spec)
        for bk in _extent_options(center.bk, k, spec)
        for bn in _extent_options(center.bn, n, spec)
    ]
    fitting, rejected = _dedup_fitting(
        raw, lambda b: _matmul_vmem_bytes(b["bm"], b["bk"], b["bn"],
                                          dtype_bytes), budget)
    result = SweepResult(
        kernel="matmul_cc",
        bucket=bucket_matmul(m, k, n, dtype_bytes),
        workload={"m": m, "k": k, "n": n, "dtype_bytes": dtype_bytes},
        budget_bytes=budget,
        center={"bm": center.bm, "bk": center.bk, "bn": center.bn},
        candidates=fitting, rejected=rejected,
    )
    if dry:
        return result

    import jax
    from repro.kernels.matmul_cc import matmul_cc

    dt = _dtype_of(dtype_bytes)
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (m, k), dt)
    b = jax.random.normal(kb, (k, n), dt)

    def make_fn(cand: Candidate):
        plan = dataclasses.replace(
            center, bm=cand.block["bm"], bk=cand.block["bk"],
            bn=cand.block["bn"], est_vmem_bytes=cand.est_vmem_bytes)
        f = jax.jit(lambda x, y, p=plan: matmul_cc(
            x, y, plan=p, interpret=interpret))
        return lambda: f(a, b)

    return _finish(result, spec, dry, make_fn, warmup, iters,
                   result.workload)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


def sweep_attention(q_len: int, kv_len: int, head_dim: int,
                    dtype_bytes: int = 2, heads: int = 4, batch: int = 1,
                    causal: bool = True, spec: Optional[TPUSpec] = None,
                    vmem_fraction: float = 1.0, warmup: int = 1,
                    iters: int = 5, dry: bool = False,
                    interpret: Optional[bool] = None) -> SweepResult:
    spec = spec or chip_spec()
    budget = int(spec.usable_vmem * vmem_fraction)
    sub = spec.sublane(dtype_bytes)
    analytic = plan_attention(q_len, kv_len, head_dim,
                              dtype_bytes=dtype_bytes, spec=spec,
                              vmem_fraction=vmem_fraction, use_tuned=False)
    # Sweep the blocks the kernel will actually run (the wrapper clamps a
    # block larger than the sequence), re-aligned to the sublane granule:
    # candidates must be 8-aligned to be admissible as tuned entries, and
    # the kernel's own pad/clamp makes the aligned block equivalent.
    clamped = clamp_attention_plan(analytic, q_len, kv_len,
                                   dtype_bytes=dtype_bytes)
    center = dataclasses.replace(
        clamped,
        block_q=min(_round_up(clamped.block_q, 8), _round_up(q_len, sub)),
        block_kv=min(_round_up(clamped.block_kv, 8),
                     _round_up(kv_len, sub)))

    def q_opts(c: int) -> List[int]:
        opts = {c, max(8, _round_down(c // 2, 8)),
                min(_round_up(c * 2, sub), _round_up(q_len, sub))}
        return sorted(o for o in opts if o >= 8)

    def kv_opts(c: int) -> List[int]:
        opts = {c, max(8, _round_down(c // 2, 8)),
                min(_round_up(c * 2, sub), _round_up(kv_len, sub))}
        return sorted(o for o in opts if o >= 8)

    raw = [{"block_q": bq, "block_kv": bkv}
           for bq in q_opts(center.block_q)
           for bkv in kv_opts(center.block_kv)]
    fitting, rejected = _dedup_fitting(
        raw, lambda b: _attn_vmem_bytes(b["block_q"], b["block_kv"],
                                        head_dim, dtype_bytes), budget)
    result = SweepResult(
        kernel="flash_attention",
        bucket=bucket_attention(q_len, kv_len, head_dim, dtype_bytes),
        workload={"q_len": q_len, "kv_len": kv_len, "head_dim": head_dim,
                  "dtype_bytes": dtype_bytes, "heads": heads,
                  "batch": batch, "causal": causal},
        budget_bytes=budget,
        center={"block_q": center.block_q, "block_kv": center.block_kv},
        candidates=fitting, rejected=rejected,
    )
    if dry:
        return result

    import jax
    from repro.kernels.flash_attention import flash_attention

    dt = _dtype_of(dtype_bytes)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (batch, heads, q_len, head_dim), dt)
    k = jax.random.normal(kk, (batch, heads, kv_len, head_dim), dt)
    v = jax.random.normal(kv, (batch, heads, kv_len, head_dim), dt)

    def make_fn(cand: Candidate):
        plan = dataclasses.replace(
            center, block_q=cand.block["block_q"],
            block_kv=cand.block["block_kv"],
            est_vmem_bytes=cand.est_vmem_bytes)
        f = jax.jit(lambda a, b, c, p=plan: flash_attention(
            a, b, c, causal=causal, plan=p, interpret=interpret))
        return lambda: f(q, k, v)

    return _finish(result, spec, dry, make_fn, warmup, iters,
                   result.workload)


# ---------------------------------------------------------------------------
# paged_attention (the plan's page level)
# ---------------------------------------------------------------------------


def sweep_paged(max_tokens: int = 256, n_kv: int = 2, group: int = 2,
                head_dim: int = 32, slots: int = 4, dtype_bytes: int = 4,
                spec: Optional[TPUSpec] = None, vmem_fraction: float = 1.0,
                warmup: int = 1, iters: int = 5, dry: bool = False,
                interpret: Optional[bool] = None) -> SweepResult:
    """Sweep the decode page size -- the block of ``kernels.
    paged_attention`` IS the plan's page, so the candidate set perturbs
    ``page_tokens`` and each candidate re-lays the pool at that granule."""
    from repro.core.plan import (
        PAGE_ALIGN,
        PAGE_BUFFERING,
        PlanPolicy,
        Workload,
        plan_run,
    )

    spec = spec or chip_spec()
    budget = int(spec.usable_vmem * vmem_fraction)
    tok_bytes = 2 * n_kv * head_dim * dtype_bytes      # K + V, one layer
    hp = plan_run(
        spec.hierarchy(),
        Workload(kv_bytes_per_token=tok_bytes, kv_layers=1, kv_heads=n_kv,
                 max_tokens=max_tokens),
        PlanPolicy(spec=spec, vmem_fraction=vmem_fraction, use_tuned=False))
    page = hp.page_plan()
    center_pt = int(page["page_tokens"])
    cap = _round_up(max_tokens, PAGE_ALIGN)
    raw_pts = {center_pt,
               max(PAGE_ALIGN, _round_down(center_pt // 2, PAGE_ALIGN)),
               min(cap, _round_up(center_pt * 2, PAGE_ALIGN))}
    raw = [{"page_tokens": pt} for pt in sorted(raw_pts)]
    fitting, rejected = _dedup_fitting(
        raw, lambda b: PAGE_BUFFERING * b["page_tokens"] * tok_bytes, budget)
    result = SweepResult(
        kernel="paged_attention",
        bucket=bucket_paged(tok_bytes, max_tokens),
        workload={"max_tokens": max_tokens, "n_kv": n_kv, "group": group,
                  "head_dim": head_dim, "slots": slots,
                  "dtype_bytes": dtype_bytes, "tok_bytes": tok_bytes},
        budget_bytes=budget,
        center={"page_tokens": center_pt},
        candidates=fitting, rejected=rejected,
    )
    if dry:
        return result

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.paged_attention import paged_attention

    dt = _dtype_of(dtype_bytes)
    h = n_kv * group
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((slots, h, head_dim)), dt)

    def make_fn(cand: Candidate):
        pt = cand.block["page_tokens"]
        n_logical = -(-max_tokens // pt)
        p_total = 1 + slots * n_logical          # + reserved null page
        k_pages = jnp.asarray(
            rng.standard_normal((p_total, pt, n_kv, head_dim)), dt)
        v_pages = jnp.asarray(
            rng.standard_normal((p_total, pt, n_kv, head_dim)), dt)
        table = jnp.asarray(
            1 + rng.permutation(slots * n_logical).reshape(slots, n_logical),
            jnp.int32)
        lengths = jnp.asarray(
            rng.integers(max_tokens // 2, max_tokens + 1, slots), jnp.int32)
        f = jax.jit(lambda qq, kk, vv, tb, ln: paged_attention(
            qq, kk, vv, tb, ln, page_tokens=pt, interpret=interpret))
        return lambda: f(q, k_pages, v_pages, table, lengths)

    return _finish(result, spec, dry, make_fn, warmup, iters,
                   result.workload)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------


def sweep_ssd(seq_len: int = 256, n_heads: int = 2, head_dim: int = 32,
              state_dim: int = 32, dtype_bytes: int = 4, batch: int = 1,
              spec: Optional[TPUSpec] = None, warmup: int = 1,
              iters: int = 5, dry: bool = False,
              interpret: Optional[bool] = None) -> SweepResult:
    from repro.models.mamba2 import choose_chunk, ssd_workset_bytes

    spec = spec or chip_spec()
    budget = spec.usable_vmem // 2           # choose_chunk's own budget
    center_c = choose_chunk(seq_len, n_heads, head_dim, state_dim,
                            dtype_bytes=dtype_bytes, spec=spec,
                            use_tuned=False)
    cap = min(_round_up(seq_len, 8), 1024)
    raw_cs = {center_c, max(16, _round_down(center_c // 2, 8)),
              min(cap, _round_up(center_c * 2, 8))}
    raw = [{"chunk": c} for c in sorted(raw_cs)]
    fitting, rejected = _dedup_fitting(
        raw, lambda b: ssd_workset_bytes(b["chunk"], n_heads, head_dim,
                                         state_dim, dtype_bytes), budget)
    result = SweepResult(
        kernel="ssd_scan",
        bucket=bucket_ssd(seq_len, n_heads, head_dim, state_dim,
                          dtype_bytes),
        workload={"seq_len": seq_len, "n_heads": n_heads,
                  "head_dim": head_dim, "state_dim": state_dim,
                  "dtype_bytes": dtype_bytes, "batch": batch},
        budget_bytes=budget,
        center={"chunk": center_c},
        candidates=fitting, rejected=rejected,
    )
    if dry:
        return result

    import jax
    import jax.numpy as jnp
    from repro.kernels.ssd_scan import ssd_scan

    dt = _dtype_of(dtype_bytes)
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(keys[0], (batch, seq_len, n_heads, head_dim), dt)
    dts = jax.nn.softplus(jax.random.normal(
        keys[1], (batch, seq_len, n_heads), jnp.float32)) * 0.5
    A = -jnp.exp(jax.random.normal(keys[2], (n_heads,), jnp.float32) * 0.3)
    Bm = jax.random.normal(keys[3], (batch, seq_len, state_dim), dt)
    Cm = jax.random.normal(keys[4], (batch, seq_len, state_dim), dt)

    def make_fn(cand: Candidate):
        c = cand.block["chunk"]
        f = jax.jit(lambda *args: ssd_scan(*args, chunk=c,
                                           interpret=interpret))
        return lambda: f(x, dts.astype(dt), A, Bm, Cm)

    return _finish(result, spec, dry, make_fn, warmup, iters,
                   result.workload)


# ---------------------------------------------------------------------------
# Orchestration (the repro-tune CLI and benchmarks/run.py drive this)
# ---------------------------------------------------------------------------

#: Kernel name -> sweep function; the order is the CLI's report order.
SWEEPS = {
    "matmul_cc": sweep_matmul,
    "flash_attention": sweep_attention,
    "paged_attention": sweep_paged,
    "ssd_scan": sweep_ssd,
}


def default_sweeps(quick: bool = False) -> Dict[str, Dict[str, Any]]:
    """The stock sweep workloads: serving/training-shaped but small enough
    to time in interpret mode on CPU.  Buckets are power-of-two, so these
    cover every shape in the same bucket."""
    if quick:
        return {
            "matmul_cc": {"m": 256, "k": 256, "n": 256, "dtype_bytes": 4},
            "flash_attention": {"q_len": 128, "kv_len": 128, "head_dim": 64,
                                "dtype_bytes": 4},
            "paged_attention": {"max_tokens": 64, "n_kv": 2, "group": 2,
                                "head_dim": 16, "slots": 2,
                                "dtype_bytes": 4},
            "ssd_scan": {"seq_len": 128, "n_heads": 2, "head_dim": 16,
                         "state_dim": 16, "dtype_bytes": 4},
        }
    return {
        "matmul_cc": {"m": 512, "k": 512, "n": 512, "dtype_bytes": 4},
        "flash_attention": {"q_len": 256, "kv_len": 256, "head_dim": 64,
                            "dtype_bytes": 4},
        "paged_attention": {"max_tokens": 256, "n_kv": 2, "group": 2,
                            "head_dim": 32, "slots": 4, "dtype_bytes": 4},
        "ssd_scan": {"seq_len": 256, "n_heads": 2, "head_dim": 32,
                     "state_dim": 32, "dtype_bytes": 4},
    }


def run_sweeps(kernels: Optional[Sequence[str]] = None,
               quick: bool = False, dry: bool = False,
               warmup: int = 1, iters: int = 5,
               spec: Optional[TPUSpec] = None,
               out_path: Optional[str] = None,
               write: bool = True) -> List[SweepResult]:
    """Run the stock sweeps and (unless ``dry`` or ``write=False``) merge
    the winners into the tuning artifact."""
    workloads = default_sweeps(quick)
    names = list(kernels) if kernels else list(SWEEPS)
    results = []
    for name in names:
        if name not in SWEEPS:
            raise KeyError(f"unknown kernel {name!r}; known: {list(SWEEPS)}")
        kw = dict(workloads[name])
        kw.update(dry=dry, warmup=warmup, iters=iters)
        if spec is not None:
            kw["spec"] = spec
        results.append(SWEEPS[name](**kw))
    if not dry and write:
        entries = [r.entry for r in results if r.entry is not None]
        if entries:
            record_tuned(entries, path=out_path)
    return results
