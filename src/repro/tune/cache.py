"""The tuning artifact: measured block winners the planner consults.

``experiments/tuning.json`` persists the empirical side of the paper's
thesis the same way ``experiments/calibration.json`` persists the phi_mesh
fit: the *runtime* carries the memory-hierarchy knowledge, not the caller
(Thibault et al.), and analytic decomposition plus empirical auto-tuning
beats either alone (Rasch's MDH line, PAPERS.md).  Each entry records one
sweep winner keyed by

  ``(kernel, arch, workload-shape bucket, hw fingerprint)``

where the bucket rounds every shape dim to its power-of-two ceiling (nearby
shapes share a winner) and the fingerprint pins the measurement to the
hardware it was taken on -- a cache entry measured on one machine must
never override the analytic choice on another, so a fingerprint mismatch
silently falls back to analytic.

Precedence is ``analytic < tuned``: the analytic plan is always computed
(it is the sweep center and the fallback), and a matching tuned entry
replaces only the block extents -- never the search bookkeeping (np, grid
coverage) -- and only after re-passing the same VMEM working-set filter
the planner applies to its own candidates.  Consumers record the
provenance (``source``/``tuning`` in the plan detail) so dry-plan output
shows which tiles are trusted measurements.
"""

from __future__ import annotations

import json
import os
import sys
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

__all__ = [
    "TUNING_ENV",
    "TuningEntry",
    "bucket_attention",
    "bucket_matmul",
    "bucket_paged",
    "bucket_ssd",
    "entry_key",
    "hw_fingerprint",
    "load_tuning",
    "lookup_tuned",
    "record_tuned",
    "tuning_path",
]

#: Env var overriding the tuning artifact path (tests point it at a tmp
#: file; unset, the repo-level ``experiments/tuning.json`` is used).
TUNING_ENV = "REPRO_TUNING"


def tuning_path() -> str:
    override = os.environ.get(TUNING_ENV)
    if override:
        return override
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "experiments", "tuning.json")


def hw_fingerprint() -> str:
    """``backend:device_kind`` of the device timings run on.

    The planner consults this lazily on every lookup; when jax has not
    been imported yet the plan walk must stay jax-free (``benchmarks/run.py
    --only plan`` is pure planning), so an un-initialized process gets a
    sentinel fingerprint that matches nothing and the planner falls back
    to the analytic choice -- never the wrong machine's measurements.
    """
    if "jax" in sys.modules:
        import jax

        try:
            dev = jax.devices()[0]
            return f"{jax.default_backend()}:{dev.device_kind}"
        except Exception:
            pass
    return "nojax:uninitialized"


# ---------------------------------------------------------------------------
# Workload-shape buckets
# ---------------------------------------------------------------------------


def _p2(x: int) -> int:
    x = max(1, int(x))
    return 1 << (x - 1).bit_length()


def bucket_matmul(m: int, k: int, n: int, dtype_bytes: int = 2) -> str:
    return f"m{_p2(m)}k{_p2(k)}n{_p2(n)}b{dtype_bytes}"


def bucket_attention(q_len: int, kv_len: int, head_dim: int,
                     dtype_bytes: int = 2) -> str:
    return f"q{_p2(q_len)}kv{_p2(kv_len)}d{_p2(head_dim)}b{dtype_bytes}"


def bucket_paged(tok_bytes: int, max_tokens: int) -> str:
    """Decode page search bucket: the per-shard token footprint and the
    resident-token bound are the only shape inputs of ``phi_page``."""
    return f"tok{_p2(tok_bytes)}len{_p2(max_tokens)}"


def bucket_ssd(seq_len: int, n_heads: int, head_dim: int,
               state_dim: int, dtype_bytes: int = 2) -> str:
    return (f"s{_p2(seq_len)}h{_p2(n_heads)}p{_p2(head_dim)}"
            f"n{_p2(state_dim)}b{dtype_bytes}")


# ---------------------------------------------------------------------------
# Entries
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TuningEntry:
    """One sweep winner.

    ``block`` holds the kernel-specific tuned extents (``bm/bk/bn`` for
    matmul, ``block_q/block_kv`` for attention, ``page_tokens`` for paged,
    ``chunk`` for ssd); ``analytic_block`` the sweep center it perturbed;
    ``median_us``/``analytic_us`` the measured medians and ``speedup``
    their ratio (> 1 means the tuned block beat the analytic center).
    """

    kernel: str
    arch: str
    bucket: str
    fingerprint: str
    block: Mapping[str, int]
    analytic_block: Mapping[str, int] = field(default_factory=dict)
    median_us: float = 0.0
    analytic_us: float = 0.0
    speedup: float = 1.0
    workload: Mapping[str, Any] = field(default_factory=dict)

    @property
    def key(self) -> str:
        return entry_key(self.kernel, self.arch, self.bucket,
                         self.fingerprint)


def entry_key(kernel: str, arch: str, bucket: str, fingerprint: str) -> str:
    return f"{kernel}|{arch}|{bucket}|{fingerprint}"


#: path -> ((mtime_ns, size) | None, parsed entries) -- stat-keyed like the
#: calibration cache so a rewrite (a sweep running in-process) is picked up
#: without manual invalidation.
_TUNE_CACHE: Dict[str, Tuple[Optional[Tuple[int, int]],
                             Dict[str, Dict[str, Any]]]] = {}

_ENTRY_FIELDS = ("kernel", "arch", "bucket", "fingerprint", "block",
                 "analytic_block", "median_us", "analytic_us", "speedup",
                 "workload")


def load_tuning(path: Optional[str] = None) -> Dict[str, Dict[str, Any]]:
    """``{key: entry-dict}`` from the tuning artifact (empty on any read or
    parse problem -- tuning is advisory, never a hard dep)."""
    path = path or tuning_path()
    try:
        st = os.stat(path)
        sig: Optional[Tuple[int, int]] = (st.st_mtime_ns, st.st_size)
    except OSError:
        sig = None
    cached = _TUNE_CACHE.get(path)
    if cached is not None and cached[0] == sig:
        return cached[1]
    out: Dict[str, Dict[str, Any]] = {}
    if sig is not None:
        try:
            with open(path) as f:
                data = json.load(f)
            entries = data.get("entries", {})
            if isinstance(entries, dict):
                for key, e in entries.items():
                    if isinstance(e, dict) and isinstance(
                            e.get("block"), dict):
                        out[key] = e
        except (OSError, ValueError):
            out = {}
    _TUNE_CACHE[path] = (sig, out)
    return out


def lookup_tuned(kernel: str, arch: str, bucket: str,
                 fingerprint: Optional[str] = None,
                 path: Optional[str] = None) -> Optional[Dict[str, Any]]:
    """The tuned entry for one (kernel, arch, bucket) on THIS hardware, or
    None (unknown key, fingerprint mismatch, missing artifact -- every miss
    means the analytic choice stands)."""
    fp = fingerprint if fingerprint is not None else hw_fingerprint()
    return load_tuning(path).get(entry_key(kernel, arch, bucket, fp))


def record_tuned(entries: List[TuningEntry],
                 path: Optional[str] = None) -> str:
    """Merge sweep winners into the artifact (existing entries for other
    keys survive -- the artifact accumulates across partial sweeps, like
    ``write_calibration``)."""
    path = path or tuning_path()
    existing: Dict[str, Any] = {}
    try:
        with open(path) as f:
            existing = json.load(f)
    except (OSError, ValueError):
        existing = {}
    merged = existing.get("entries")
    if not isinstance(merged, dict):
        merged = {}
    for e in entries:
        d = asdict(e)
        merged[e.key] = {f: d[f] for f in _ENTRY_FIELDS}
    out = {
        "_meta": {
            "source": "repro.tune.sweep (repro-tune / launch/tune.py)",
            "note": "block winners of the neighborhood sweep around the "
                    "planner's analytic tiles; consulted by "
                    "core.plan/_plan_tile_level, core.autotile."
                    "plan_attention, core.plan/_plan_page_level and "
                    "models.mamba2.choose_chunk when (kernel, arch, "
                    "bucket, fingerprint) matches; precedence "
                    "analytic < tuned (DESIGN.md §9)",
        },
        "entries": {k: merged[k] for k in sorted(merged)},
    }
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return path
