"""Prefill/decode disaggregation over the DCN level (DESIGN.md §12).

Prefill-role replicas run chunked prefill into pool pages (a one-token
``generate`` -- publishing the prompt's completed pages into the radix
tree IS the export path, no second code path), then stream those pages
to a decode-role replica as serialized page payloads plus the
page-boundary state snapshots the state families need.  The transfer
SCHEDULE reuses the ring machinery serving already trusts: page ``s``
of the chain moves at the step ``dist.overlap.plan_ring`` would stream
chunk ``s`` -- serpentine mode interleaves the chain from both ends
(both DCN directions carrying half each), ring mode streams it in
order.  Admission to decode is gated on the LAST page's arrival:
``PageStreamReceiver.payloads`` refuses an incomplete chain, so a
decode replica never prefills against a half-installed prefix.

Token identity holds under GREEDY sampling (the default): stochastic
sampling draws from per-engine step counters, which disaggregation by
construction splits across two engines.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.cluster.router import Router
from repro.cluster.worker import Replica


@dataclass
class KVTransfer:
    """One prompt's serialized KV pages in flight.

    ``payloads`` is in LOGICAL chain order (payload ``j`` covers tokens
    ``[j*page_tokens, (j+1)*page_tokens)``); ``order`` is the ring-plan
    transfer schedule over those indices.  ``snaps`` maps page-boundary
    token counts to recurrent-state snapshots (state families)."""

    rid: int
    tokens: List[int]
    page_tokens: int
    payloads: List[Dict[str, Any]]
    order: List[int]
    snaps: Dict[int, Any] = field(default_factory=dict)
    mode: str = "serpentine"
    first_token: Optional[int] = None

    @property
    def n_pages(self) -> int:
        return len(self.payloads)


def transfer_order(n_pages: int, mode: str = "serpentine") -> List[int]:
    """The page-transfer schedule from the ring plan: step ``s`` of a
    ``p``-way ring streams the chunk(s) ``plan_ring`` says rank 0
    consumes at step ``s`` -- one index per step in "ring" mode, the
    forward/backward pair in "serpentine" (the bidirectional-DCN
    interleave).  Every page appears exactly once."""
    if n_pages <= 1:
        return list(range(n_pages))
    from repro.dist.overlap import plan_ring

    rp = plan_ring(n_pages, mode)
    order: List[int] = []
    seen = set()
    for s in range(rp.p):
        steps = (rp.fwd_offsets[s],) if rp.bwd_offsets is None else \
            (rp.fwd_offsets[s], rp.bwd_offsets[s])
        for ix in steps:
            ix = int(ix) % n_pages
            if ix not in seen:
                seen.add(ix)
                order.append(ix)
    return order


class PageStreamReceiver:
    """Decode-side reassembly buffer: pages arrive in transfer order,
    admission unlocks only when the whole chain is resident."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._got: Dict[int, Dict[str, Any]] = {}

    def receive(self, index: int, payload: Dict[str, Any]) -> None:
        if not 0 <= index < self.n_pages:
            raise IndexError(f"page index {index} outside chain of "
                             f"{self.n_pages}")
        self._got[index] = payload

    @property
    def complete(self) -> bool:
        return len(self._got) == self.n_pages

    def payloads(self) -> List[Dict[str, Any]]:
        """The chain in logical order -- the admission gate: raises while
        any page (in particular the last-scheduled one) is missing."""
        if not self.complete:
            missing = sorted(set(range(self.n_pages)) - set(self._got))
            raise RuntimeError(
                f"admission gated on page arrival: missing {missing} "
                f"of {self.n_pages}")
        return [self._got[i] for i in range(self.n_pages)]


# ---------------------------------------------------------------------------
# Transfer endpoints (front-side, over the Replica instruction queue)
# ---------------------------------------------------------------------------


def export_transfer(prefill: Replica, tokens, rid: int = 0,
                    mode: str = "serpentine") -> KVTransfer:
    """Run prefill for ``tokens`` on a prefill-role replica and package
    its completed pages.  The one-token generate is the prefill: chunked
    prefill writes the prompt into pool pages and the radix tree keeps
    them resident, so export is a tree lookup, not a copy out of a live
    slot."""
    toks = np.asarray(tokens, dtype=np.int32).reshape(-1)
    out = prefill.generate([toks.tolist()], 1).wait()
    first = out[0][0] if out and out[0] else None
    exp = prefill.submit("export", toks.tolist()).wait()
    if exp is None or not exp["pages"]:
        raise RuntimeError(
            "prefill replica cached no pages for this prompt (prefix "
            "cache off, family not prefix-cacheable, or prompt shorter "
            "than one page)")
    return KVTransfer(
        rid=rid, tokens=list(exp["tokens"]),
        page_tokens=int(exp["page_tokens"]), payloads=list(exp["pages"]),
        order=transfer_order(len(exp["pages"]), mode),
        snaps=dict(exp["snaps"] or {}), mode=mode, first_token=first)


def import_transfer(decode: Replica, transfer: KVTransfer) -> int:
    """Stream ``transfer``'s pages to a decode-role replica in ring
    order and install them once the LAST page lands.  Returns the number
    of prompt tokens now resident on the decode side."""
    recv = PageStreamReceiver(transfer.n_pages)
    for ix in transfer.order:
        recv.receive(ix, transfer.payloads[ix])
    payloads = recv.payloads()          # the admission gate
    return decode.submit(
        "import", (transfer.tokens, payloads, transfer.snaps)).wait()


class DisaggCluster:
    """P prefill-role + D decode-role replicas: prompts prefill on the P
    side, their pages stream across, and decode admits against a local
    radix hit covering the whole transferred prefix."""

    def __init__(self, prefill: List[Replica], decode: List[Replica],
                 router: Optional[Router] = None, page_tokens: int = 0,
                 mode: str = "serpentine"):
        if not prefill or not decode:
            raise ValueError("disaggregation needs >=1 prefill and >=1 "
                             "decode replica")
        self.prefill = prefill
        self.decode = decode
        self.mode = mode
        self.router = router or Router(len(decode), policy="free_pages",
                                       page_tokens=page_tokens)
        self._rr = 0
        self._rid = 0

    @classmethod
    def from_plan(cls, plan, factory, split: str = "1:1",
                  transport: str = "thread", policy: str = "free_pages",
                  mode: str = "serpentine") -> "DisaggCluster":
        """Split the plan's fleet into prefill:decode roles.  ``split``
        is "P:D"; P+D must equal ``plan.replicas()`` -- the role split
        partitions the planned fleet, it does not grow it."""
        p, d = (int(x) for x in split.split(":"))
        n = plan.replicas()
        if p + d != n or p < 1 or d < 1:
            raise ValueError(f"--disagg {split} does not partition the "
                             f"planned fleet of {n} replicas")
        from repro.cluster.router import plan_stats

        page = plan.page_plan() or {}
        prefill = [Replica(factory, replica=i, role="prefill",
                           transport=transport,
                           default_stats=plan_stats(plan, i, "prefill"))
                   for i in range(p)]
        decode = [Replica(factory, replica=p + i, role="decode",
                          transport=transport,
                          default_stats=plan_stats(plan, p + i, "decode"))
                  for i in range(d)]
        router = Router(d, policy=policy,
                        page_tokens=int(page.get("page_tokens") or 0))
        return cls(prefill, decode, router=router, mode=mode)

    def stats(self):
        return [r.stats() for r in self.prefill + self.decode]

    def generate(self, tokens, max_new_tokens: int = 16,
                 on_token=None) -> List[int]:
        """One request end to end: prefill -> page stream -> routed
        decode.  The decode replica re-submits the FULL prompt; its radix
        tree already holds the transferred pages, so prefill there covers
        only the sub-page tail."""
        pre = self.prefill[self._rr % len(self.prefill)]
        self._rr += 1
        self._rid += 1
        transfer = export_transfer(pre, tokens, rid=self._rid,
                                   mode=self.mode)
        by = {s.replica: s for s in
              (r.stats() for r in self.decode)}
        stats = []
        for j, rep in enumerate(self.decode):
            st = by[rep.replica]
            st.replica = j              # router indexes decode-side slots
            stats.append(st)
        j = self.router.route(stats, tokens=tokens)
        import_transfer(self.decode[j], transfer)
        out = self.decode[j].generate(
            [np.asarray(tokens).reshape(-1).tolist()], max_new_tokens,
            on_token=on_token).wait()
        return out[0] if out else []

    def close(self) -> None:
        for rep in self.prefill + self.decode:
            rep.close()
