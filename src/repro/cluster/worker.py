"""The replica host: one worker (spawned process or thread) per
`ServeEngine`, driven by an instruction queue (DESIGN.md §12).

The orchestration shape follows Mithril's ``TorchParallel``: the front
side never touches the engine directly -- it enqueues ``(seq, op,
payload)`` instructions and a per-replica worker loop executes them
against ONE engine built lazily in the worker and cached for the
worker's lifetime (the expensive part -- mesh, params, jit caches --
is paid once per process, not per request).  Every reply is tagged with
the instruction's ``seq`` so one response queue can carry interleaved
token streams, results and errors; after each instruction the worker
pushes an unsolicited ``ReplicaStats`` tick (``seq == _TICK``) so the
router sees the replica's pool pressure without a round trip.

Two transports share the loop verbatim:

  * ``"proc"`` -- a ``multiprocessing`` *spawn* context worker with a
    ``ctx.Queue`` pair.  The factory must be picklable (``EngineSpec`` /
    ``StubSpec``); this is the production shape, one JAX runtime per
    replica.
  * ``"thread"`` -- a daemon thread with ``queue.Queue``s in-process.
    Same protocol, no pickling, and the live engine is reachable for
    LIVE telemetry (``Replica.stats`` reads ``engine.stats()`` directly,
    so a replica's free-page count moves WHILE a request is resident --
    what the ``free_pages`` routing policy keys on).  Tests and the
    single-host benchmark use this.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field, fields, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: seq id of unsolicited telemetry pushes (never a real instruction).
_TICK = -1

TRANSPORTS = ("thread", "proc")


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------


@dataclass
class ReplicaStats:
    """One replica's telemetry tick -- the router's entire world view.

    ``free_pages``/``slots_free`` come from ``engine.stats()`` (the live
    pool when one exists); ``queued``/``active`` are FRONT-side facts
    (instructions enqueued but unfinished) filled in by ``Replica.stats``
    -- the worker cannot see its own backlog.  ``drained`` is a router
    verdict, stamped by ``ServeCluster.stats``."""

    replica: int = 0
    role: str = "serve"                 # | "prefill" | "decode"
    free_pages: int = 0
    used_pages: int = 0
    pages_total: int = 0
    slots_free: int = 0
    slots_total: int = 0
    page_tokens: int = 0
    prefix_nodes: int = 0
    prefix_pages: int = 0
    prefix_resident_bytes: int = 0
    queued: int = 0
    active: int = 0
    tokens: int = 0
    ticks: int = 0
    drained: bool = False
    #: Full registry snapshot (DESIGN.md §13): every counter/gauge plus
    #: flattened histogram quantiles, forwarded on the existing stats
    #: tick -- so the router (and ``GET /metrics``) reads the very gauge
    #: the replica's page pool writes, not a reconstruction.  Stub
    #: engines (no registry) leave it empty.
    metrics: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_engine(cls, engine, replica: int, role: str = "serve",
                    ticks: int = 0) -> "ReplicaStats":
        s = engine.stats()
        keep = {f.name for f in fields(cls)}
        snap: Dict[str, Any] = {}
        obs = getattr(engine, "obs", None)
        if obs is not None:
            try:
                snap = obs.snapshot()
            except Exception:                       # noqa: BLE001
                snap = {}
        return cls(replica=replica, role=role, ticks=ticks, metrics=snap,
                   **{k: v for k, v in s.items() if k in keep})


# ---------------------------------------------------------------------------
# Picklable engine factories (the spawn transport ships these, not engines)
# ---------------------------------------------------------------------------

#: Engines built in THIS process, keyed by (spec, replica): the spawn
#: worker builds its engine once and every later instruction reuses it;
#: the thread transport keys by replica so co-resident replicas get
#: INDEPENDENT pools (the whole point of the cluster).
_ENGINE_CACHE: Dict[Any, Any] = {}


@dataclass(frozen=True)
class EngineSpec:
    """A picklable ``ServeEngine`` recipe: everything the worker needs to
    rebuild the engine on its side of the spawn.  ``chip`` is a tuple of
    ``chip_spec`` override items (tests shrink VMEM with it)."""

    arch: str = "llama3.2-1b"
    reduced: bool = True
    max_new_tokens: int = 16
    max_slots: int = 1
    max_len: int = 256
    batching: str = "paged"
    prefill: str = "chunked"
    prefix_cache: str = "radix"
    kv_budget_bytes: Optional[int] = None
    seed: int = 0
    chip: Tuple[Tuple[str, Any], ...] = ()

    def __call__(self, replica: int = 0):
        key = (self, replica)
        eng = _ENGINE_CACHE.get(key)
        if eng is None:
            from repro.configs.base import get_model_config
            from repro.hw.tpu import chip_spec
            from repro.launch.mesh import make_host_mesh
            from repro.serve.engine import ServeEngine, ServePolicy

            cfg = get_model_config(self.arch)
            if self.reduced:
                cfg = cfg.reduced()
            eng = ServeEngine(
                cfg, make_host_mesh(),
                policy=ServePolicy(
                    max_new_tokens=self.max_new_tokens,
                    max_slots=self.max_slots, max_len=self.max_len,
                    batching=self.batching, prefill=self.prefill,
                    prefix_cache=self.prefix_cache,
                    kv_budget_bytes=self.kv_budget_bytes),
                seed=self.seed,
                spec=chip_spec(**dict(self.chip)),
                replica=replica)
            _ENGINE_CACHE[key] = eng
        return eng


class _StubEngine:
    """Deterministic engine double: token ``i`` of a prompt is
    ``(sum(prompt) + i) % 997``, with an optional per-token delay so
    tests can hold a replica busy.  Implements exactly the surface the
    worker loop drives (``generate``/``stats``/``export_pages``/
    ``import_pages``)."""

    def __init__(self, spec: "StubSpec", replica: int = 0):
        self.spec = spec
        self.replica = replica
        self._tokens = 0
        self._busy = 0

    def generate(self, prompts, max_new_tokens=16, on_token=None):
        max_new = max_new_tokens
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        outs = []
        self._busy += 1
        try:
            for i, p in enumerate(prompts):
                base = int(sum(int(x) for x in np.asarray(p).reshape(-1)))
                toks = []
                for step in range(int(max_new[i])):
                    if self.spec.delay_s:
                        time.sleep(self.spec.delay_s)
                    t = (base + step) % 997
                    toks.append(t)
                    self._tokens += 1
                    if on_token is not None:
                        on_token(i, t)
                outs.append(toks)
        finally:
            self._busy -= 1
        return outs

    def stats(self) -> Dict[str, Any]:
        used = self._busy * self.spec.pages_per_request
        return {
            "batching": "paged",
            "free_pages": max(0, self.spec.pages_total - used),
            "used_pages": used,
            "pages_total": self.spec.pages_total,
            "slots_free": max(0, self.spec.slots_total - self._busy),
            "slots_total": self.spec.slots_total,
            "page_tokens": self.spec.page_tokens,
            "page_bytes": 0,
            "kv_shard": 1,
            "tokens": self._tokens,
            "decode_steps": self._tokens,
            "prefill_chunks": 0,
            "prefix_nodes": 0,
            "prefix_pages": 0,
            "prefix_resident_bytes": 0,
        }

    def export_pages(self, tokens):
        return None

    def import_pages(self, tokens, payloads, snaps=None, n_slots=1):
        return 0


@dataclass(frozen=True)
class StubSpec:
    """Picklable factory for ``_StubEngine`` (protocol / HTTP / router
    tests: no JAX, deterministic tokens, controllable latency)."""

    pages_total: int = 64
    slots_total: int = 4
    page_tokens: int = 8
    pages_per_request: int = 8
    delay_s: float = 0.0

    def __call__(self, replica: int = 0) -> _StubEngine:
        return _StubEngine(self, replica)


# ---------------------------------------------------------------------------
# The worker loop (both transports run THIS, verbatim)
# ---------------------------------------------------------------------------


def _serve_loop(recv: Callable[[], Any], send: Callable[[Any], None],
                factory, replica: int, role: str) -> None:
    """Drain ``(seq, op, payload)`` instructions against one lazily-built
    engine.  Ops: ``generate`` (streams ``(seq, "token", (i, tok))``
    before the final result), ``export`` / ``import`` (disaggregation
    page hooks), ``stats``, ``shutdown``.  Any exception becomes a
    ``(seq, "err", msg)`` reply -- the worker never dies on a bad
    request.  After every instruction one unsolicited ``(_TICK,
    "stats", ReplicaStats)`` tick is pushed."""
    engine = None
    ticks = 0
    while True:
        seq, op, payload = recv()
        if op == "shutdown":
            send((seq, "ok", None))
            return
        send((seq, "begin", None))
        try:
            if engine is None:
                engine = factory(replica)
            if op == "generate":
                prompts, max_new = payload

                def cb(i, tok, _seq=seq):
                    send((_seq, "token", (i, tok)))

                result = engine.generate(prompts, max_new_tokens=max_new,
                                         on_token=cb)
            elif op == "export":
                result = engine.export_pages(payload)
            elif op == "import":
                tokens, payloads, snaps = payload
                result = engine.import_pages(tokens, payloads, snaps=snaps)
            elif op == "stats":
                result = engine.stats()
            elif op == "trace":
                tracer = getattr(engine, "tracer", None)
                result = (tracer.chrome_events(payload)
                          if tracer is not None else [])
            else:
                raise ValueError(f"unknown op {op!r}")
            send((seq, "ok", result))
        except Exception as e:                      # noqa: BLE001
            send((seq, "err", f"{type(e).__name__}: {e}"))
        ticks += 1
        if engine is not None:
            try:
                send((_TICK, "stats",
                      ReplicaStats.from_engine(engine, replica, role,
                                               ticks=ticks)))
            except Exception:                       # noqa: BLE001
                pass


def _proc_main(inq, outq, factory, replica: int, role: str) -> None:
    _serve_loop(inq.get, outq.put, factory, replica, role)


# ---------------------------------------------------------------------------
# Front side
# ---------------------------------------------------------------------------


class _Call:
    """One in-flight instruction: a future plus its streaming hooks."""

    def __init__(self, seq: int, op: str, payload: Any,
                 on_token=None, on_done=None):
        self.seq = seq
        self.op = op
        self.payload = payload
        self.on_token = on_token
        self.on_done = on_done
        self.t_submit = time.monotonic()
        self.first_token_time: Optional[float] = None
        self.started = False
        self.result: Any = None
        self.err: Optional[str] = None
        self._ev = threading.Event()

    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: Optional[float] = 60.0):
        if not self._ev.wait(timeout):
            raise TimeoutError(f"call {self.op}#{self.seq} timed out")
        if self.err is not None:
            raise RuntimeError(self.err)
        return self.result

    def _finish(self, result=None, err=None) -> None:
        self.result = result
        self.err = err
        self._ev.set()
        if self.on_done is not None:
            try:
                self.on_done(self)
            except Exception:                       # noqa: BLE001
                pass


class Replica:
    """Front-side handle to one replica host.

    ``submit`` enqueues an instruction and returns a ``_Call``; a demux
    pump thread routes the shared response queue's messages back to their
    calls (token streams fire ``on_token(i, tok)`` as they arrive --
    ``tok is None`` is a stream reset after a recompute preemption).
    ``cancel_pending`` abandons instructions the worker has not BEGUN
    (drain/requeue): the worker may still execute them later, but their
    replies are discarded -- wasted compute, never wrong results."""

    def __init__(self, factory, replica: int = 0, role: str = "serve",
                 transport: str = "thread",
                 default_stats: Optional[ReplicaStats] = None):
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}; "
                             f"one of {TRANSPORTS}")
        self.replica = replica
        self.role = role
        self.transport = transport
        self.engine = None              # thread transport: live telemetry
        self.last_stats: Optional[ReplicaStats] = None
        #: What a replica that has never served advertises -- the PLAN's
        #: pool geometry (whole pool free), so the ``free_pages`` policy
        #: spreads onto fresh replicas instead of starving them at the
        #: zero-telemetry default.
        self.default_stats = default_stats
        self._seq = 0
        self._calls: Dict[int, _Call] = {}
        self._lock = threading.Lock()
        self._closed = False
        if transport == "thread":
            self._inq: Any = queue.Queue()
            self._outq: Any = queue.Queue()

            def _build(rep):
                eng = factory(rep)
                self.engine = eng
                return eng

            self._worker: Any = threading.Thread(
                target=_serve_loop,
                args=(self._inq.get, self._outq.put, _build, replica, role),
                name=f"replica-{replica}", daemon=True)
        else:
            import multiprocessing as mp

            ctx = mp.get_context("spawn")
            self._inq = ctx.Queue()
            self._outq = ctx.Queue()
            self._worker = ctx.Process(
                target=_proc_main,
                args=(self._inq, self._outq, factory, replica, role),
                daemon=True)
        self._worker.start()
        self._pump = threading.Thread(target=self._demux,
                                      name=f"replica-{replica}-demux",
                                      daemon=True)
        self._pump.start()

    # ------------------------------------------------------------- demux
    def _demux(self) -> None:
        while True:
            try:
                msg = self._outq.get(timeout=0.1)
            except queue.Empty:
                if self._closed and not self._calls:
                    return
                continue
            except (EOFError, OSError):
                return
            seq, kind, payload = msg
            if seq == _TICK:
                self.last_stats = payload
                continue
            with self._lock:
                call = self._calls.get(seq)
            if call is None:
                continue                 # cancelled: discard the reply
            if kind == "begin":
                call.started = True
            elif kind == "token":
                i, tok = payload
                if tok is None:
                    call.first_token_time = None    # preempted: re-emits
                elif call.first_token_time is None:
                    call.first_token_time = time.monotonic()
                if call.on_token is not None:
                    try:
                        call.on_token(i, tok)
                    except Exception:               # noqa: BLE001
                        pass
            else:
                with self._lock:
                    self._calls.pop(seq, None)
                call._finish(result=payload if kind == "ok" else None,
                             err=payload if kind == "err" else None)

    # ----------------------------------------------------------- submits
    def submit(self, op: str, payload: Any, on_token=None,
               on_done=None) -> _Call:
        if self._closed:
            raise RuntimeError(f"replica {self.replica} is closed")
        with self._lock:
            seq = self._seq
            self._seq += 1
            call = _Call(seq, op, payload, on_token=on_token,
                         on_done=on_done)
            self._calls[seq] = call
        self._inq.put((seq, op, payload))
        return call

    def generate(self, prompts: Sequence[Any], max_new_tokens=16,
                 on_token=None, on_done=None) -> _Call:
        prompts = [np.asarray(p).tolist() if isinstance(p, np.ndarray)
                   else p for p in prompts]
        return self.submit("generate", (prompts, max_new_tokens),
                           on_token=on_token, on_done=on_done)

    # --------------------------------------------------------- telemetry
    def _load(self) -> Tuple[int, int]:
        with self._lock:
            gen = [c for c in self._calls.values() if c.op == "generate"]
        active = sum(1 for c in gen if c.started)
        return len(gen) - active, active

    def stats(self) -> ReplicaStats:
        """Latest telemetry, preferring the LIVE engine (thread
        transport) so mid-generate pool pressure is visible; the spawn
        transport sees the last tick.  ``queued``/``active`` always come
        from this side's books."""
        st = None
        if self.engine is not None:
            try:
                st = ReplicaStats.from_engine(self.engine, self.replica,
                                              self.role)
            except Exception:                       # noqa: BLE001
                st = None
        if st is None:
            base = self.last_stats or self.default_stats
            st = (replace(base) if base is not None
                  else ReplicaStats(replica=self.replica, role=self.role))
        st.replica = self.replica
        st.role = self.role
        st.queued, st.active = self._load()
        return st

    def trace(self, last: Optional[int] = None) -> List[Dict[str, Any]]:
        """This replica's Chrome trace events (pid = replica id).  The
        thread transport reads the live tracer; the spawn transport
        round-trips a ``trace`` instruction.  Engines without a tracer
        (stubs) yield []."""
        if self.engine is not None:
            tracer = getattr(self.engine, "tracer", None)
            return tracer.chrome_events(last) if tracer is not None else []
        try:
            return self.submit("trace", last).wait() or []
        except Exception:                           # noqa: BLE001
            return []

    # -------------------------------------------------------------- drain
    def pending(self) -> List[_Call]:
        """Generate calls enqueued but not yet begun by the worker."""
        with self._lock:
            return [c for c in self._calls.values()
                    if c.op == "generate" and not c.started
                    and not c.done()]

    def cancel_pending(self) -> List[_Call]:
        """Abandon every not-yet-begun generate call (drain/requeue).
        Returns the abandoned calls so the router can resubmit their
        payloads elsewhere; late replies from this replica are ignored."""
        cancelled = []
        with self._lock:
            for seq, call in list(self._calls.items()):
                if call.op == "generate" and not call.started \
                        and not call.done():
                    del self._calls[seq]
                    cancelled.append(call)
        return cancelled

    def close(self, timeout: float = 10.0) -> None:
        if self._closed:
            return
        try:
            self.submit("shutdown", None)
        except RuntimeError:
            pass
        self._closed = True
        self._worker.join(timeout)
        if self.transport == "proc" and self._worker.is_alive():
            self._worker.terminate()
