"""Multi-replica serving over the DCN level (DESIGN.md §12).

The planner's outermost consumer: ``plan_decode(cfg, mesh, cluster=N)``
grows a DCN level whose realized ``np`` is the fleet width, each replica
hosts one single-host ``ServeEngine`` (the plan's ICI/VMEM subtree),
and the router places each request by the memory-aware ``free_pages``
policy (Silva et al.) with prefix-affinity.  ``disagg`` splits the
fleet into prefill and decode roles with ring-ordered KV page
streaming between them; ``http`` is the stdlib streaming front end.
"""

from repro.cluster.disagg import (DisaggCluster, KVTransfer,
                                  PageStreamReceiver, export_transfer,
                                  import_transfer, transfer_order)
from repro.cluster.http import ClusterServer
from repro.cluster.router import (POLICIES, ClusterRequest, Router,
                                  ServeCluster)
from repro.cluster.worker import (EngineSpec, Replica, ReplicaStats,
                                  StubSpec)

__all__ = [
    "POLICIES",
    "ClusterRequest",
    "ClusterServer",
    "DisaggCluster",
    "EngineSpec",
    "KVTransfer",
    "PageStreamReceiver",
    "Replica",
    "ReplicaStats",
    "Router",
    "ServeCluster",
    "StubSpec",
    "export_transfer",
    "import_transfer",
    "transfer_order",
]
