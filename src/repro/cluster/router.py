"""Request placement over the replica fleet (DESIGN.md §12).

Three pluggable policies, all reading the same ``ReplicaStats`` ticks:

  * ``round_robin`` -- cycle the admissible replicas (the baseline the
    benchmark A/Bs against).
  * ``least_loaded`` -- fewest outstanding requests (``queued +
    active``), ties to the lowest replica id.
  * ``free_pages`` -- the headline memory-aware policy: admit to the
    replica whose page pool has the MOST free pages, ties to the lowest
    replica id.  This is Silva et al.'s branch-and-bound result (load
    balance by *available memory*, not work count) applied at the DCN
    level: a replica holding a long prompt's pages reports low
    ``free_pages`` while the work-count view still says "one request",
    so memory-skewed workloads route around it.

Prefix AFFINITY is orthogonal to the policy: the request's leading
page-aligned tokens are hashed, and a prefix that already landed
somewhere goes back to that replica (its radix tree holds the pages --
a cross-replica miss would re-prefill the whole shared prompt).  The
policy decides only the FIRST placement of each prefix.

Drained replicas are never admitted.  A ``StragglerPolicy``
(``ft/resilience.py``) can drive draining from routed-request latency:
``note_latency`` feeds per-replica TTFT samples, ``sweep_stragglers``
drains the median+k*MAD outliers, and ``undrain`` forgets a replica's
history so re-admission starts from fresh samples.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.worker import Replica, ReplicaStats

POLICIES = ("round_robin", "least_loaded", "free_pages")


def plan_stats(plan, replica: int, role: str = "serve") -> ReplicaStats:
    """A fresh replica's advertised telemetry: the PLAN's pool geometry
    with the whole pool free.  Until a replica's first tick arrives this
    is what the router sees, so the ``free_pages`` policy spreads onto
    never-used replicas instead of starving them behind a served one."""
    ptab = dict(plan.page_table() or {})
    page = dict(plan.page_plan() or {})
    total = int(ptab.get("pages_total") or 0)
    return ReplicaStats(replica=replica, role=role, free_pages=total,
                        pages_total=total,
                        page_tokens=int(page.get("page_tokens") or 0))


class Router:
    """Stateless-per-request placement over ``ReplicaStats`` snapshots
    (the affinity map and round-robin cursor are the only state)."""

    def __init__(self, n: int, policy: str = "free_pages",
                 page_tokens: int = 0, affinity: bool = True,
                 straggler=None, obs=None, tracer=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.n = n
        self.policy = policy
        self.page_tokens = page_tokens
        self.affinity = affinity
        self.straggler = straggler
        self.drained: set = set()
        self._rr = 0
        self._prefix_home: Dict[int, int] = {}
        # Observability (DESIGN.md §13): placement decisions count into
        # the registry and land in the trace (the router's tracer uses
        # its own pid, so a merged cluster trace shows who sent each
        # request where alongside the replicas serving them).
        self.obs = obs
        self.tracer = tracer

    # --------------------------------------------------------- placement
    def _prefix_key(self, tokens) -> Optional[int]:
        t = self.page_tokens
        if not self.affinity or not t or tokens is None:
            return None
        toks = np.asarray(tokens).reshape(-1)
        blocks = int(toks.shape[0]) // t
        if blocks <= 0:
            return None
        return hash(tuple(int(x) for x in toks[:blocks * t]))

    def route(self, stats: Sequence[ReplicaStats], tokens=None) -> int:
        """Pick the replica id for one request.  ``stats`` is one
        ``ReplicaStats`` per replica (any order); ``tokens`` enables
        prefix affinity."""
        by = {s.replica: s for s in stats}
        live = [i for i in sorted(by)
                if i not in self.drained and not by[i].drained]
        if not live:
            raise RuntimeError("no admissible replicas (all drained)")
        key = self._prefix_key(tokens)
        if key is not None:
            home = self._prefix_home.get(key)
            if home in live:
                self._record(home, by, affinity=True)
                return home
        if self.policy == "round_robin":
            pick = live[self._rr % len(live)]
            self._rr += 1
        elif self.policy == "least_loaded":
            pick = min(live, key=lambda i: (by[i].queued + by[i].active, i))
        else:                                       # free_pages
            # Memory first; outstanding load breaks free-page ties (an
            # instant burst arrives before any pool telemetry can move),
            # then the lowest replica id -- fully deterministic.
            pick = max(live, key=lambda i: (
                by[i].free_pages, -(by[i].queued + by[i].active), -i))
        if key is not None:
            self._prefix_home[key] = pick
        self._record(pick, by, affinity=False)
        return pick

    def _record(self, pick: int, by, affinity: bool) -> None:
        if self.obs is not None:
            self.obs.inc("route_decisions")
            if affinity:
                self.obs.inc("route_affinity_hits")
        if self.tracer is not None:
            self.tracer.instant(
                "route",
                args={"pick": pick, "policy": self.policy,
                      "affinity": affinity,
                      "free_pages": by[pick].free_pages})

    # ----------------------------------------------------- drain lifecycle
    def drain(self, replica: int) -> None:
        self.drained.add(replica)

    def undrain(self, replica: int) -> None:
        self.drained.discard(replica)
        if self.straggler is not None:
            self.straggler.forget(replica)

    def note_latency(self, replica: int, seconds: float) -> None:
        if self.straggler is not None:
            self.straggler.record(replica, seconds)

    def sweep_stragglers(self) -> List[int]:
        """Drain every replica the straggler detector flags; returns the
        NEWLY drained ids."""
        if self.straggler is None:
            return []
        fresh = [h for h in self.straggler.stragglers()
                 if h not in self.drained]
        for h in fresh:
            self.drain(h)
        return fresh


# ---------------------------------------------------------------------------
# The cluster front: N replicas behind one router
# ---------------------------------------------------------------------------


class ClusterRequest:
    """One routed request: where it landed, its streaming call, and the
    TTFT clock (measured from SUBMISSION, so a drain/requeue's wait on
    the first replica still counts against it)."""

    def __init__(self, rid: int, tokens, max_new: int, on_token=None):
        self.rid = rid
        self.tokens = tokens
        self.max_new = max_new
        self.on_token = on_token
        self.replica: Optional[int] = None
        self.call = None
        self.t_submit = time.monotonic()

    def done(self) -> bool:
        return self.call is not None and self.call.done()

    def result(self, timeout: Optional[float] = 60.0) -> List[int]:
        out = self.call.wait(timeout)
        return out[0] if out else []

    def ttft(self) -> Optional[float]:
        t = self.call.first_token_time if self.call is not None else None
        return None if t is None else t - self.t_submit


class ServeCluster:
    """N ``Replica`` hosts behind one ``Router`` -- the planner's
    outermost consumer.  ``from_plan`` reads the fleet width straight off
    the decode plan's DCN level (``plan.replicas()``), so the cluster
    realizes the run-time's placement decision rather than a config
    file's."""

    def __init__(self, replicas: List[Replica], router: Router):
        from repro.obs import Registry, Tracer

        self.replicas = replicas
        self.router = router
        self._lock = threading.Lock()
        self._next_rid = 0
        self._inflight: List[ClusterRequest] = []
        # Front-side observability (DESIGN.md §13): the router records
        # its placements under its own pid (one past the replica range)
        # so ``trace_events`` can merge router + every replica onto one
        # Perfetto timeline.
        self.obs = Registry()
        self.tracer = Tracer(pid=len(replicas), process_name="router")
        self.obs.set("fleet_replicas", len(replicas), unit="replicas")
        if router.obs is None:
            router.obs = self.obs
        if router.tracer is None:
            router.tracer = self.tracer

    @classmethod
    def from_plan(cls, plan, factory, transport: str = "thread",
                  policy: str = "free_pages", affinity: bool = True,
                  straggler=None) -> "ServeCluster":
        n = plan.replicas()
        page = plan.page_plan() or {}
        replicas = [Replica(factory, replica=i, transport=transport,
                            default_stats=plan_stats(plan, i))
                    for i in range(n)]
        router = Router(n, policy=policy,
                        page_tokens=int(page.get("page_tokens") or 0),
                        affinity=affinity, straggler=straggler)
        return cls(replicas, router)

    # ----------------------------------------------------------- serving
    def stats(self) -> List[ReplicaStats]:
        out = []
        for rep in self.replicas:
            st = rep.stats()
            st.drained = rep.replica in self.router.drained
            out.append(st)
        return out

    def _dispatch(self, cr: ClusterRequest) -> None:
        i = self.router.route(self.stats(), tokens=cr.tokens)
        rep = self.replicas[i]
        cr.replica = i

        def done(call, _i=i):
            if call.err is None and call.first_token_time is not None:
                self.router.note_latency(_i,
                                         call.first_token_time - cr.t_submit)

        cr.call = rep.generate([cr.tokens], cr.max_new,
                               on_token=cr.on_token, on_done=done)

    def submit(self, tokens, max_new_tokens: int = 16,
               on_token=None) -> ClusterRequest:
        """Route one request and start it (always streamed, so TTFT is
        observable).  Returns immediately; ``ClusterRequest.result()``
        blocks for the tokens."""
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        cr = ClusterRequest(rid, tokens, max_new_tokens, on_token=on_token)
        with self._lock:
            self._inflight.append(cr)
        self._dispatch(cr)
        return cr

    def generate(self, prompts: Sequence[Any], max_new_tokens: int = 16
                 ) -> List[List[int]]:
        """Blocking convenience: route every prompt, wait for all, return
        token lists in submission order (the token-identity surface)."""
        crs = [self.submit(p, max_new_tokens) for p in prompts]
        return [cr.result() for cr in crs]

    # ------------------------------------------------------ observability
    def trace_events(self, last: Optional[int] = None) -> List[Dict]:
        """The whole fleet's Chrome trace on ONE timeline: the router's
        placement instants (its own pid) merged with every replica's
        request spans (pid = replica id), sorted by timestamp."""
        from repro.obs import merge_events

        lists = [self.tracer.chrome_events(last)]
        for rep in self.replicas:
            lists.append(rep.trace(last))
        return merge_events(*lists)

    def prometheus(self) -> str:
        """Prometheus text exposition for the fleet: the front's own
        registry plus every replica's forwarded snapshot and scalar
        stats, labelled by replica/role."""
        from dataclasses import asdict

        from repro.obs import prometheus_lines

        lines = [self.obs.to_prometheus(labels={"process": "router"})
                 .rstrip("\n")]
        for st in self.stats():
            labels = {"replica": str(st.replica), "role": st.role}
            d = asdict(st)
            snap = d.pop("metrics", {}) or {}
            d.pop("replica", None)
            d.pop("role", None)
            d.pop("batching", None)
            lines.extend(prometheus_lines(
                {f"replica_{k}": v for k, v in d.items()}, labels))
            lines.extend(prometheus_lines(snap, labels))
        return "\n".join(lines) + "\n"

    # -------------------------------------------------------------- drain
    def drain_replica(self, replica: int) -> List[int]:
        """Stop admitting to ``replica`` and requeue its not-yet-started
        requests through the router.  Returns the requeued rids."""
        self.router.drain(replica)
        cancelled = self.replicas[replica].cancel_pending()
        moved = []
        with self._lock:
            inflight = list(self._inflight)
        for cr in inflight:
            if cr.call in cancelled:
                self._dispatch(cr)
                moved.append(cr.rid)
        return moved

    def sweep_stragglers(self) -> List[int]:
        """Drain-and-requeue every straggling replica (router verdict)."""
        moved = []
        for rep in self.router.sweep_stragglers():
            moved.extend(self.drain_replica(rep))
        return moved

    def close(self) -> None:
        for rep in self.replicas:
            rep.close()
