"""Stdlib-only streaming HTTP front end over a ``ServeCluster``
(DESIGN.md §12).  No framework: ``http.server.ThreadingHTTPServer``
plus hand-rolled chunked transfer encoding, so the only dependency is
the standard library.

  * ``POST /generate`` -- body ``{"tokens": [...], "max_new_tokens": N}``;
    response is ``Transfer-Encoding: chunked`` NDJSON, one line per
    delivered token (``{"token": t, "i": k}``), ``{"reset": true}`` on a
    recompute preemption (previously streamed tokens re-emit), and a
    final ``{"done": true, "tokens": [...], "replica": r}`` line.
  * ``GET /healthz`` -- ``{"ok": true, "replicas": N, "admissible": M}``.
  * ``GET /stats`` -- the router's world view: one ``ReplicaStats`` dict
    per replica plus the active policy.
  * ``GET /metrics`` -- Prometheus text exposition (DESIGN.md §13): the
    router's registry plus every replica's forwarded snapshot, labelled
    ``{replica=...,role=...}``.
  * ``GET /trace[?n=N]`` -- Chrome/Perfetto ``trace_event`` JSON of the
    last N events (default: everything the rings hold), router and all
    replicas merged on one timeline.
"""

from __future__ import annotations

import json
import queue
import threading
from dataclasses import asdict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Optional, Tuple
from urllib.parse import parse_qs, urlparse


def _make_handler(cluster):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, *args):           # noqa: D102 -- quiet tests
            pass

        # ------------------------------------------------------- helpers
        def _json(self, code: int, obj: Any) -> None:
            data = json.dumps(obj).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def _chunk(self, data: bytes) -> None:
            self.wfile.write(f"{len(data):X}\r\n".encode() + data + b"\r\n")
            self.wfile.flush()

        def _line(self, obj: Any) -> None:
            self._chunk(json.dumps(obj).encode() + b"\n")

        def _text(self, code: int, text: str,
                  ctype: str = "text/plain; version=0.0.4") -> None:
            data = text.encode()
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        # ---------------------------------------------------------- GETs
        def do_GET(self):                       # noqa: N802
            url = urlparse(self.path)
            if url.path == "/healthz":
                stats = cluster.stats()
                self._json(200, {
                    "ok": True,
                    "replicas": len(stats),
                    "admissible": sum(1 for s in stats if not s.drained),
                })
            elif url.path == "/stats":
                self._json(200, {
                    "policy": cluster.router.policy,
                    "replicas": [asdict(s) for s in cluster.stats()],
                })
            elif url.path == "/metrics":
                self._text(200, cluster.prometheus())
            elif url.path == "/trace":
                qs = parse_qs(url.query)
                last = None
                try:
                    last = int(qs["n"][0]) if "n" in qs else None
                except ValueError:
                    self._json(400, {"error": "n must be an integer"})
                    return
                self._json(200, {
                    "traceEvents": cluster.trace_events(last),
                    "displayTimeUnit": "ms",
                })
            else:
                self._json(404, {"error": f"no route {self.path}"})

        # --------------------------------------------------------- POSTs
        def do_POST(self):                      # noqa: N802
            if self.path != "/generate":
                self._json(404, {"error": f"no route {self.path}"})
                return
            try:
                n = int(self.headers.get("Content-Length", 0))
                body = json.loads(self.rfile.read(n) or b"{}")
                tokens = [int(t) for t in body["tokens"]]
                max_new = int(body.get("max_new_tokens", 16))
            except (KeyError, ValueError, json.JSONDecodeError) as e:
                self._json(400, {"error": f"bad request: {e}"})
                return
            events: "queue.Queue[Tuple[str, Any]]" = queue.Queue()
            cr = cluster.submit(
                tokens, max_new_tokens=max_new,
                on_token=lambda i, tok: events.put(("token", (i, tok))))
            self.send_response(200)
            self.send_header("Content-Type", "application/x-ndjson")
            self.send_header("Transfer-Encoding", "chunked")
            self.end_headers()
            while True:
                try:
                    kind, payload = events.get(timeout=0.05)
                except queue.Empty:
                    if cr.done():
                        break
                    continue
                i, tok = payload
                self._line({"reset": True} if tok is None
                           else {"token": int(tok), "i": int(i)})
            try:
                out = cr.result(timeout=60.0)
                self._line({"done": True, "tokens": out,
                            "replica": cr.replica})
            except Exception as e:              # noqa: BLE001
                self._line({"error": f"{type(e).__name__}: {e}"})
            self._chunk(b"")                    # terminal 0-length chunk

    return Handler


class ClusterServer:
    """The serving front end: bind, serve on a daemon thread, close."""

    def __init__(self, cluster, host: str = "127.0.0.1", port: int = 0):
        self.cluster = cluster
        self.httpd = ThreadingHTTPServer((host, port),
                                         _make_handler(cluster))
        self.httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return self.httpd.server_address[:2]

    def start(self) -> "ClusterServer":
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        name="cluster-http", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.httpd.serve_forever()

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(5.0)
