"""Fault-tolerant sharded checkpointing.

  * **Atomicity**: writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after an fsync'd manifest -- a crash mid-save can never
    corrupt the latest valid checkpoint.
  * **Async**: ``CheckpointManager.save`` snapshots device arrays to host
    and hands serialization to a background thread; the train step is
    blocked only for the host copy.
  * **Sharded/multi-host**: each host writes only the leaves (or leaf
    shards) it owns under ``host_{k}/``; the manifest indexes them. On this
    single-process container host_count == 1 exercises the same code path.
  * **Elastic restore**: leaves are restored by *name* and re-sharded to
    whatever mesh the restoring job runs (``reshard``), so a job can
    restart on a different topology -- the checkpoint is
    topology-independent.
  * **Keep-k GC** + ``latest_checkpoint`` auto-resume.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
        return out
    if hasattr(tree, "_fields"):  # NamedTuple (check before tuple!)
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k),
                                f"{prefix}{_SEP}{k}" if prefix else k))
        return out
    if isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}" if prefix else str(i)))
        return out
    out[prefix] = tree
    return out


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    host_index: int = 0, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    host_dir = os.path.join(tmp, f"host_{host_index}")
    os.makedirs(host_dir, exist_ok=True)

    flat = _flatten(tree)
    manifest = {"step": step, "keys": sorted(flat), "time": time.time(),
                "extra": extra or {}}
    arrays = {}
    for key, leaf in flat.items():
        arrays[key.replace(_SEP, "__")] = np.asarray(leaf)
    np.savez(os.path.join(host_dir, "arrays.npz"), **arrays)
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            step = int(m.group(1))
            if best is None or step > best[0]:
                best = (step, os.path.join(directory, name))
    return best[1] if best else None


def restore_checkpoint(
    path: str,
    template: PyTree,
    reshard: Optional[Callable[[str, np.ndarray], Any]] = None,
) -> PyTree:
    """Restore into the structure of ``template``; ``reshard(key, array)``
    may place each leaf onto the current mesh (elastic restart)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data: Dict[str, np.ndarray] = {}
    for host in sorted(os.listdir(path)):
        if not host.startswith("host_"):
            continue
        with np.load(os.path.join(path, host, "arrays.npz")) as z:
            for k in z.files:
                data[k.replace("__", _SEP)] = z[k]
    missing = [k for k in manifest["keys"] if k not in data]
    if missing:
        raise IOError(f"checkpoint {path} missing leaves: {missing[:5]}...")
    for key in _flatten(template):
        if key not in data:
            raise KeyError(f"template leaf {key!r} absent from checkpoint")
    return _rebuild(template, data, reshard)


def _rebuild(template: PyTree, data: Dict[str, np.ndarray],
             reshard, prefix: str = "") -> PyTree:
    if isinstance(template, dict):
        return {k: _rebuild(v, data, reshard,
                            f"{prefix}{_SEP}{k}" if prefix else str(k))
                for k, v in template.items()}
    if isinstance(template, (tuple, list)) and not hasattr(template, "_fields"):
        t = [_rebuild(v, data, reshard,
                      f"{prefix}{_SEP}{i}" if prefix else str(i))
             for i, v in enumerate(template)]
        return type(template)(t)
    if hasattr(template, "_fields"):
        return type(template)(*[
            _rebuild(getattr(template, k), data, reshard,
                     f"{prefix}{_SEP}{k}" if prefix else k)
            for k in template._fields
        ])
    arr = data[prefix]
    return reshard(prefix, arr) if reshard else arr


class CheckpointManager:
    """Async keep-k checkpointing with preemption-safe final save."""

    def __init__(self, directory: str, keep: int = 3, host_index: int = 0):
        self.directory = directory
        self.keep = keep
        self.host_index = host_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, blocking: bool = False,
             extra: Optional[dict] = None) -> None:
        self.wait()                           # one in flight at a time
        host_tree = jax.tree.map(
            lambda x: np.asarray(x) if hasattr(x, "dtype") else x, tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                self.host_index, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        for s in sorted(steps)[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, template: PyTree, reshard=None):
        path = latest_checkpoint(self.directory)
        if path is None:
            return None, None
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return restore_checkpoint(path, template, reshard), manifest
