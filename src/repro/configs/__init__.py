from repro.configs.base import (
    EncDecConfig,
    MeshConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    RunConfig,
    SHAPES,
    SMOKE_SHAPES,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    XLSTMConfig,
    apply_overrides,
    get_model_config,
    get_shape,
    list_archs,
    parse_cli,
    register,
)

__all__ = [k for k in dir() if not k.startswith("_")]
