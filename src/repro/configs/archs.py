"""The 10 assigned architectures, exact published configurations.

Each entry records its source tier from the assignment. All are selectable
via ``--arch <id>`` in the launchers; ``ModelConfig.reduced()`` gives the
smoke-test variant exercised by ``tests/test_arch_smoke.py``.
"""

from repro.configs.base import (
    EncDecConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    XLSTMConfig,
    register,
)


@register("zamba2-1.2b")
def zamba2_1p2b() -> ModelConfig:
    """Zamba2-1.2B: Mamba2 backbone + shared attention blocks.
    [arXiv:2411.15242; hf]"""
    return ModelConfig(
        arch="zamba2-1.2b",
        family="hybrid_ssm",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        d_head=64,
        ssm=SSMConfig(
            state_dim=64, conv_width=4, expand=2, head_dim=64, chunk=256,
            attn_every=6, shared_attention=True,
        ),
        notes="Mamba2 (SSD) mixers; one weight-shared attn+MLP block applied "
              "every 6 layers (Zamba-style shared block).",
        source="arXiv:2411.15242",
    )


@register("qwen2-0.5b")
def qwen2_0p5b() -> ModelConfig:
    """Qwen2-0.5B: dense, GQA kv=2, QKV bias. [arXiv:2407.10671; hf]"""
    return ModelConfig(
        arch="qwen2-0.5b",
        family="dense",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151936,
        d_head=64,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
        source="arXiv:2407.10671",
    )


@register("deepseek-coder-33b")
def deepseek_coder_33b() -> ModelConfig:
    """DeepSeek-Coder-33B: llama-arch dense, GQA kv=8. [arXiv:2401.14196; hf]"""
    return ModelConfig(
        arch="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        d_head=128,
        rope_theta=100000.0,
        source="arXiv:2401.14196",
    )


@register("stablelm-1.6b")
def stablelm_1p6b() -> ModelConfig:
    """StableLM-2-1.6B: dense, MHA (kv=32).
    [hf:stabilityai/stablelm-2-1_6b; unverified]"""
    return ModelConfig(
        arch="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        d_head=64,
        qkv_bias=False,
        notes="StableLM-2 uses 25% partial rotary; we apply full RoPE "
              "(backbone-equivalent FLOPs/memory).",
        source="hf:stabilityai/stablelm-2-1_6b",
    )


@register("llama3.2-1b")
def llama32_1b() -> ModelConfig:
    """Llama-3.2-1B: small llama3, GQA kv=8.
    [hf:meta-llama/Llama-3.2-1B; unverified]"""
    return ModelConfig(
        arch="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        d_head=64,
        tie_embeddings=True,
        rope_theta=500000.0,
        source="hf:meta-llama/Llama-3.2-1B",
    )


@register("qwen2-vl-7b")
def qwen2_vl_7b() -> ModelConfig:
    """Qwen2-VL-7B language backbone: M-RoPE, GQA kv=4; vision frontend is a
    stub (precomputed patch embeddings). [arXiv:2409.12191; hf]"""
    return ModelConfig(
        arch="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        d_head=128,
        qkv_bias=True,
        mrope=True,
        input_embeds=True,
        rope_theta=1e6,
        notes="Backbone only; input_specs() supplies (B, S, d_model) patch "
              "embeddings + (3, B, S) M-RoPE position ids.",
        source="arXiv:2409.12191",
    )


@register("mixtral-8x7b")
def mixtral_8x7b() -> ModelConfig:
    """Mixtral-8x7B: 8-expert top-2 MoE, GQA kv=8, sliding-window attention.
    [arXiv:2401.04088; hf]"""
    return ModelConfig(
        arch="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        d_head=128,
        sliding_window=4096,
        rope_theta=1e6,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
        # MoE keeps per-expert gradient buckets + the dispatch gather
        # destinations alive next to the resident shard -> larger phi_mesh
        # transient factor (launch/dryrun.py --calibrate to refine).
        overhead=1.25,
        source="arXiv:2401.04088",
    )


@register("deepseek-v2-236b")
def deepseek_v2_236b() -> ModelConfig:
    """DeepSeek-V2-236B: MLA (kv_lora=512) + 160-expert top-6 MoE with 2
    shared experts; first layer dense. [arXiv:2405.04434; hf]"""
    return ModelConfig(
        arch="deepseek-v2-236b",
        family="mla_moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=1536,
        vocab_size=102400,
        mla=MLAConfig(
            kv_lora_rank=512, q_lora_rank=1536,
            rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
        ),
        moe=MoEConfig(
            n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
            first_k_dense=1, dense_d_ff=12288,
        ),
        # See mixtral-8x7b: MoE transient buffers scale the phi_mesh estimate.
        overhead=1.25,
        source="arXiv:2405.04434",
    )


@register("xlstm-1.3b")
def xlstm_1p3b() -> ModelConfig:
    """xLSTM-1.3B: sLSTM + mLSTM blocks (7:1). [arXiv:2405.04517; unverified]"""
    return ModelConfig(
        arch="xlstm-1.3b",
        family="xlstm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        d_head=512,
        xlstm=XLSTMConfig(
            slstm_every=8, mlstm_proj_factor=2.0, slstm_proj_factor=1.3333,
            conv_width=4,
        ),
        notes="d_ff=0: the xLSTM blocks carry their own up/down projections "
              "(mLSTM pf=2, sLSTM pf=4/3).",
        source="arXiv:2405.04517",
    )


@register("whisper-large-v3")
def whisper_large_v3() -> ModelConfig:
    """Whisper-large-v3 backbone: enc-dec transformer, conv frontend stubbed
    (precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
    return ModelConfig(
        arch="whisper-large-v3",
        family="enc_dec",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        d_head=64,
        input_embeds=True,
        enc_dec=EncDecConfig(n_encoder_layers=32, n_decoder_layers=32,
                             frontend="stub"),
        notes="32L = 32 enc + 32 dec (whisper-large). Learned absolute "
              "positions; conv frontend replaced by input_specs() frame "
              "embeddings per the assignment.",
        source="arXiv:2212.04356",
    )
