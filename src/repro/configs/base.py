"""Typed configuration system.

Pure dataclasses -- importing ``repro.configs`` never touches JAX device
state (required so the dry-run can set XLA_FLAGS before any JAX import).

``ModelConfig`` covers all 10 assigned architecture families through optional
feature blocks (MoE, MLA, SSM, xLSTM, enc-dec, M-RoPE); each architecture
file in this package instantiates one with the exact published numbers and
registers it under its ``--arch`` id.
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    d_ff_expert: int = 0          # per-expert FFN width
    first_k_dense: int = 0        # leading dense layers (DeepSeek-V2)
    dense_d_ff: int = 0           # FFN width of those dense layers
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = full-rank Q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    state_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256              # SSD chunk length (cache-conscious knob)
    attn_every: int = 0           # hybrid: shared attn block every N layers
    shared_attention: bool = False


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # every Nth block is sLSTM (xLSTM[7:1])
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333
    conv_width: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 32
    n_decoder_layers: int = 32
    frontend: str = "stub"        # precomputed frame embeddings


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                   # dense | moe | mla_moe | hybrid_ssm | xlstm | enc_dec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    sliding_window: int = 0       # 0 = full attention
    mrope: bool = False           # multimodal rotary (Qwen2-VL)
    input_embeds: bool = False    # frontend stub provides embeddings
    # Perf knobs (cache-conscious attention: sequences >= threshold stream
    # decomposer-sized KV blocks instead of materializing (S, S) logits).
    attn_blockwise_threshold: int = 8192
    # phi_mesh transient-copy factor for the mesh-level planner (repro.plan):
    # >1 reserves HBM for the buffers the runtime keeps alive alongside the
    # resident shard (gradient buckets, all-gather destinations); calibrate
    # against dry-run HLO memory analysis via ``launch/dryrun.py --calibrate``.
    overhead: float = 1.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    notes: str = ""
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid/linear or windowed)."""
        return self.family in ("hybrid_ssm", "xlstm") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytical parameter count (embeddings + per-layer blocks)."""
        d, v = self.d_model, self.vocab_size
        total = d * v * (1 if self.tie_embeddings else 2)
        total += self._per_layer_params() * self.n_layers
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, v = self.d_model, self.vocab_size
        total = d * v * (1 if self.tie_embeddings else 2)
        per_layer = self._per_layer_params(active_only=True)
        return total + per_layer * self.n_layers

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            q_in = m.q_lora_rank or d
            qk_dim = m.nope_head_dim + m.rope_head_dim
            p = 0
            if m.q_lora_rank:
                p += d * m.q_lora_rank
            p += q_in * self.n_heads * qk_dim
            p += d * (m.kv_lora_rank + m.rope_head_dim)
            p += m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d
            return p
        hd = self.head_dim
        return (
            d * self.n_heads * hd
            + 2 * d * self.n_kv_heads * hd
            + self.n_heads * hd * d
        )

    def _ffn_params(self, active_only: bool = False) -> int:
        d = self.d_model
        if self.moe is not None:
            mo = self.moe
            per_expert = 3 * d * (mo.d_ff_expert or self.d_ff)
            n_act = mo.top_k if active_only else mo.n_experts
            p = per_expert * (n_act + mo.n_shared_experts)
            p += d * mo.n_experts  # router
            return p
        return 3 * d * self.d_ff if self.d_ff else 0

    def _per_layer_params(self, active_only: bool = False) -> int:
        d = self.d_model
        if self.family == "hybrid_ssm":
            s = self.ssm
            d_inner = s.expand * d
            mamba = (
                d * (2 * d_inner + 2 * s.state_dim * (d_inner // s.head_dim))
                + d_inner * s.conv_width
                + d_inner * d
                + 2 * (d_inner // s.head_dim)
            )
            # Shared attention block amortized over its period (params are
            # shared, counted once per period).
            shared = 0
            if s.attn_every:
                shared = (self._attn_params() + 3 * d * self.d_ff) // s.attn_every
            return mamba + shared + 2 * d
        if self.family == "xlstm":
            x = self.xlstm
            d_in_m = int(x.mlstm_proj_factor * d)
            mlstm = d * 2 * d_in_m + d_in_m * d + 4 * d_in_m * d_in_m // max(1, self.n_heads)
            return mlstm + 2 * d
        attn = self._attn_params()
        ffn = self._ffn_params(active_only)
        return attn + ffn + 2 * d

    def reduced(self) -> "ModelConfig":
        """Smoke-test-sized variant of the same family (runs on 1 CPU)."""
        kw: dict = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            d_head=16,
        )
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe, n_experts=4, top_k=min(2, self.moe.top_k),
                d_ff_expert=32 if self.moe.d_ff_expert else 0,
                first_k_dense=min(1, self.moe.first_k_dense),
                dense_d_ff=64 if self.moe.dense_d_ff else 0,
            )
        if self.mla is not None:
            kw["mla"] = replace(
                self.mla, kv_lora_rank=16,
                q_lora_rank=16 if self.mla.q_lora_rank else 0,
                rope_head_dim=8, nope_head_dim=16, v_head_dim=16,
            )
            kw["d_head"] = 0
        if self.ssm is not None:
            kw["ssm"] = replace(
                self.ssm, state_dim=16, head_dim=16, chunk=16,
                attn_every=min(2, self.ssm.attn_every) if self.ssm.attn_every else 0,
            )
            kw["n_layers"] = 4 if self.ssm.attn_every else 2
        if self.xlstm is not None:
            kw["xlstm"] = replace(self.xlstm, slstm_every=2)
            kw["n_layers"] = 4
        if self.enc_dec is not None:
            kw["enc_dec"] = replace(
                self.enc_dec, n_encoder_layers=2, n_decoder_layers=2
            )
        if self.sliding_window:
            kw["sliding_window"] = 32
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes (assigned: 4 per LM arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Reduced shapes for CPU smoke tests.
SMOKE_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeConfig("long_500k", 128, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / training / run
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    multi_pod: bool = False


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"           # none | full | dots
    microbatches: int = 1         # gradient accumulation
    optimizer_dtype: str = "float32"   # float32 | bfloat16 state compression
    grad_compression: str = "none"     # none | bf16 | int8_ef
    # Collective-matmul schedule for the TP projections (DESIGN.md §5):
    # gspmd (XLA's defaults) | ring | serpentine | auto (serpentine when the
    # mesh decomposer chose FSDP -- the interconnect-bound regime).
    collectives: str = "gspmd"
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()
    train: TrainConfig = TrainConfig()
    # Cache-conscious runtime knobs (the paper's feature, first-class):
    decomposition: str = "cache_conscious"   # | horizontal
    schedule: str = "cc"                     # | srrc
    tcl: str = "VMEM"
    use_pallas: bool = True


# ---------------------------------------------------------------------------
# phi_mesh calibration artifact (launch/dryrun.py --calibrate)
# ---------------------------------------------------------------------------

#: Env var overriding the calibration artifact path (tests point it at a
#: tmp file; unset, the repo-level ``experiments/calibration.json`` is
#: used when present).
CALIBRATION_ENV = "REPRO_CALIBRATION"


def calibration_path() -> str:
    override = os.environ.get(CALIBRATION_ENV)
    if override:
        return override
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "experiments", "calibration.json")


#: path -> ((mtime_ns, size) | None, parsed entries).  Keyed on the stat
#: signature so a rewrite (e.g. ``dryrun --calibrate`` mid-process) is
#: picked up without any manual cache invalidation.
_CAL_CACHE: Dict[str, Tuple[Optional[Tuple[int, int]],
                            Dict[str, Dict[str, float]]]] = {}

#: Calibrated per-arch scalars the artifact may carry: ``overhead`` feeds
#: ``ModelConfig.overhead`` (the phi_mesh transient factor), ``act_scale``
#: feeds ``launch.specs.activation_footprint`` (the replicated activation
#: term the mesh search reserves per chip).
_CAL_FIELDS = ("overhead", "act_scale")


def _load_calibration(path: str) -> Dict[str, Dict[str, float]]:
    """``{arch: {"overhead": x, "act_scale": y}}`` from a calibration
    artifact (missing fields omitted; empty on any read/parse problem --
    calibration is advisory, never a hard dep)."""
    try:
        st = os.stat(path)
        sig: Optional[Tuple[int, int]] = (st.st_mtime_ns, st.st_size)
    except OSError:
        sig = None
    cached = _CAL_CACHE.get(path)
    if cached is not None and cached[0] == sig:
        return cached[1]
    out: Dict[str, Dict[str, float]] = {}
    if sig is not None:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
        for arch, entry in data.items():
            if arch.startswith("_") or not isinstance(entry, dict):
                continue
            fields = {}
            for f_ in _CAL_FIELDS:
                try:
                    fields[f_] = float(entry[f_])
                except (KeyError, TypeError, ValueError):
                    continue
            if fields:
                out[arch] = fields
    _CAL_CACHE[path] = (sig, out)
    return out


def calibration_overhead(arch_id: str) -> Optional[float]:
    """The measured ``phi_mesh`` overhead for one arch, or None."""
    return _load_calibration(calibration_path()).get(arch_id, {}) \
        .get("overhead")


def calibration_act_scale(arch_id: str) -> Optional[float]:
    """The measured activation-footprint scale for one arch, or None
    (``launch/dryrun.py --calibrate`` fits the replicated term the same
    way it fits ``overhead``)."""
    return _load_calibration(calibration_path()).get(arch_id, {}) \
        .get("act_scale")


# ---------------------------------------------------------------------------
# Registry + CLI
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def get_model_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[arch_id]()
    if cfg.overhead == 1.0:
        # Registered configs that leave ``overhead`` at its default pick up
        # the measured value from the calibration artifact
        # (``launch/dryrun.py --calibrate``); an explicit per-arch overhead
        # always wins.
        measured = calibration_overhead(arch_id)
        if measured is not None:
            cfg = replace(cfg, overhead=max(1.0, measured))
    return cfg


def get_shape(name: str, smoke: bool = False) -> ShapeConfig:
    table = SMOKE_SHAPES if smoke else SHAPES
    if name not in table:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(table)}")
    return table[name]


def _ensure_loaded() -> None:
    # Import the arch modules lazily to avoid import cycles.
    from repro.configs import archs  # noqa: F401


def apply_overrides(cfg, overrides: Dict[str, str]):
    """Apply dotted-path CLI overrides (``--train.learning_rate 1e-4``)."""
    for key, raw in overrides.items():
        parts = key.split(".")
        objs = [cfg]
        for p in parts[:-1]:
            objs.append(getattr(objs[-1], p))
        leaf, name = objs[-1], parts[-1]
        old = getattr(leaf, name)
        if isinstance(old, bool):
            val = raw.lower() in ("1", "true", "yes")
        elif isinstance(old, int):
            val = int(raw)
        elif isinstance(old, float):
            val = float(raw)
        elif isinstance(old, tuple):
            val = tuple(int(x) for x in raw.strip("()").split(","))
        else:
            val = raw
        new_leaf = replace(leaf, **{name: val})
        # Rebuild the chain outwards.
        for obj, part in zip(reversed(objs[:-1]), reversed(parts[:-1])):
            new_leaf = replace(obj, **{part: new_leaf})
        cfg = new_leaf
    return cfg


def parse_cli(argv: List[str]) -> Tuple[Dict[str, str], List[str]]:
    """Split ``--key value`` pairs from positional args."""
    overrides: Dict[str, str] = {}
    rest: List[str] = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a.startswith("--"):
            if "=" in a:
                k, v = a[2:].split("=", 1)
                overrides[k] = v
                i += 1
            else:
                overrides[a[2:]] = argv[i + 1]
                i += 2
        else:
            rest.append(a)
            i += 1
    return overrides, rest
