"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, proving the distribution config is coherent.

MUST be the first two lines (before ANY other import -- jax locks the device
count on first init):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    TrainConfig,
    get_model_config,
    get_shape,
    list_archs,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    decode_batch_specs,
    train_batch_specs,
)
from repro.launch.trainer import (  # noqa: E402
    make_serve_steps,
    make_train_step,
)
from repro.optim import adamw_init  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def skip_reason(arch: str, shape_name: str) -> str:
    """Cells skipped per the assignment, with the one-line reason."""
    cfg = get_model_config(arch)
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return ("pure full-attention arch: long_500k requires sub-quadratic "
                "attention (see DESIGN.md §4)")
    return ""


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cache_policy: str = "baseline", out_root: str = None):
    """Lower + compile one cell. Returns the report dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_model_config(arch)
    shape = get_shape(shape_name)
    t0 = time.time()

    if shape.kind == "train":
        train = TrainConfig(remat="full", microbatches=1)
        ts = make_train_step(cfg, shape, mesh, train, jit=True)
        p_abs = ts.model.abstract_params(jnp.float32)
        opt_abs = jax.eval_shape(lambda p: adamw_init(p), p_abs)
        b_abs = train_batch_specs(cfg, shape)
        lowered = ts.fn.lower(p_abs, opt_abs, b_abs)
        step_kind = "train_step"
    else:
        # Baseline (paper-faithful) placement; §Perf variants override via
        # benchmarks/perf_iter.py, and production serving gets the winning
        # policy by default (cache_policy="auto" in make_serve_steps).
        ss = make_serve_steps(cfg, shape, mesh, jit=True,
                              cache_policy=cache_policy)
        p_abs = ss.model.abstract_params(jnp.float32)
        if shape.kind == "prefill":
            b_abs = train_batch_specs(cfg, shape)
            b_abs.pop("labels", None)
            lowered = ss.prefill.lower(p_abs, b_abs)
            step_kind = "prefill_step"
        else:
            cache_abs = jax.eval_shape(
                lambda: ss.model.init_cache(
                    shape.global_batch, shape.seq_len, jnp.bfloat16,
                    enc_len=shape.seq_len))
            b_abs = decode_batch_specs(cfg, shape)
            lowered = ss.decode.lower(p_abs, cache_abs, b_abs)
            step_kind = "serve_step"

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()

    # Loop-corrected per-chip quantities (XLA's cost_analysis counts while
    # bodies once; see repro.roofline.hlo).
    from repro.roofline import analyze_hlo, roofline_terms

    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text)
    cfg_full = get_model_config(arch)
    n_chips = 512 if multi_pod else 256
    terms = roofline_terms(cfg_full, shape,
                           "2x16x16" if multi_pod else "16x16",
                           step_kind, hlo, n_chips=n_chips)

    def g(obj, attr):
        try:
            v = getattr(obj, attr, None)
            return int(v) if v is not None else None
        except Exception:
            return None

    # Persist the HLO so perf iterations can re-analyze without recompiling.
    import gzip
    hlo_dir = os.path.join(out_root or os.path.abspath(RESULTS_DIR), "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
        f.write(hlo_text)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": step_kind,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "xla_cost_flops_looponce": cost.get("flops")
        if isinstance(cost, dict) else None,
        "flops": hlo.flops,
        "hbm_bytes": hlo.hbm_bytes,
        "collective_bytes": hlo.collective_bytes,
        "loop_trip_counts": hlo.loop_trip_counts,
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "bottleneck": terms.bottleneck,
            "model_flops_per_chip": terms.model_flops_per_chip,
            "useful_ratio": terms.useful_ratio,
            "mfu_bound": terms.mfu_bound,
            "ideal_bound_s": terms.ideal_bound_s,
            "roofline_fraction": terms.roofline_fraction,
        },
        "memory": {
            "argument_bytes": g(mem, "argument_size_in_bytes"),
            "output_bytes": g(mem, "output_size_in_bytes"),
            "temp_bytes": g(mem, "temp_size_in_bytes"),
            "peak_bytes": g(mem, "peak_memory_in_bytes"),
            "generated_code_bytes": g(mem, "generated_code_size_in_bytes"),
        },
    }
    return report


def print_plan_tree(arch: str, multi_pod: bool) -> None:
    """Print the full hierarchical plan (``repro.plan``) for one arch on a
    production mesh -- the planner walk the trainer consumes, without
    lowering anything.  The multi-pod mesh carries a "pod" axis, so its
    hierarchy (and hence the printed tree) has a DCN level above the ICI.
    """
    from repro.dist.sharding import TRAIN_STATE_BYTES_PER_PARAM, mesh_plan
    from repro.launch.specs import activation_footprint

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_model_config(arch)
    shape = get_shape("train_4k")
    sizes = dict(mesh.shape)
    model_n = sizes.get("model", 1)
    data_n = max(1, mesh.size // model_n)
    hp = mesh_plan(
        mesh,
        state_bytes=cfg.param_count() * TRAIN_STATE_BYTES_PER_PARAM // model_n,
        act_bytes=activation_footprint(cfg, shape, "full") // data_n,
        max_np=data_n,
        overhead=cfg.overhead,
        matmul=(shape.seq_len, cfg.d_model, cfg.d_ff or cfg.d_model),
    )
    print(f"[plan] {arch} on {'2x16x16' if multi_pod else '16x16'}:")
    for line in hp.describe():
        print("  " + line)


def calibrate_cell(arch: str, shape_name: str, multi_pod: bool = False,
                   out_root: str = None) -> dict:
    """Compare ``phi_mesh``'s per-chip estimate against the lowered-HLO
    memory analysis (the satellite calibration helper for
    ``ModelConfig.overhead``).

    Lowers + compiles the cell, reads XLA's per-device peak bytes, and
    divides ``phi_mesh``'s per-chip estimate by it.  The estimate is
    evaluated at the FSDP degree the rules actually *realize* (full data
    axes when sharded, 1 when replicated), not the planner's quantized np
    -- the lowered HLO shards at the realized degree, so comparing at any
    other np would fold a sharding-degree mismatch into the ratio.  A
    ratio < 1 means ``phi_mesh`` underestimates the resident transients --
    raise ``overhead`` toward ``1/ratio``.
    """
    from repro.core.decompose import make_phi_mesh
    from repro.core.distribution import (
        Array1DDistribution,
        ReplicatedDistribution,
    )
    from repro.dist.sharding import arch_rules
    from repro.launch.specs import activation_footprint, decode_footprint

    rep = lower_cell(arch, shape_name, multi_pod, out_root=out_root)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_model_config(arch)
    shape = get_shape(shape_name)
    data_n = max(1, mesh.size // dict(mesh.shape).get("model", 1))
    if shape.kind == "train":
        rules = arch_rules(
            cfg, mesh,
            act_bytes=activation_footprint(cfg, shape, "full") // data_n)
    else:
        rules = arch_rules(
            cfg, mesh, state_bytes_per_param=2,
            act_bytes=decode_footprint(cfg, shape,
                                       shape.seq_len) // mesh.size)
    lp = rules.meta["plan"].level("ICI")
    realized = rules.meta["fsdp_capacity"] if rules.meta["fsdp"] else 1
    phi = make_phi_mesh(overhead=lp.detail["overhead"])
    dists = [Array1DDistribution(
        length=max(1, lp.detail["sharded_bytes"]), element_size=1)]
    if lp.detail["replicated_bytes"]:
        dists.append(ReplicatedDistribution(lp.detail["replicated_bytes"]))
    terms = [phi(lp.granule_bytes, d, realized) for d in dists]
    est = sum(terms)
    mem = rep["memory"]
    # XLA's CPU backend reports no peak; fall back to the resident total
    # (arguments + temporaries + outputs), which is what phi_mesh models.
    peak = mem["peak_bytes"] or sum(
        mem[k] or 0 for k in ("argument_bytes", "temp_bytes", "output_bytes"))
    ratio = est / peak if peak else float("inf")
    print(f"[cal] {arch} x {shape_name} "
          f"({'2x16x16' if multi_pod else '16x16'}): "
          f"phi_mesh_est={est / 2 ** 30:.2f}GiB "
          f"hlo_peak={peak / 2 ** 30:.2f}GiB "
          f"calibration_ratio={ratio:.2f} (overhead={cfg.overhead})")
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "phi_mesh_est_bytes": est, "hlo_peak_bytes": peak,
           "calibration_ratio": ratio, "overhead": cfg.overhead}
    # Fit the REPLICATED term too (ROADMAP: calibrate activation_footprint
    # the same way as overhead).  Train cells feed activation_footprint in
    # as the replicated reserve, so the activation-implied residual is the
    # HLO peak minus the sharded-state estimate, and the ratio of modeled
    # to implied activation bytes calibrates ``act_scale``.  Serve cells
    # skip it: their replicated term is dominated by the weight shard.
    if shape.kind == "train" and len(terms) > 1 and peak:
        from repro.configs.base import calibration_act_scale

        act_est = terms[1]
        act_residual = max(1.0, peak - terms[0])
        rec.update({
            "act_est_bytes": act_est,
            "act_residual_bytes": act_residual,
            "act_ratio": act_est / act_residual,
            "act_scale": calibration_act_scale(arch) or 1.0,
        })
        print(f"[cal]   act: modeled={act_est / 2 ** 30:.2f}GiB "
              f"implied={act_residual / 2 ** 30:.2f}GiB "
              f"act_ratio={rec['act_ratio']:.2f} "
              f"(act_scale={rec['act_scale']})")
    return rec


def write_calibration(records: list, path: str = None) -> str:
    """Fold per-cell calibration records into the calibration artifact
    ``ModelConfig.overhead`` defaults from (``configs.base``).

    ``est = overhead * phi_mesh_terms``, so the overhead that would make
    the estimate meet the worst observed cell is ``overhead / min(ratio)``;
    clamped at 1.0 (phi never *undershoots* on purpose).  Existing entries
    for other archs are preserved (the artifact accumulates across
    partial ``--arch`` runs).
    """
    from repro.configs.base import calibration_path

    path = path or calibration_path()
    existing = {}
    try:
        with open(path) as f:
            existing = json.load(f)
    except (OSError, ValueError):
        pass
    by_arch = {}
    for rec in records:
        by_arch.setdefault(rec["arch"], []).append(rec)
    for arch, recs in by_arch.items():
        finite = [r for r in recs
                  if r["calibration_ratio"] not in (0, float("inf"))]
        if not finite:
            continue
        worst = min(finite, key=lambda r: r["calibration_ratio"])
        suggested = max(1.0, worst["overhead"] / worst["calibration_ratio"])
        entry = {
            "overhead": round(suggested, 3),
            "worst_ratio": round(worst["calibration_ratio"], 4),
            "worst_cell": f"{worst['shape']}@{worst['mesh']}",
            "cells": len(recs),
        }
        # The replicated (activation) term, fitted the same way: the scale
        # that makes the modeled activation bytes meet the worst observed
        # activation-implied residual, clamped at 1.0 (the model never
        # undershoots on purpose).  ``est = act_scale * base``, so the
        # meeting scale is ``act_scale / act_ratio``.
        acts = [r for r in recs
                if r.get("act_ratio") not in (None, 0, float("inf"))]
        if acts:
            worst_a = min(acts, key=lambda r: r["act_ratio"])
            entry["act_scale"] = round(
                max(1.0, worst_a["act_scale"] / worst_a["act_ratio"]), 3)
            entry["act_worst_ratio"] = round(worst_a["act_ratio"], 4)
            entry["act_worst_cell"] = \
                f"{worst_a['shape']}@{worst_a['mesh']}"
        else:
            # A run with no train cells (e.g. --shape decode_32k) fits no
            # activation term; carry the previously calibrated act fields
            # forward instead of silently reverting act_scale to 1.0.
            prev = existing.get(arch, {})
            for k in ("act_scale", "act_worst_ratio", "act_worst_cell"):
                if isinstance(prev, dict) and k in prev:
                    entry[k] = prev[k]
        existing[arch] = entry
    existing["_meta"] = {
        "source": "launch/dryrun.py --calibrate",
        "note": "overhead = registered_overhead / min(phi_mesh_est / "
                "hlo_peak); act_scale = used_act_scale / min(act_est / "
                "act_implied_residual); consumed by "
                "configs.base.get_model_config (overhead) and "
                "launch.specs.activation_footprint (act_scale) for archs "
                "left at the 1.0 defaults",
    }
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(existing, f, indent=1, sort_keys=True)
    print(f"[cal] wrote {path} ({len(by_arch)} arch(es))")
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    help="one shape, a comma-separated list, or 'all' "
                         "(a --calibrate run must cover an arch's shapes "
                         "in ONE invocation: write_calibration folds the "
                         "worst cell per run)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--cache_policy", default="baseline",
                    choices=["baseline", "auto"])
    ap.add_argument("--plan-tree", action="store_true",
                    help="print each cell's hierarchical plan (repro.plan) "
                         "and exit -- no lowering")
    ap.add_argument("--calibrate", action="store_true",
                    help="lower + compile each cell, print the phi_mesh vs "
                         "HLO-memory calibration ratio, and fold the "
                         "results into experiments/calibration.json (the "
                         "artifact ModelConfig.overhead defaults from)")
    ap.add_argument("--calibration-out", default=None,
                    help="override the calibration artifact path "
                         "(default: configs.base.calibration_path())")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.shape == "all"
              else [s.strip() for s in args.shape.split(",") if s.strip()])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.plan_tree:
        for arch in archs:
            for multi_pod in meshes:
                print_plan_tree(arch, multi_pod)
        return 0

    if args.calibrate:
        n_fail = 0
        records = []
        for arch in archs:
            for shape_name in shapes:
                if skip_reason(arch, shape_name):
                    continue
                for multi_pod in meshes:
                    try:
                        records.append(calibrate_cell(
                            arch, shape_name, multi_pod, out_root=args.out))
                    except Exception as e:
                        n_fail += 1
                        print(f"[cal-FAIL] {arch} x {shape_name}: {e}")
        if records:
            write_calibration(records, path=args.calibration_out)
        return 1 if n_fail else 0

    out_dir = args.out or os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            reason = skip_reason(arch, shape_name)
            for multi_pod in meshes:
                tag = (f"{arch}__{shape_name}__"
                       f"{'2x16x16' if multi_pod else '16x16'}")
                path = os.path.join(out_dir, tag + ".json")
                if reason:
                    rep = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "status": "skipped", "reason": reason}
                    n_skip += 1
                else:
                    try:
                        rep = lower_cell(arch, shape_name, multi_pod,
                                         cache_policy=args.cache_policy,
                                         out_root=out_dir)
                        n_ok += 1
                        print(f"[ok]   {tag}  compile={rep['compile_s']}s "
                              f"flops={rep['flops']}")
                    except Exception as e:  # report, keep going
                        rep = {"arch": arch, "shape": shape_name,
                               "mesh": "2x16x16" if multi_pod else "16x16",
                               "status": "failed", "error": repr(e),
                               "traceback": traceback.format_exc()[-2000:]}
                        n_fail += 1
                        print(f"[FAIL] {tag}: {e}")
                with open(path, "w") as f:
                    json.dump(rep, f, indent=1)
                if reason:
                    print(f"[skip] {tag}: {reason}")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
