"""Distributed training step factory + the training loop.

``make_train_step`` builds the jitted SPMD step for any (arch x mesh):
params/optimizer FSDP+TP sharded via the logical rules, batch sharded over
the data axes, microbatch gradient accumulation, optional gradient
compression on the wire, AdamW update, donated buffers.

The serve-step factory moved to ``repro.serve.steps`` (the serving stack
is owned by ``repro.serve`` -- DESIGN.md §7); ``ServeSteps`` and
``make_serve_steps`` are re-exported here for back-compat.

The Trainer class wires in the fault-tolerance substrate: async keep-k
checkpoints, preemption drain, step watchdog + straggler policy, and
elastic restore (re-shard on whatever mesh the relaunch built).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, TrainConfig
from repro.dist.sharding import (
    ShardingRules,
    arch_rules,
    default_rules,
    logical_sharding,
    param_shardings,
    resolve_collectives,
    use_mesh_rules,
    with_batch_guard,
    with_collectives,
)
from repro.launch.specs import (
    activation_footprint,
    batch_logical_axes,
)
from repro.models.model import Model, build_model
from repro.serve.steps import ServeSteps, make_serve_steps  # noqa: F401  (back-compat)
from repro.models.params import param_axes
from repro.optim import (
    OptState,
    adamw_init,
    adamw_update,
    compress_gradient,
    decompress_gradient,
)

PyTree = Any


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


#: "auto" -> serpentine iff the decomposer chose FSDP; shared with the
#: serve-step factory (the one place the policy lives: dist.sharding).
_apply_collectives = resolve_collectives


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


@dataclass
class TrainStep:
    fn: Callable                      # (params, opt, batch) -> (params, opt, metrics)
    param_sharding: PyTree
    opt_sharding: OptState
    batch_sharding: Dict[str, NamedSharding]
    model: Model
    plan: Any = None                  # the HierarchicalPlan the rules consumed


def make_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    train: TrainConfig = TrainConfig(),
    rules: Optional[ShardingRules] = None,
    jit: bool = True,
    plan: Optional[Any] = None,
) -> TrainStep:
    if rules is None:
        # Hierarchical planning (repro.plan): the FSDP/replicated choice
        # inside arch_rules walks the mesh hierarchy (DCN -> ICI -> VMEM)
        # once, with this step's activation share reserved as the
        # replicated phi term (see dist.sharding).  Activations shard over
        # the data axes only -- the residual stream replicates across
        # "model" -- so the reserve divides by the data extent.  Pass
        # ``plan`` to reuse a plan built elsewhere (dry-run, benchmarks)
        # instead of re-planning.
        data_n = max(1, mesh.size // dict(mesh.shape).get("model", 1))
        rules = arch_rules(
            cfg, mesh,
            act_bytes=activation_footprint(cfg, shape, train.remat) // data_n,
            plan=plan)
    rules = with_batch_guard(rules, mesh, shape.global_batch)
    rules = _apply_collectives(rules, train.collectives)
    model = build_model(cfg, remat=train.remat)
    specs = model.param_specs()
    p_shard = param_shardings(mesh, rules, specs)
    opt_shard = OptState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree.map(lambda s: s, p_shard),
        nu=jax.tree.map(lambda s: s, p_shard),
    )
    b_axes = batch_logical_axes(cfg, "train")
    b_shard = {
        k: NamedSharding(mesh, rules.act_spec(v)) for k, v in b_axes.items()
    }
    compute_dtype = _dtype(train.dtype)

    def loss_fn(params, batch):
        cast = jax.tree.map(lambda p: p.astype(compute_dtype)
                            if p.dtype == jnp.float32 else p, params)
        with use_mesh_rules(mesh, rules):
            loss, metrics = model.loss(cast, batch, dtype=compute_dtype)
        return loss, metrics

    def grads_of(params, batch):
        if train.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        # Gradient accumulation: scan over microbatches of the batch dim.
        mb = train.microbatches

        def resh(x):
            b = x.shape[0] if x.ndim and x.shape[0] != 3 else None
            return x

        def split(x, axis=0):
            return x.reshape(x.shape[:axis] + (mb, x.shape[axis] // mb)
                             + x.shape[axis + 1:])

        mb_batch = {}
        for k, v in batch.items():
            ax = 1 if k == "positions_3d" else 0
            mb_batch[k] = jnp.moveaxis(split(v, ax), ax, 0)

        def body(carry, mbatch):
            gsum, lsum = carry
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            gsum = jax.tree.map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                       mb_batch)
        grads = jax.tree.map(lambda g: g / mb, gsum)
        loss = lsum / mb
        return loss, {"nll": loss, "aux": jnp.zeros((), jnp.float32)}, grads

    def step_fn(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if train.grad_compression != "none":
            wire, scales, _ = compress_gradient(grads, train.grad_compression)
            grads = decompress_gradient(wire, train.grad_compression, scales)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, train)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    if jit:
        step_fn = jax.jit(
            step_fn,
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, None),
            donate_argnums=(0, 1),
        )
    return TrainStep(fn=step_fn, param_sharding=p_shard,
                     opt_sharding=opt_shard, batch_sharding=b_shard,
                     model=model, plan=rules.meta.get("plan"))


def init_sharded_state(ts: TrainStep, mesh: Mesh, seed: int,
                       train: TrainConfig) -> Tuple[PyTree, OptState]:
    """Initialize params + optimizer directly sharded (never materialized on
    one device)."""
    opt_dtype = _dtype(train.optimizer_dtype)

    @partial(jax.jit,
             out_shardings=(ts.param_sharding, ts.opt_sharding))
    def init(rng):
        params = ts.model.init(rng, dtype=jnp.float32)
        opt = adamw_init(params, state_dtype=opt_dtype)
        return params, opt

    return init(jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# Training loop with the FT substrate
# ---------------------------------------------------------------------------


class Trainer:
    def __init__(self, run: RunConfig, mesh: Mesh):
        from repro.ckpt import CheckpointManager
        from repro.ft import PreemptionHandler, StepWatchdog, StragglerPolicy

        self.run = run
        self.mesh = mesh
        self.ts = make_train_step(run.model, run.shape, mesh, run.train)
        self.ckpt = CheckpointManager(run.train.checkpoint_dir,
                                      keep=run.train.keep_checkpoints)
        self.preempt = PreemptionHandler().install()
        self.straggler = StragglerPolicy()
        self.watchdog = StepWatchdog(
            deadline_s=300.0,
            on_timeout=lambda step, dt: print(
                f"[ft] step {step} exceeded deadline ({dt:.1f}s)"))
        self.step = 0
        self.params = None
        self.opt = None

    # ---------------------------------------------------------------- state
    def init_or_restore(self) -> int:
        from repro.optim import adamw_init

        self.params, self.opt = init_sharded_state(
            self.ts, self.mesh, self.run.train.seed, self.run.train)
        restored, manifest = self._try_restore()
        if restored is not None:
            self.params, self.opt = restored
            self.step = manifest["step"]
            print(f"[ckpt] resumed from step {self.step}")
        return self.step

    def _try_restore(self):
        template = jax.tree.map(
            lambda x: np.zeros(x.shape, x.dtype), (self.params, self.opt))
        flat_shardings = {}

        def record(path, shard, prefix=""):
            pass

        # Reshard by name onto the current mesh (elastic restart).
        shard_tree = (self.ts.param_sharding, self.ts.opt_sharding)
        flat_s = _flatten_with_paths(shard_tree)

        def reshard(key, arr):
            s = flat_s.get(key)
            if s is None:
                return jnp.asarray(arr)
            return jax.device_put(arr, s)

        out, manifest = self.ckpt.restore_latest(template, reshard=reshard)
        return (out, manifest) if out is not None else (None, None)

    # ----------------------------------------------------------------- loop
    def fit(self, steps: int, data_iter, log_every: int = 10) -> Dict[str, list]:
        history = {"loss": [], "step_time": []}
        target = self.step + steps
        while self.step < target:
            if self.preempt.should_stop:
                print("[ft] preemption requested: final checkpoint + drain")
                self.ckpt.save(self.step, (self.params, self.opt),
                               blocking=True)
                break
            step_idx, host_batch = next(data_iter)
            batch = {
                k: jax.device_put(v, self.ts.batch_sharding.get(k))
                for k, v in host_batch.items()
            }
            self.watchdog.start_step(self.step)
            t0 = time.perf_counter()
            self.params, self.opt, metrics = self.ts.fn(
                self.params, self.opt, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.watchdog.end_step()
            self.straggler.record(0, dt)
            history["loss"].append(loss)
            history["step_time"].append(dt)
            self.step += 1
            if log_every and self.step % log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({dt * 1e3:.0f} ms)")
            if self.step % self.run.train.checkpoint_every == 0:
                self.ckpt.save(self.step, (self.params, self.opt))
        self.ckpt.wait()
        return history


def _flatten_with_paths(tree: PyTree, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_with_paths(
                v, f"{prefix}/{k}" if prefix else str(k)))
        return out
    if isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten_with_paths(
                v, f"{prefix}/{i}" if prefix else str(i)))
        return out
    if hasattr(tree, "_fields"):
        for k in tree._fields:
            out.update(_flatten_with_paths(
                getattr(tree, k), f"{prefix}/{k}" if prefix else k))
        return out
    out[prefix] = tree
    return out
