"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
JAX device state (the dry-run must set XLA_FLAGS before the first jax init).

Mesh shapes:
  single-pod:  (16, 16)    axes ("data", "model")   -- 256 chips
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model") -- 512 chips

Stage-1 of the paper's two-stage decomposition (§2): the domain is first
split across the distributed system (this mesh), then within each chip by
the cache-conscious decomposer (stage 2, ``core.autotile``).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (smoke tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
