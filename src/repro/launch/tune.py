"""``repro-tune`` -- run the empirical tuning sweeps end to end.

``python -m repro.launch.tune [--quick] [--dry] [--kernels a,b] ...``
(also installed as the ``repro-tune`` console script).  For each kernel the
harness takes the planner's analytic block as the sweep center, enumerates
the aligned power-of-two neighborhood, VMEM-filters it with the planner's
own working-set model, times the survivors (warmup + ``block_until_ready``
medians; Pallas interpret mode on CPU), and merges the winners into
``experiments/tuning.json`` -- which the planner then consults with
precedence analytic < tuned.

``--dry`` stops after enumeration + filtering (no jax, no timing): the CI
smoke asserts every candidate respects the level budget.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    from repro.tune.cache import hw_fingerprint, tuning_path
    from repro.tune.sweep import SWEEPS, run_sweeps

    ap = argparse.ArgumentParser(
        prog="repro-tune",
        description="neighborhood sweep around the plan's analytic tiles")
    ap.add_argument("--kernels", default="all",
                    help=f"comma-separated subset of {','.join(SWEEPS)} "
                         f"or 'all'")
    ap.add_argument("--quick", action="store_true",
                    help="smaller stock workloads (CI-sized)")
    ap.add_argument("--dry", action="store_true",
                    help="enumerate + VMEM-filter only; no timing, no "
                         "artifact write")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default=None,
                    help="artifact path (default: the REPRO_TUNING env "
                         "override, else experiments/tuning.json)")
    ap.add_argument("--no-write", action="store_true",
                    help="time the sweep but do not persist winners")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])

    kernels = (None if args.kernels == "all"
               else [k.strip() for k in args.kernels.split(",") if k.strip()])
    results = run_sweeps(kernels=kernels, quick=args.quick, dry=args.dry,
                         warmup=args.warmup, iters=args.iters,
                         out_path=args.out, write=not args.no_write)

    all_fit = True
    for r in results:
        print(f"[tune] {r.kernel} bucket={r.bucket} center={r.center} "
              f"candidates={len(r.candidates)} rejected={r.rejected} "
              f"budget={r.budget_bytes}")
        for c in sorted(r.candidates, key=lambda c: c.label):
            fit_ok = c.est_vmem_bytes <= r.budget_bytes
            all_fit &= fit_ok
            tm = f"{c.median_us:10.1f}us" if c.median_us is not None else \
                "      (dry)"
            mark = " <- analytic" if c.block == r.center else ""
            print(f"[tune]   {c.label:<40s} est={c.est_vmem_bytes:>10d} "
                  f"{tm}{mark}")
        if r.entry is not None:
            e = r.entry
            print(f"[tune]   winner {dict(e.block)} median={e.median_us}us "
                  f"analytic={e.analytic_us}us speedup={e.speedup}x")
    print(f"[tune] all_candidates_fit_vmem={all_fit}")
    if args.dry:
        print("[tune] dry run: nothing timed, nothing written")
    elif args.no_write:
        print("[tune] --no-write: winners not persisted")
    else:
        print(f"[tune] wrote {args.out or tuning_path()} "
              f"(fingerprint {hw_fingerprint()})")
    return 0 if all_fit else 1


if __name__ == "__main__":
    sys.exit(main())
