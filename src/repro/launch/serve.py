"""Serving launcher: batched prefill + decode with the family-appropriate
cache. ``python -m repro.launch.serve --arch <id> --tokens 32``."""

from __future__ import annotations

import sys
import time


def main(argv=None) -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_model_config, parse_cli
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import make_batch
    from repro.launch.trainer import make_serve_steps

    overrides, _ = parse_cli(argv if argv is not None else sys.argv[1:])
    arch = overrides.pop("arch", "llama3.2-1b")
    n_new = int(overrides.pop("tokens", "16"))
    batch = int(overrides.pop("batch", "4"))
    prompt_len = int(overrides.pop("prompt_len", "64"))

    cfg = get_model_config(arch).reduced()
    shape = ShapeConfig("serve", prompt_len, batch, "decode")
    mesh = make_host_mesh()
    ss = make_serve_steps(cfg, shape, mesh, dtype=jnp.float32,
                          max_len_extra=n_new + 1)

    rng = np.random.default_rng(0)
    params = ss.model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    prompt = make_batch(cfg, shape, rng, kind="train")
    prompt.pop("labels", None)

    t0 = time.perf_counter()
    logits, cache = ss.prefill(params, prompt)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    t0 = time.perf_counter()
    for i in range(n_new):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        step = {"tokens": nxt}
        if cfg.family == "vlm":
            step["positions_3d"] = jnp.broadcast_to(
                cache["pos"][None, None, None], (3, batch, 1)).astype(jnp.int32)
        logits, cache = ss.decode(params, cache, step)
        toks.append(np.asarray(nxt[:, 0]))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    print(f"[serve] arch={arch} batch={batch} prompt={prompt_len}")
    print(f"[serve] prefill {t_prefill * 1e3:.1f} ms; "
          f"decode {t_decode / n_new * 1e3:.2f} ms/token "
          f"({batch * n_new / t_decode:.1f} tok/s)")
    print(f"[serve] sample continuation ids: {[int(t[0]) for t in toks[:8]]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
