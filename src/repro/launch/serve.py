"""Serving launcher: a thin CLI over ``repro.serve.ServeEngine``.

``python -m repro.launch.serve --arch <id> --tokens 32`` (also installed as
the ``repro-serve`` console script).  Every batch/page/shard choice falls
out of the hierarchical planner's decode workload (DESIGN.md §7/§8): the
CLI only names the architecture, the prompt mix, the sampling config, and
``--batching {cohort,paged,auto}`` -- "auto" (default) picks the paged
page-pool engine whenever the decode plan exposes a page level (and the
family has a per-slot decode path), falling back to cohort batching.
``--prefix {off,radix}`` turns on the cross-request radix prefix cache
(DESIGN.md §11) in the paged engine.
"""

from __future__ import annotations

import sys
import time


def main(argv=None) -> int:
    import jax
    import numpy as np

    from repro.configs import get_model_config, parse_cli
    from repro.launch.mesh import make_host_mesh
    from repro.serve import SamplingConfig, ServeEngine, ServePolicy

    overrides, _ = parse_cli(argv if argv is not None else sys.argv[1:])
    arch = overrides.pop("arch", "llama3.2-1b")
    n_new = int(overrides.pop("tokens", "16"))
    batch = int(overrides.pop("batch", "4"))
    prompt_len = int(overrides.pop("prompt_len", "64"))
    mixed = overrides.pop("mixed", "0").lower() in ("1", "true", "yes")
    kind = overrides.pop("sampling", "greedy")
    temperature = float(overrides.pop("temperature", "1.0"))
    top_k = int(overrides.pop("top_k", "0"))
    seed = int(overrides.pop("seed", "0"))
    batching = overrides.pop("batching", "auto")
    prefill = overrides.pop("prefill", "chunked")
    prefix = overrides.pop("prefix", "off")

    cfg = get_model_config(arch).reduced()
    sampling = SamplingConfig(kind=kind, temperature=temperature,
                              top_k=top_k or (40 if kind == "top_k" else 0),
                              seed=seed)
    if batching not in ("cohort", "paged", "auto"):
        raise SystemExit(f"--batching must be cohort|paged|auto, "
                         f"got {batching!r}")
    if prefill not in ("chunked", "monolithic"):
        raise SystemExit(f"--prefill must be chunked|monolithic, "
                         f"got {prefill!r}")
    if prefix not in ("off", "radix"):
        raise SystemExit(f"--prefix must be off|radix, got {prefix!r}")
    # "auto" resolves inside ServeEngine against its own decode plan:
    # paged exactly when the plan exposes a page level and the family has
    # a per-slot decode path; ``--batching cohort`` keeps the PR 4 engine
    # as the A/B baseline.
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=n_new, max_slots=max(1, batch),
                           max_len=prompt_len + n_new + 1,
                           batching=batching, prefill=prefill,
                           prefix_cache=prefix, sampling=sampling),
        dtype=jax.numpy.float32)

    rng = np.random.default_rng(seed)
    prompts = []
    for i in range(batch):
        plen = prompt_len if not mixed else max(8, prompt_len // (1 + i % 2))
        prompts.append(engine_prompt(cfg, plen, rng))

    t0 = time.perf_counter()
    outs = engine.generate(prompts)
    dt = time.perf_counter() - t0

    n_tok = sum(len(o) for o in outs)
    m = engine.metrics
    print(f"[serve] arch={arch} requests={batch} prompt={prompt_len}"
          f"{' (mixed)' if mixed else ''} sampling={kind} "
          f"batching={m['batching']}")
    print(f"[serve] plan: page_tokens={m['page_tokens']} "
          f"page_bytes={m['page_bytes']} kv_shard={m['kv_shard']} "
          f"budget={m['budget_bytes'] / 2**30:.1f}GiB")
    print(f"[serve] {n_tok} tokens in {dt:.2f}s ({n_tok / max(dt, 1e-9):.1f} "
          f"tok/s); cohorts={m['cohorts']} decode_steps={m['decode_steps']} "
          f"evictions={m['evictions']} "
          f"prefill_chunks={m.get('prefill_chunks', 0)} "
          f"slot_utilization={m.get('slot_utilization', 0.0):.2f} "
          f"backfills={m.get('backfills', 0)} "
          f"peak_resident={m.get('peak_resident_bytes', 0)}B")
    if m.get("prefix_cache") == "radix":
        print(f"[serve] prefix: hits={m.get('prefix_hits', 0)} "
              f"hit_tokens={m.get('prefix_hit_tokens', 0)} "
              f"pages_saved={m.get('pages_saved', 0)} "
              f"cow_copies={m.get('cow_copies', 0)} "
              f"hit_rate={m.get('prefix_hit_rate', 0.0):.2f} "
              f"resident_pages={m.get('prefix_resident_pages', 0)} "
              f"budget={m.get('prefix_budget_bytes', 0)}B")
    print(f"[serve] sample continuation ids: {outs[0][:8]}")
    return 0


def engine_prompt(cfg, prompt_len: int, rng):
    """A synthetic prompt in the family's input format (frontend stubs per
    the assignment: VLM/audio cells receive precomputed embeddings)."""
    import numpy as np

    if cfg.family == "vlm":
        return {
            "embeds": (rng.standard_normal((prompt_len, cfg.d_model))
                       .astype(np.float32) * 0.02),
            "positions_3d": np.broadcast_to(
                np.arange(prompt_len, dtype=np.int32)[None], (3, prompt_len)),
        }
    if cfg.family == "enc_dec":
        return {
            "enc_embeds": (rng.standard_normal((prompt_len, cfg.d_model))
                           .astype(np.float32) * 0.02),
            "tokens": rng.integers(0, cfg.vocab_size, prompt_len,
                                   dtype=np.int32),
        }
    return rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)


if __name__ == "__main__":
    sys.exit(main())
