"""Serving launcher: a thin CLI over ``repro.serve.ServeEngine``.

``python -m repro.launch.serve --arch <id> --tokens 32`` (also installed as
the ``repro-serve`` console script).  Every batch/page/shard choice falls
out of the hierarchical planner's decode workload (DESIGN.md §7/§8): the
CLI only names the architecture, the prompt mix, the sampling config, and
``--batching {cohort,paged,auto}`` -- "auto" (default) picks the paged
page-pool engine whenever the decode plan exposes a page level (and the
family has a per-slot decode path), falling back to cohort batching.
``--prefix {off,radix}`` turns on the cross-request radix prefix cache
(DESIGN.md §11) in the paged engine.

``--cluster N`` serves through ``repro.cluster`` instead of one engine
(DESIGN.md §12): ``plan_decode(cluster=N)`` grows a DCN level whose
realized ``np`` is the fleet width, N replica hosts stand up behind a
router (``--policy {free_pages,least_loaded,round_robin}``), and
``--serve`` additionally binds the streaming HTTP front end
(``--port``).  ``--disagg P:D`` splits the fleet into prefill and
decode roles with ring-ordered KV page streaming between them
(``--cluster`` total must equal P+D); page transfer needs the prompt to
span at least one planned page, so at reduced scale pass
``--vmem_kib 16`` to force a small page.
"""

from __future__ import annotations

import sys
import time


def main(argv=None) -> int:
    import jax
    import numpy as np

    from repro.configs import get_model_config, parse_cli
    from repro.launch.mesh import make_host_mesh
    from repro.serve import SamplingConfig, ServeEngine, ServePolicy

    overrides, _ = parse_cli(argv if argv is not None else sys.argv[1:])
    arch = overrides.pop("arch", "llama3.2-1b")
    n_new = int(overrides.pop("tokens", "16"))
    batch = int(overrides.pop("batch", "4"))
    prompt_len = int(overrides.pop("prompt_len", "64"))
    mixed = overrides.pop("mixed", "0").lower() in ("1", "true", "yes")
    kind = overrides.pop("sampling", "greedy")
    temperature = float(overrides.pop("temperature", "1.0"))
    top_k = int(overrides.pop("top_k", "0"))
    seed = int(overrides.pop("seed", "0"))
    batching = overrides.pop("batching", "auto")
    prefill = overrides.pop("prefill", "chunked")
    prefix = overrides.pop("prefix", "off")
    cluster = int(overrides.pop("cluster", "0"))
    disagg = overrides.pop("disagg", "")
    policy = overrides.pop("policy", "free_pages")
    serve_http = overrides.pop("serve", "0").lower() in ("1", "true", "yes")
    port = int(overrides.pop("port", "8480"))
    transport = overrides.pop("transport", "thread")
    vmem_kib = int(overrides.pop("vmem_kib", "0"))
    trace_path = overrides.pop("trace", "")
    metrics_interval = float(overrides.pop("metrics_interval", "0"))
    show_stats = overrides.pop("stats", "0").lower() in ("1", "true",
                                                         "yes")

    cfg = get_model_config(arch).reduced()
    sampling = SamplingConfig(kind=kind, temperature=temperature,
                              top_k=top_k or (40 if kind == "top_k" else 0),
                              seed=seed)
    if batching not in ("cohort", "paged", "auto"):
        raise SystemExit(f"--batching must be cohort|paged|auto, "
                         f"got {batching!r}")
    if prefill not in ("chunked", "monolithic"):
        raise SystemExit(f"--prefill must be chunked|monolithic, "
                         f"got {prefill!r}")
    if prefix not in ("off", "radix"):
        raise SystemExit(f"--prefix must be off|radix, got {prefix!r}")
    if cluster or disagg:
        return _main_cluster(
            arch=arch, cfg=cfg, n_new=n_new, batch=batch,
            prompt_len=prompt_len, seed=seed, prefix=prefix or "radix",
            cluster=cluster, disagg=disagg, policy=policy,
            serve_http=serve_http, port=port, transport=transport,
            vmem_kib=vmem_kib, trace_path=trace_path,
            show_stats=show_stats)
    # "auto" resolves inside ServeEngine against its own decode plan:
    # paged exactly when the plan exposes a page level and the family has
    # a per-slot decode path; ``--batching cohort`` keeps the PR 4 engine
    # as the A/B baseline.
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=n_new, max_slots=max(1, batch),
                           max_len=prompt_len + n_new + 1,
                           batching=batching, prefill=prefill,
                           prefix_cache=prefix, sampling=sampling),
        dtype=jax.numpy.float32)

    rng = np.random.default_rng(seed)
    prompts = []
    for i in range(batch):
        plen = prompt_len if not mixed else max(8, prompt_len // (1 + i % 2))
        prompts.append(engine_prompt(cfg, plen, rng))

    stop_metrics = None
    if metrics_interval > 0:
        stop_metrics = _metrics_ticker(engine.obs, metrics_interval)
    t0 = time.perf_counter()
    try:
        outs = engine.generate(prompts)
    finally:
        if stop_metrics is not None:
            stop_metrics()
    dt = time.perf_counter() - t0

    n_tok = sum(len(o) for o in outs)
    m = engine.metrics
    print(f"[serve] arch={arch} requests={batch} prompt={prompt_len}"
          f"{' (mixed)' if mixed else ''} sampling={kind} "
          f"batching={m['batching']}")
    print(f"[serve] plan: page_tokens={m['page_tokens']} "
          f"page_bytes={m['page_bytes']} kv_shard={m['kv_shard']} "
          f"budget={m['budget_bytes'] / 2**30:.1f}GiB")
    print(f"[serve] {n_tok} tokens in {dt:.2f}s ({n_tok / max(dt, 1e-9):.1f} "
          f"tok/s); cohorts={m['cohorts']} decode_steps={m['decode_steps']} "
          f"evictions={m['evictions']} "
          f"prefill_chunks={m.get('prefill_chunks', 0)} "
          f"slot_utilization={m.get('slot_utilization', 0.0):.2f} "
          f"backfills={m.get('backfills', 0)} "
          f"peak_resident={m.get('peak_resident_bytes', 0)}B")
    if m.get("prefix_cache") == "radix":
        print(f"[serve] prefix: hits={m.get('prefix_hits', 0)} "
              f"hit_tokens={m.get('prefix_hit_tokens', 0)} "
              f"pages_saved={m.get('pages_saved', 0)} "
              f"cow_copies={m.get('cow_copies', 0)} "
              f"hit_rate={m.get('prefix_hit_rate', 0.0):.2f} "
              f"resident_pages={m.get('prefix_resident_pages', 0)} "
              f"budget={m.get('prefix_budget_bytes', 0)}B")
    print(f"[serve] sample continuation ids: {outs[0][:8]}")
    if trace_path:
        engine.tracer.export_chrome(trace_path)
        print(f"[serve] trace: {len(engine.tracer.export_events())} events"
              f" -> {trace_path} (chrome://tracing / ui.perfetto.dev)")
    if show_stats:
        # The registry's formatted snapshot (DESIGN.md §13): sorted
        # keys, units annotated -- identical shape across cohort, paged
        # and cluster modes.
        print("[serve] metrics registry:")
        print(engine.obs.format_table())
    return 0


def _metrics_ticker(registry, interval_s: float):
    """Print the registry snapshot every ``interval_s`` on a daemon
    thread (``--metrics-interval``); returns a stop() callable."""
    import threading

    stop = threading.Event()

    def run():
        n = 0
        while not stop.wait(interval_s):
            n += 1
            snap = registry.snapshot()
            keys = ("tokens", "decode_steps", "prefill_chunks",
                    "free_pages", "used_pages", "evictions", "stalls")
            line = " ".join(f"{k}={snap[k]}" for k in keys if k in snap)
            print(f"[metrics t+{n * interval_s:.1f}s] {line}")

    threading.Thread(target=run, name="metrics-ticker",
                     daemon=True).start()
    return stop.set


def _main_cluster(*, arch, cfg, n_new, batch, prompt_len, seed, prefix,
                  cluster, disagg, policy, serve_http, port, transport,
                  vmem_kib=0, trace_path="", show_stats=False) -> int:
    """``repro-serve --cluster N [--disagg P:D] [--serve]``: the fleet
    width comes from the plan's DCN level, each replica hosts one
    single-host ``ServeEngine``, the router places by ``--policy``."""
    import numpy as np

    from repro.cluster import (ClusterServer, DisaggCluster, EngineSpec,
                               ServeCluster)
    from repro.hw.tpu import chip_spec
    from repro.launch.mesh import make_host_mesh
    from repro.serve.engine import plan_decode

    if disagg:
        p, d = (int(x) for x in disagg.split(":"))
        cluster = cluster or (p + d)
    chip = (("vmem_bytes", vmem_kib << 10),
            ("vmem_reserved_bytes", 0)) if vmem_kib else ()
    # Engines run float32 (EngineSpec), so plan with their KV width --
    # the guard below compares against the geometry they will realize.
    plan = plan_decode(cfg, make_host_mesh(),
                       max_len=prompt_len + n_new + 1, dtype_bytes=4,
                       spec=chip_spec(**dict(chip)),
                       cluster=max(1, cluster))
    dcn = plan.level("DCN")
    page_tokens = int((plan.page_plan() or {}).get("page_tokens", 0) or 0)
    if disagg and page_tokens and prompt_len < page_tokens:
        # Disaggregation streams COMPLETED pages; a prompt inside one
        # page has nothing to export.  At reduced scale the default
        # chip's VMEM page covers the whole sequence, so the demo needs
        # a forced-small page.
        raise SystemExit(
            f"--disagg needs the prompt to span >= 1 planned page, but "
            f"page_tokens={page_tokens} > prompt_len={prompt_len}; "
            f"raise --prompt_len or shrink the page with --vmem_kib 16")
    spec = EngineSpec(arch=arch, max_new_tokens=n_new, max_slots=1,
                      max_len=prompt_len + n_new + 1,
                      prefix_cache="radix" if prefix == "off" else prefix,
                      chip=chip)
    print(f"[cluster] arch={arch} replicas={plan.replicas()} "
          f"(DCN np={dcn.np if dcn else 1}) policy={policy} "
          f"transport={transport}"
          + (f" disagg={disagg}" if disagg else ""))
    if disagg:
        front = DisaggCluster.from_plan(plan, spec, split=disagg,
                                        transport=transport, policy=policy)
    else:
        front = ServeCluster.from_plan(plan, spec, transport=transport,
                                       policy=policy)
    try:
        if serve_http:
            if disagg:
                raise SystemExit("--serve fronts a ServeCluster; run "
                                 "--disagg without --serve (the HTTP "
                                 "front end routes whole requests)")
            srv = ClusterServer(front, port=port).start()
            host, bound = srv.address
            print(f"[cluster] serving on http://{host}:{bound} "
                  f"(/generate /healthz /stats); ctrl-c to stop")
            try:
                srv.serve_forever()
            except KeyboardInterrupt:
                pass
            finally:
                srv.close()
            return 0
        rng = np.random.default_rng(seed)
        prompts = [rng.integers(0, cfg.vocab_size, prompt_len,
                                dtype=np.int32).tolist()
                   for _ in range(batch)]
        import time as _time

        t0 = _time.perf_counter()
        if disagg:
            outs = [front.generate(p, n_new) for p in prompts]
        else:
            outs = front.generate(prompts, n_new)
        dt = _time.perf_counter() - t0
        n_tok = sum(len(o) for o in outs)
        for st in front.stats():
            print(f"[cluster] replica {st.replica} role={st.role} "
                  f"free_pages={st.free_pages}/{st.pages_total} "
                  f"slots={st.slots_free}/{st.slots_total} "
                  f"prefix_nodes={st.prefix_nodes} tokens={st.tokens}")
        print(f"[cluster] {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / max(dt, 1e-9):.1f} tok/s)")
        print(f"[cluster] sample continuation ids: {outs[0][:8]}")
        if trace_path and hasattr(front, "trace_events"):
            from repro.obs import write_chrome
            evs = front.trace_events()
            write_chrome(trace_path, evs)
            pids = sorted({e.get("pid") for e in evs
                           if e.get("ph") != "M"})
            print(f"[cluster] trace: {len(evs)} events from pids {pids} "
                  f"-> {trace_path} (one timeline; pid = replica id, "
                  f"pid {len(front.replicas)} = router)")
        if show_stats:
            for st in front.stats():
                if not st.metrics:
                    continue
                print(f"[cluster] replica {st.replica} metrics registry:")
                for k in sorted(st.metrics):
                    v = st.metrics[k]
                    if isinstance(v, float):
                        v = f"{v:.6g}"
                    print(f"  {k} {v}")
    finally:
        front.close()
    return 0


def engine_prompt(cfg, prompt_len: int, rng):
    """A synthetic prompt in the family's input format (frontend stubs per
    the assignment: VLM/audio cells receive precomputed embeddings)."""
    import numpy as np

    if cfg.family == "vlm":
        return {
            "embeds": (rng.standard_normal((prompt_len, cfg.d_model))
                       .astype(np.float32) * 0.02),
            "positions_3d": np.broadcast_to(
                np.arange(prompt_len, dtype=np.int32)[None], (3, prompt_len)),
        }
    if cfg.family == "enc_dec":
        return {
            "enc_embeds": (rng.standard_normal((prompt_len, cfg.d_model))
                           .astype(np.float32) * 0.02),
            "tokens": rng.integers(0, cfg.vocab_size, prompt_len,
                                   dtype=np.int32),
        }
    return rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)


if __name__ == "__main__":
    sys.exit(main())
