"""Input specifications for every (architecture x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for each model
input (weak-type-correct, shardable, no device allocation) -- used by the
dry-run's ``.lower()``; ``make_batch`` materializes real arrays of the same
structure for smoke tests and the training examples.

Modality frontends are stubs per the assignment: VLM cells receive
precomputed patch embeddings + M-RoPE position ids; audio cells receive
precomputed frame embeddings.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        return {
            "embeds": _sds((b, s, cfg.d_model), dtype),
            "positions_3d": _sds((3, b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
    if cfg.family == "enc_dec":
        return {
            "enc_embeds": _sds((b, s, cfg.d_model), dtype),
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
    return {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b = shape.global_batch
    out: Dict[str, Any] = {"tokens": _sds((b, 1), jnp.int32)}
    if cfg.family == "vlm":
        # Decode generates text tokens; M-RoPE positions for the new token.
        out["positions_3d"] = _sds((3, b, 1), jnp.int32)
        out.pop("tokens")
        out["tokens"] = _sds((b, 1), jnp.int32)
    return out


def batch_logical_axes(cfg: ModelConfig, kind: str) -> Dict[str, Tuple]:
    """Logical activation axes of each batch input (for in_shardings)."""
    if kind == "decode":
        axes = {"tokens": ("batch", None)}
        if cfg.family == "vlm":
            axes["positions_3d"] = (None, "batch", None)
        return axes
    if cfg.family == "vlm":
        return {
            "embeds": ("batch", "seq", "embed"),
            "positions_3d": (None, "batch", "seq"),
            "labels": ("batch", "seq"),
        }
    if cfg.family == "enc_dec":
        return {
            "enc_embeds": ("batch", "seq", "embed"),
            "tokens": ("batch", "seq"),
            "labels": ("batch", "seq"),
        }
    return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}


def cache_logical_axes(cfg: ModelConfig, cache: Any, long_context: bool) -> Any:
    """Logical axes pytree matching ``Model.init_cache`` output.

    The KV-cache sequence dim is sharded over "model" (sequence parallelism)
    for long-context decode, where the cache dominates memory.
    """
    seq_ax = "kv_seq" if long_context else None

    def axes_for(path: Tuple[str, ...], leaf) -> Tuple:
        name = path[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, S, KV, hd); under sequence parallelism the model axis
            # shards the sequence dim, so heads must stay unsharded.
            head_ax = None if long_context else "kv_heads"
            return ("layers", "batch", seq_ax, head_ax, None)[:nd] if nd == 5 \
                else (None,) * nd
        if name in ("ckv", "krope"):
            return ("layers", "batch", seq_ax, None)
        if name == "conv":
            return ("layers", "batch", None, "mlp")
        if name == "ssm":
            return ("layers", "batch", "state_heads", None, None)
        if name == "C":
            # mLSTM matrix state (L, B, H, dh, dh): few state heads (H =
            # n_heads, e.g. 4) rarely fill the model axis, so the rules may
            # move TP to the per-head state dim instead ("state_inner" --
            # sub-axis sharding, see dist.sharding.arch_rules).
            return ("layers", "batch", "state_heads", "state_inner", None)
        if name in ("n", "c", "h", "m"):
            return (("layers", "batch", "state_heads", "state_inner")[:nd])
        if name in ("len",):
            return (None,) * nd
        if name == "pos":
            return ()
        return (None,) * nd

    def walk(node, path=()):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        return axes_for(path, node)

    return walk(cache)


def activation_footprint(cfg: ModelConfig, shape: ShapeConfig,
                         remat: str = "full", dtype_bytes: int = 2) -> int:
    """Rough global activation working-set bytes for one step.

    Fed (divided by the chip count) into the mesh-level decomposer as the
    *replicated* term of the phi_mesh domain: activations shard over the
    batch axes, not over the FSDP partition count the search is choosing,
    so they reserve HBM that parameter shards cannot use.  Counts the
    residual stream per resident layer (all layers without remat, ~sqrt(L)
    checkpoints with it), a 4x block working-set factor (qkv/ffn
    intermediates), and the fp32 logits buffer.

    The whole estimate is scaled by the measured per-arch ``act_scale``
    from the calibration artifact when present
    (``launch/dryrun.py --calibrate`` fits the replicated term against the
    lowered-HLO residual exactly like it fits ``ModelConfig.overhead``);
    without an artifact the model above stands as-is.
    """
    from repro.configs.base import calibration_act_scale

    # "full" remat keeps ~sqrt(L) checkpoints resident; "none" keeps every
    # layer, and "dots" saves all dot outputs across all L layers, so both
    # count the full depth.
    resident_layers = (max(2, int(math.isqrt(max(1, cfg.n_layers))))
                       if remat == "full" else cfg.n_layers)
    tokens = shape.global_batch * shape.seq_len
    stream = tokens * cfg.d_model * dtype_bytes * resident_layers * 4
    logits = tokens * cfg.vocab_size * 4
    scale = calibration_act_scale(getattr(cfg, "arch", "")) or 1.0
    return int((stream + logits) * scale)


def overlap_wire_bytes(m: int, k: int, n: int, p: int, kind: str = "ag",
                       mode: str = "ring", dtype_bytes: int = 2) -> int:
    """Per-ring-step bytes one ICI link carries for a ``(m, k) @ (k, n)``
    projection under the overlap layer (DESIGN.md §5).

    The hopping payload differs by kernel: the all-gather ring forwards the
    resident ``(m, k/p)`` activation chunk, the reduce-scatter ring the
    ``(m/p, n)`` partial-sum accumulator.  The serpentine schedule splits
    either across both link directions, halving the per-link payload --
    the quantity the §Perf A/B in ``benchmarks/run.py`` reports next to
    its measured step times.  For a model's residual projection,
    ``m = global_batch * seq_len`` and ``k = d_model``.
    """
    p = max(1, p)
    if kind == "ag":
        payload = m * (k // p) * dtype_bytes
    elif kind == "rs":
        payload = (m // p) * n * dtype_bytes
    else:
        raise ValueError(f"kind must be 'ag' or 'rs', got {kind!r}")
    return payload // 2 if mode == "serpentine" else payload


def decode_footprint(cfg: ModelConfig, shape: ShapeConfig, max_len: int,
                     dtype_bytes: int = 2) -> int:
    """Rough global serving working-set bytes: the KV cache (the dominant
    term -- latent for MLA, K+V heads otherwise) plus one layer's streaming
    activations.  No backprop stash, no logits buffer held across steps."""
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
    cache = shape.global_batch * max_len * per_tok * dtype_bytes * cfg.n_layers
    stream = shape.global_batch * shape.seq_len * cfg.d_model * dtype_bytes * 4
    return cache + stream


def make_batch(cfg: ModelConfig, shape: ShapeConfig, rng: np.random.Generator,
               dtype=jnp.bfloat16, kind: str = "train") -> Dict[str, Any]:
    """Materialize a real batch matching the specs (smoke tests/examples)."""
    b, s = shape.global_batch, shape.seq_len
    if kind == "decode":
        out = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)}
        if cfg.family == "vlm":
            out["positions_3d"] = jnp.zeros((3, b, 1), jnp.int32)
        return out
    if cfg.family == "vlm":
        return {
            "embeds": jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)), dtype) * 0.02,
            "positions_3d": jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32),
        }
    if cfg.family == "enc_dec":
        return {
            "enc_embeds": jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)), dtype) * 0.02,
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32),
        }
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return {"tokens": tokens, "labels": labels}
