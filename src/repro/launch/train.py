"""Training launcher: ``python -m repro.launch.train --arch <id> [opts]``.

Runs the full production loop on whatever devices the host exposes (the
512-chip mesh is exercised by ``dryrun.py``; this entry point trains for
real on the local mesh): sharded init or elastic restore, prefetching data
pipeline, async checkpoints, preemption drain, straggler watchdog.
"""

from __future__ import annotations

import sys


def main(argv=None) -> int:
    from repro.configs import (
        MeshConfig,
        RunConfig,
        TrainConfig,
        apply_overrides,
        get_model_config,
        get_shape,
        parse_cli,
    )
    from repro.configs.base import ShapeConfig

    overrides, _ = parse_cli(argv if argv is not None else sys.argv[1:])
    arch = overrides.pop("arch", "qwen2-0.5b")
    shape_name = overrides.pop("shape", "train_4k")
    reduced = overrides.pop("reduced", "true").lower() in ("1", "true", "yes")
    steps = int(overrides.pop("steps", "200"))
    seq_len = int(overrides.pop("seq_len", "256"))
    batch = int(overrides.pop("batch", "8"))

    cfg = get_model_config(arch)
    if reduced:
        cfg = cfg.reduced()
        shape = ShapeConfig(shape_name, seq_len, batch, "train")
    else:
        shape = get_shape(shape_name)

    run = RunConfig(model=cfg, shape=shape, train=TrainConfig(
        total_steps=steps, remat="none" if reduced else "full"))
    for k, v in list(overrides.items()):
        run = apply_overrides(run, {k: v})

    import jax

    from repro.data import DataPipeline, SyntheticLMDataset
    from repro.launch.mesh import make_host_mesh
    from repro.launch.trainer import Trainer

    mesh = make_host_mesh()
    print(f"[train] arch={arch} reduced={reduced} mesh={mesh.shape} "
          f"params={cfg.param_count() / 1e6:.1f}M")
    trainer = Trainer(run, mesh)
    start = trainer.init_or_restore()

    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                            seed=run.train.seed)
    pipe = DataPipeline(ds, global_batch=shape.global_batch,
                        start_step=start)
    try:
        history = trainer.fit(steps - start, iter(pipe))
    finally:
        pipe.close()
    if history["loss"]:
        print(f"[train] loss {history['loss'][0]:.3f} -> "
              f"{history['loss'][-1]:.3f} over {len(history['loss'])} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
