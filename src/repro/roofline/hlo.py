"""HLO text analysis with while-loop trip-count correction.

``compiled.cost_analysis()`` counts each while-loop body exactly ONCE, so a
scanned 60-layer model reports ~1 layer of FLOPs. This module re-derives the
three roofline quantities directly from the optimized HLO text:

  * dot/convolution FLOPs        (x trip count of every enclosing loop)
  * HBM traffic estimate          = operand + output bytes of top-level
    (fusion-boundary) instructions -- fusion internals live in
    registers/VMEM, buffers crossing fusion boundaries live in HBM
  * collective bytes by op type  (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), operand bytes x trip count

Trip counts are recovered from each while condition's comparison constant
(scans lower to ``iv < N``). All quantities are *per device* -- the analyzed
program is the SPMD-partitioned per-device module.

Operands in optimized HLO are bare instruction names; shapes are resolved
through a per-computation symbol table built from the defining lines.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(s: str) -> int:
    """Total bytes of a type string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _first_shape_dims(s: str) -> List[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    operands: List[str]
    attrs: str
    raw: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)   # name -> out type


# Header: `%name (params...) -> type {` possibly prefixed with ENTRY.
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_DEF_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w.\-]+)")


def _parse_instr_line(line: str) -> Optional[Tuple[str, str, str, str]]:
    """-> (name, out_type, opcode, rest-after-opcode-paren) or None.

    Handles tuple types with nested parens and `/*index=N*/` comments.
    """
    m = _DEF_HEAD.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":
        depth = 0
        j = i
        while j < len(line):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        out_type = line[i: j + 1]
        k = j + 1
    else:
        tm = re.match(r"[\w]+\[[\d,]*\](?:\{[^}]*\})?", line[i:])
        if not tm:
            return None
        out_type = tm.group(0)
        k = i + tm.end()
    om = _OPCODE.match(line[k:])
    if not om:
        return None
    opcode = om.group(1)
    rest = line[k + om.end():]
    return name, out_type, opcode, rest


def parse_hlo(text: str):
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None or stripped.endswith("{"):
            m = _COMP_HDR.match(stripped)
            if m and " = " not in stripped.split("->")[0]:
                cur = Computation(m.group(2), is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if not parsed:
            continue
        name, out_type, opcode, rest = parsed
        depth, idx = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    idx = i
                    break
        operand_str = rest[:idx]
        attrs = rest[idx + 1:]
        operands = _OPERAND.findall(operand_str)
        ins = Instr(name, out_type, opcode, operands, attrs, line)
        cur.instrs.append(ins)
        cur.types[name] = out_type
    return comps, entry


def _operand_types(ins: Instr, comp: Computation) -> List[str]:
    return [comp.types.get(op, "") for op in ins.operands]


def _called(ins: Instr) -> List[Tuple[str, str]]:
    out = []
    for role in ("condition", "body", "calls", "to_apply",
                 "true_computation", "false_computation",
                 "branch_computations"):
        for m in re.finditer(role + r"=\{?%?([\w.\-]+)", ins.attrs):
            out.append((role, m.group(1)))
    seen, res = set(), []
    for r in out:
        if r not in seen:
            seen.add(r)
            res.append(r)
    return res


def _max_int_constant(comp: Computation, comps) -> int:
    best = 0
    for ins in comp.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
        for _, c in _called(ins):
            if c in comps:
                best = max(best, _max_int_constant(comps[c], comps))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = _first_shape_dims(ins.out_type)
    types = _operand_types(ins, comp)
    if not types or not types[0]:
        return 0.0
    lhs_dims = _first_shape_dims(types[0])
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contracted = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            if d != "" and int(d) < len(lhs_dims):
                contracted *= lhs_dims[int(d)]
    return 2.0 * _elems(out_dims) * contracted


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_dims = _first_shape_dims(ins.out_type)
    types = _operand_types(ins, comp)
    if len(types) < 2 or not types[1]:
        return 0.0
    rhs_dims = _first_shape_dims(types[1])
    per_out = 1
    for d in rhs_dims[:-1]:
        per_out *= d
    return 2.0 * _elems(out_dims) * per_out


def _fusion_root_is_dus(ins: Instr, comps) -> bool:
    for role, c in _called(ins):
        if role == "calls" and c in comps:
            instrs = comps[c].instrs
            if instrs and instrs[-1].opcode == "dynamic-update-slice":
                return True
    return False


_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _fusion_operand_bytes(ins: Instr, comp: Computation, comps) -> float:
    """Charged operand traffic of a fusion: an operand whose fused-side
    parameter is consumed ONLY by (dynamic-)slice/gather ops is read at the
    slices' sizes, not the full buffer (e.g. the per-layer dynamic-slice of
    scan-stacked params/saved activations -- charging the full stack per
    trip would overcount by the layer count)."""
    fused = None
    for role, c in _called(ins):
        if role == "calls" and c in comps:
            fused = comps[c]
            break
    op_types = _operand_types(ins, comp)
    if fused is None:
        return float(sum(_type_bytes(t) for t in op_types))

    # Map parameter index -> fused-side parameter instruction name.
    param_names: Dict[int, str] = {}
    for fin in fused.instrs:
        if fin.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", fin.raw)
            if m:
                param_names[int(m.group(1))] = fin.name

    total = 0.0
    for i, t in enumerate(op_types):
        full = _type_bytes(t)
        pname = param_names.get(i)
        if pname is None:
            total += full
            continue
        consumers = [fin for fin in fused.instrs if pname in fin.operands]
        if consumers and all(c.opcode in _SLICE_OPS for c in consumers):
            total += sum(min(full, _type_bytes(c.out_type))
                         for c in consumers)
        else:
            total += full
    return total


_SKIP_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "copy-start", "copy-done", "add-dependency",
    "opt-barrier",
}


@dataclass
class HLOSummary:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    dot_flops_by_comp: Dict[str, float] = field(default_factory=dict)
    loop_trip_counts: Dict[str, int] = field(default_factory=dict)
    n_collective_ops: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(text: str) -> HLOSummary:
    comps, entry = parse_hlo(text)
    summary = HLOSummary(collective_bytes={k: 0.0 for k in COLLECTIVES})
    if entry is None:
        if not comps:
            return summary
        entry = next(iter(comps))

    def visit(comp_name: str, mult: float, in_fusion: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                f = _dot_flops(ins, comp) * mult
                summary.flops += f
                summary.dot_flops_by_comp[comp_name] = (
                    summary.dot_flops_by_comp.get(comp_name, 0.0) + f)
            elif op == "convolution":
                summary.flops += _conv_flops(ins, comp) * mult

            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                nbytes = sum(_type_bytes(t)
                             for t in _operand_types(ins, comp))
                summary.collective_bytes[base] += nbytes * mult
                summary.n_collective_ops += 1

            if not in_fusion and op not in _SKIP_MEM_OPS:
                out_b = _type_bytes(ins.out_type)
                if op in ("dynamic-update-slice",):
                    # In-place: traffic = read+write of the updated region
                    # only (operand 1), not the aliased full buffer.
                    types = _operand_types(ins, comp)
                    upd = _type_bytes(types[1]) if len(types) > 1 else out_b
                    nbytes = 2 * upd
                elif op in ("gather", "dynamic-slice"):
                    # Reads only the gathered rows (~= output) + indices.
                    nbytes = 2 * out_b
                elif op == "scatter":
                    # Read indices + read-modify-write of touched regions.
                    types = _operand_types(ins, comp)
                    upd = _type_bytes(types[2]) if len(types) > 2 else out_b
                    nbytes = 3 * upd
                elif op == "fusion" and _fusion_root_is_dus(ins, comps):
                    # Fused in-place update: traffic ~= the small inputs
                    # (indices + update region), not the aliased big buffer.
                    ops_b = [_type_bytes(t)
                             for t in _operand_types(ins, comp)]
                    nbytes = 2 * (sum(ops_b) - max(ops_b)) if ops_b else out_b
                elif op == "fusion":
                    nbytes = out_b + _fusion_operand_bytes(ins, comp, comps)
                else:
                    nbytes = out_b + sum(
                        _type_bytes(t) for t in _operand_types(ins, comp))
                summary.hbm_bytes += nbytes * mult

            called = dict(_called(ins))
            if op == "while":
                body = called.get("body")
                cond = called.get("condition")
                trips = 1
                if cond and cond in comps:
                    trips = max(1, _max_int_constant(comps[cond], comps))
                    summary.loop_trip_counts[body or cond] = trips
                if body:
                    visit(body, mult * trips, in_fusion)
            elif op == "fusion":
                for role, c in _called(ins):
                    if role == "calls":
                        visit(c, mult, True)
            elif op in ("call", "conditional", "async-start"):
                for role, c in _called(ins):
                    if role != "to_apply" or op == "call":
                        visit(c, mult, in_fusion)
            # reduce/scatter/sort lambdas (to_apply) are negligible.

    visit(entry, 1.0, False)
    return summary
