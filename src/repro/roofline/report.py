"""Roofline terms from dry-run artifacts.

Per (arch x shape x mesh), with the mandated v5e constants
(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI):

  compute term    = HLO_FLOPs_per_chip / peak
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_bytes_per_chip / (links x link_bw)

plus MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (decode/prefill) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.hw import TPUSpec, chip_spec
from repro.roofline.hlo import HLOSummary


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    step: str
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_breakdown: Dict[str, float]
    model_flops_per_chip: float
    useful_ratio: float                 # MODEL / HLO
    bottleneck: str
    step_time_bound_s: float
    mfu_bound: float                    # model-flops utilization at the bound
    ideal_bound_s: float = 0.0          # perfect-fusion/sharding bound
    roofline_fraction: float = 0.0      # ideal_bound / achieved bound

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.step} "
                f"| {self.compute_s * 1e3:.2f} | {self.memory_s * 1e3:.2f} "
                f"| {self.collective_s * 1e3:.2f} | {self.bottleneck} "
                f"| {self.useful_ratio:.2f} | {self.mfu_bound * 100:.1f}% |")


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig,
                   dtype_bytes: int = 2) -> float:
    """Global KV/state cache bytes for decode shapes."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "mla_moe":
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        return cfg.n_layers * b * s * per_tok * dtype_bytes
    if cfg.family == "hybrid_ssm":
        ssm = cfg.ssm
        d_inner = ssm.expand * cfg.d_model
        h = d_inner // ssm.head_dim
        state = cfg.n_layers * b * h * ssm.head_dim * ssm.state_dim * 4
        attn = 0
        if ssm.attn_every:
            n_apps = -(-cfg.n_layers // ssm.attn_every)
            attn = (n_apps * b * s * cfg.n_kv_heads * cfg.head_dim
                    * 2 * dtype_bytes)
        return state + attn
    if cfg.family == "xlstm":
        from repro.models.xlstm import _round128
        di = _round128(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
        dh = di // cfg.n_heads
        n_m = cfg.n_layers - cfg.n_layers // cfg.xlstm.slstm_every
        return n_m * b * cfg.n_heads * dh * dh * 4
    s_kv = min(s, cfg.sliding_window) if cfg.sliding_window else s
    layers = (cfg.enc_dec.n_decoder_layers if cfg.family == "enc_dec"
              else cfg.n_layers)
    cache = layers * b * s_kv * cfg.n_kv_heads * cfg.head_dim * 2 * dtype_bytes
    if cfg.family == "enc_dec":   # cross K/V over the encoder length
        cache += (layers * b * s * cfg.n_kv_heads * cfg.head_dim
                  * 2 * dtype_bytes)
    return cache


def ideal_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Optimistic global HBM traffic for one step (perfect fusion/sharding):
    the roofline target the perf loop climbs toward."""
    n = cfg.param_count()
    tokens = shape.global_batch * shape.seq_len
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    if shape.kind == "train":
        # fp32 master+m+v read/write (24B) + bf16 weights read fwd/remat/bwd
        # (6B) + f32 grads write+read (8B).
        weights = n * 38.0
        acts = L * tokens * d * 2.0 * 8.0     # block in/outs, fwd+bwd
        logits = tokens * v * 2.0 * 2.0
        return weights + acts + logits
    if shape.kind == "prefill":
        weights = n * 2.0
        acts = L * tokens * d * 2.0 * 4.0
        cache = kv_cache_bytes(cfg, shape)    # written once
        return weights + acts + cache
    # decode: all (active) params + the whole cache once per token.
    active = cfg.active_param_count()
    return active * 2.0 + kv_cache_bytes(cfg, shape)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global MODEL_FLOPS for one step: 6*N*D train, 2*N*D per generated /
    prefilled token (active params for MoE)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence.
    return 2.0 * n_active * shape.global_batch


def roofline_terms(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh_name: str,
    step: str,
    hlo: HLOSummary,
    n_chips: int = 256,
    spec: Optional[TPUSpec] = None,
) -> RooflineTerms:
    spec = spec or chip_spec()
    # HLO quantities are already per-device (SPMD partitioned module).
    compute_s = hlo.flops / spec.peak_bf16_flops
    memory_s = hlo.hbm_bytes / spec.hbm_bw
    links = spec.ici_links_per_axis
    collective_s = hlo.total_collective_bytes / (links * spec.ici_bw_per_link)

    mf = model_flops(cfg, shape) / n_chips
    useful = mf / hlo.flops if hlo.flops else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, collective_s)
    mfu = (mf / spec.peak_bf16_flops) / bound if bound else 0.0
    ideal = max(mf / spec.peak_bf16_flops,
                ideal_bytes(cfg, shape) / n_chips / spec.hbm_bw)
    return RooflineTerms(
        arch=cfg.arch, shape=shape.name, mesh=mesh_name, step=step,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        flops_per_chip=hlo.flops, hbm_bytes_per_chip=hlo.hbm_bytes,
        collective_bytes_per_chip=hlo.total_collective_bytes,
        collective_breakdown=dict(hlo.collective_bytes),
        model_flops_per_chip=mf, useful_ratio=useful,
        bottleneck=bottleneck, step_time_bound_s=bound, mfu_bound=mfu,
        ideal_bound_s=ideal,
        roofline_fraction=min(1.0, ideal / bound) if bound else 0.0,
    )
