from repro.roofline.hlo import HLOSummary, analyze_hlo
from repro.roofline.report import RooflineTerms, roofline_terms

__all__ = ["HLOSummary", "analyze_hlo", "RooflineTerms", "roofline_terms"]
