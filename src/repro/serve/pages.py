"""The global KV page pool: plan-sized pages, per-slot tables, slot-level
admission (DESIGN.md §8).

PR 4's cohort engine made the plan's VMEM page the *growth* granule, but
allocation stayed per cohort: a finished slot's pages were pinned until its
whole cohort retired (or the next growth-boundary compaction).  This module
makes the page a real ALLOCATION unit across requests, the way hierarchical
runtimes own placement instead of the caller (Thibault et al.; Rasch's
(de/re)-composition):

  * ``PagePool`` -- the physical pool: ``pages_total`` pages of
    ``page_plan()["page_tokens"]`` tokens each, a free list, and cumulative
    alloc/release counters (the accounting the property tests pin).
    Physical page 0 is the reserved *null page*: empty slots' decode
    writes land there and nothing ever reads it unmasked.
  * ``PagedScheduler`` -- slot-level admission, pure python: a fixed batch
    of decode *slots*, FIFO admission of one request per free slot
    (``pages_for(prompt + 1)`` pages up front), one-page growth, youngest
    -slot recompute preemption, and sliding-window page reclaim (a page
    wholly below ``pos - window`` frees immediately -- the paged answer to
    the ring buffer).  A finished slot frees its pages at once and is
    backfilled by the next pending request mid-flight: continuous batching
    at slot granularity.
  * ``init_paged_cache`` / ``install_slot`` -- the pooled cache pytree the
    paged decode step (``Model.decode_step_paged``) consumes: ``pool``
    (one shared ``(L, P, T, KV, D)`` buffer per attention-layer group),
    ``state`` (per-slot recurrent/conv buffers, batch on axis 1),
    ``table`` (the per-slot page table) and the per-slot position vector
    ``pos``.  ``install_slot`` scatters a single-request prefill cache
    into the slot's pages and state rows (ring-rotated window prefills are
    un-rotated through their ``pos mod w`` slot map first).

One decode jit bucket serves the whole run -- pool, table and slot count
are static shapes -- where the cohort engine retraces per capacity step.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.serve.kvcache import PageSpec
from repro.serve.scheduler import Request

PyTree = Any

#: Families with a per-slot paged decode path (``Model.decode_step_paged``).
#: MLA's latent cache and enc-dec's encoder-keyed cross K/V are future
#: work; the engine falls back to cohort batching for them.
PAGED_FAMILIES = ("dense", "moe", "hybrid_ssm", "xlstm")


# ---------------------------------------------------------------------------
# Physical pool
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list allocator over the physical page pool.

    ``pages_total`` includes the reserved null page 0, which is never
    allocated or freed.  ``pages_allocated`` / ``pages_released`` are
    cumulative, so ``pages_allocated - pages_released == used_pages`` is
    an invariant the scheduler property test reconciles after every op.
    """

    def __init__(self, pages_total: int):
        if pages_total < 2:
            raise ValueError(
                f"pages_total must be >= 2 (null page + one usable page), "
                f"got {pages_total}")
        self.pages_total = int(pages_total)
        # pop() yields ascending physical ids -- deterministic layouts.
        self._free = list(range(self.pages_total - 1, 0, -1))
        self.pages_allocated = 0
        self.pages_released = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.pages_total - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` physical pages, or None when the pool cannot hold them
        (never a partial grant)."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self.pages_allocated += n
        return out

    def free(self, ids: Sequence[int]) -> None:
        for i in ids:
            if i == 0:
                raise ValueError("page 0 is the reserved null page")
            self._free.append(i)
        self.pages_released += len(ids)


# ---------------------------------------------------------------------------
# Slot-level scheduler
# ---------------------------------------------------------------------------


@dataclass
class SlotState:
    """One occupied decode slot.  ``pages`` maps logical page index ->
    physical page id, ``None`` marking a window-reclaimed page (its tokens
    fell out of the sliding window; the table keeps pointing at the null
    page and the kernel's window mask never reads them)."""

    rid: int
    req: Request
    pos: int                        # resident tokens (prompt, then +1/step)
    pages: List[Optional[int]] = field(default_factory=list)

    @property
    def live_pages(self) -> List[int]:
        return [p for p in self.pages if p is not None]


class PagedScheduler:
    """Slot-level admission under the page-pool budget (pure python).

    The schedulable unit is one SLOT of a fixed decode batch -- not a
    cohort -- so a finished sequence's pages free immediately and the slot
    is backfilled by the next pending request between decode ticks.
    Rules:

      * **admit**   FIFO: the head request takes any free slot iff the pool
        can grant its LIVE page demand -- ``pages_for(prompt + 1)`` minus
        the pages wholly below ``prompt - window`` for sliding-window
        families (those logical pages are born reclaimed: placeholder
        ``None`` entries, never allocated, masked by the kernel), and 0
        for token-free families.  A lone head that can never fit an empty
        pool raises.
      * **grow**    one page per slot when ``pos + 1`` crosses the slot's
        capacity; refusal (pool empty) makes the engine preempt or stall.
      * **victim**  the slot holding the newest request strictly younger
        than the grower's (least sunk cost; rids survive requeueing so a
        preempted request keeps its seniority).  A grower with no younger
        victim STALLS for the tick instead -- pages pinned, decode
        skipped -- so mutual eviction ping-pong cannot happen and the
        oldest request always progresses.
      * **reclaim** pages wholly below ``pos - window`` free immediately
        (sliding-window families only).
    """

    def __init__(self, pool: PagePool, page: PageSpec, n_slots: int,
                 pages_per_slot: int, window: int = 0):
        self.pool = pool
        self.page = page
        self.n_slots = max(1, n_slots)
        self.pages_per_slot = max(1, pages_per_slot)
        self.window = max(0, window)
        self.slots: List[Optional[SlotState]] = [None] * self.n_slots
        self.pending: Deque[Request] = deque()
        self.n_evictions = 0

    # ----------------------------------------------------------- inventory
    def has_work(self) -> bool:
        return bool(self.pending) or any(s is not None for s in self.slots)

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def used_pages_by_slots(self) -> int:
        return sum(len(s.live_pages) for s in self.slots if s is not None)

    def _admit_pages(self, req: Request) -> Tuple[int, int]:
        """``(live, dead)`` logical page counts at admission: only ``live``
        pages are allocated; ``dead`` pages are wholly below
        ``prompt - window`` (their tokens can never attend) and enter the
        slot as ``None`` placeholders -- the same state window reclaim
        leaves behind -- so a long windowed prompt is billed for its
        RESIDENT window, not its full length."""
        if self.page.page_bytes <= 0:
            return 0, 0                   # token-free family (xLSTM)
        total = self.page.pages_for(req.prompt_len + 1)
        dead = 0
        if self.window:
            dead = max(0, req.prompt_len - self.window) \
                // self.page.page_tokens
        return total - dead, dead

    # ----------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def admit(self) -> List[Tuple[int, Request, List[Optional[int]]]]:
        """Fill free slots from the queue head.  Returns
        ``[(slot, request, logical_pages), ...]`` where ``logical_pages``
        maps logical page index -> physical id, with ``None`` marking
        born-reclaimed out-of-window pages; the engine prefills each
        request and installs it into its slot."""
        out: List[Tuple[int, Request, List[Optional[int]]]] = []
        for slot, s in enumerate(self.slots):
            if s is not None or not self.pending:
                continue
            head = self.pending[0]
            live, dead = self._admit_pages(head)
            ids = self.pool.alloc(live)
            if ids is None:
                if not any(x is not None for x in self.slots) and not out:
                    raise ValueError(
                        f"request {head.rid} needs {live} KV pages; the "
                        f"pool holds {self.pool.pages_total - 1} -- raise "
                        f"kv_budget_bytes or shorten the prompt")
                break                     # wait for running slots to free
            self.pending.popleft()
            pages: List[Optional[int]] = [None] * dead + list(ids)
            self.slots[slot] = SlotState(rid=head.rid, req=head,
                                         pos=head.prompt_len,
                                         pages=pages)
            out.append((slot, head, list(pages)))
        return out

    # -------------------------------------------------------------- growth
    def ensure_capacity(self, slot: int) -> bool:
        """Make room for one more token in ``slot``.  True when the slot
        already has capacity or one page was granted; False when the pool
        is exhausted (the engine then preempts and retries) or the slot's
        logical page table is full (``pages_per_slot`` -- check
        ``table_full`` to tell the cases apart: eviction cannot help a
        full table)."""
        s = self.slots[slot]
        if self.page.page_bytes <= 0:
            return True
        if s.pos + 1 <= len(s.pages) * self.page.page_tokens:
            return True
        if len(s.pages) >= self.pages_per_slot:
            return False
        ids = self.pool.alloc(1)
        if ids is None:
            return False
        s.pages.extend(ids)
        return True

    def table_full(self, slot: int) -> bool:
        """True when the slot has exhausted its logical page table (its
        sequence hit the ``pages_per_slot`` bound)."""
        s = self.slots[slot]
        return self.page.page_bytes > 0 and len(s.pages) >= \
            self.pages_per_slot

    def victim(self, protect: int) -> Optional[int]:
        """Preemption victim: the occupied slot holding the newest request
        STRICTLY YOUNGER than ``protect``'s (rids are assigned at
        submission and survive requeueing, so re-admitted requests keep
        their seniority).  Restricting victims to younger slots is what
        makes preemption livelock-free: two growing slots can never evict
        each other in a ping-pong -- the younger one *stalls* (keeps its
        pages, skips the tick) until the older finishes, and the oldest
        slot always makes progress."""
        mine = self.slots[protect].rid
        others = [i for i, s in enumerate(self.slots)
                  if s is not None and i != protect and s.rid > mine]
        if not others:
            return None
        return max(others, key=lambda i: self.slots[i].rid)

    def evict(self, slot: int) -> Request:
        """Recompute preemption: free the slot's pages and requeue its
        request at the FRONT of the queue."""
        s = self.slots[slot]
        self.pool.free(s.live_pages)
        self.slots[slot] = None
        self.pending.appendleft(s.req)
        self.n_evictions += 1
        return s.req

    # ---------------------------------------------------------- retirement
    def finish(self, slot: int) -> None:
        s = self.slots[slot]
        self.pool.free(s.live_pages)
        self.slots[slot] = None

    def reclaim_window(self, slot: int, window: int) -> List[int]:
        """Free pages wholly below ``pos - window`` (their tokens can never
        attend again).  The page table keeps its logical shape; freed
        entries are masked by the kernel's window mask even after the
        physical page is rewritten by another slot."""
        s = self.slots[slot]
        if not window or self.page.page_bytes <= 0:
            return []
        lo = s.pos - window
        freed: List[int] = []
        for j, p in enumerate(s.pages):
            if p is not None and (j + 1) * self.page.page_tokens <= lo:
                freed.append(p)
                s.pages[j] = None
        if freed:
            self.pool.free(freed)
        return freed


# ---------------------------------------------------------------------------
# Pooled cache pytree
# ---------------------------------------------------------------------------


def _n_attn_apps(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return -(-cfg.n_layers // s.attn_every) if (s and s.attn_every) else 0


def init_paged_cache(cfg: ModelConfig, model, n_slots: int, n_pages: int,
                     page_tokens: int, n_logical_pages: int,
                     dtype) -> PyTree:
    """The pooled cache pytree ``Model.decode_step_paged`` consumes.

    ``pool`` holds the shared page pool per attention-layer group
    (``(L, n_pages, page_tokens, KV, D)``), ``state`` the per-slot
    recurrent/conv buffers (batch on axis 1, taken from the family's
    ``init_cache`` shapes), ``table`` the ``(n_slots, n_logical_pages)``
    page table (0 = null page) and ``pos`` the per-slot position vector.
    """
    import jax.numpy as jnp

    fam = cfg.family
    if fam not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged serving is not implemented for family {fam!r}")
    kv, hd = cfg.n_kv_heads, cfg.head_dim

    def pool_kv(nl):
        return {"k": jnp.zeros((nl, n_pages, page_tokens, kv, hd), dtype),
                "v": jnp.zeros((nl, n_pages, page_tokens, kv, hd), dtype)}

    cache: Dict[str, Any] = {
        "table": jnp.zeros((n_slots, n_logical_pages), jnp.int32),
        "pos": jnp.zeros((n_slots,), jnp.int32),
        "pool": {},
        "state": {},
    }
    if fam in ("dense", "moe"):
        cache["pool"] = pool_kv(cfg.n_layers)
    elif fam == "hybrid_ssm":
        base = model.init_cache(n_slots, page_tokens, dtype)
        cache["state"] = {"mamba": base["mamba"]}
        n_apps = _n_attn_apps(cfg)
        if n_apps:
            cache["pool"] = pool_kv(n_apps)
    elif fam == "xlstm":
        base = model.init_cache(n_slots, page_tokens, dtype)
        cache["state"] = {"mlstm": base["mlstm"], "slstm": base["slstm"]}
    return cache


#: Which prefill-cache subtree feeds the pool vs the per-slot state, per
#: family (the other leaves -- ``len``, ``pos`` -- are superseded by the
#: per-slot position vector).
_POOL_GROUP = {"dense": "layers", "moe": "layers", "hybrid_ssm": "attn"}
_STATE_GROUPS = {"hybrid_ssm": ("mamba",), "xlstm": ("mlstm", "slstm")}


def install_slot(cfg: ModelConfig, cache: PyTree, slot: int,
                 prefill_cache: PyTree, page_ids: Sequence[int],
                 prompt_len: int) -> PyTree:
    """Scatter one request's single-sequence prefill cache into its slot.

    KV leaves land in the slot's freshly allocated pages (``page_ids``,
    logical order); recurrent/conv state overwrites the slot's batch row.
    Sliding-window prefills whose prompt overflowed the ring are
    un-rotated first (slot ``a mod w`` holds absolute position ``a``), and
    out-of-window positions simply stay on the null page -- the kernel's
    window mask never reads them.

    Known trade: this runs un-jitted, so the functional ``.at[].set`` on
    the pool copies the whole pool buffer per admission -- O(pool), fine
    at CPU test scale but the wrong cost on HBM-sized pools.  The fix is
    the ROADMAP's chunked-prefill item: write prompt KV into the pages
    directly from a jitted, buffer-donating prefill instead of copying a
    dense prefill cache in afterwards.
    """
    import jax.numpy as jnp

    fam = cfg.family
    new_cache = dict(cache)
    group = _POOL_GROUP.get(fam)
    live = [(j, p) for j, p in enumerate(page_ids) if p is not None]
    if group is not None and group in prefill_cache and cache["pool"] \
            and live:
        t = cache["pool"]["k"].shape[2]
        n_pages = len(page_ids)
        logical = jnp.asarray([j for j, _ in live])
        phys = jnp.asarray([p for _, p in live], jnp.int32)
        pool = dict(cache["pool"])
        for name in ("k", "v"):
            leaf = prefill_cache[group][name]      # (L, 1, s_kv, KV, HD)
            w = leaf.shape[2]
            lo = 0
            if cfg.sliding_window and w <= cfg.sliding_window \
                    and prompt_len >= w:
                lo = prompt_len - w                # ring overflowed: tail only
                idx = jnp.arange(lo, prompt_len) % w
                toks = leaf[:, 0, idx]
            else:
                toks = leaf[:, 0, :prompt_len]
            buf = jnp.zeros((leaf.shape[0], n_pages * t) + leaf.shape[3:],
                            leaf.dtype)
            buf = buf.at[:, lo:prompt_len].set(toks)
            buf = buf.reshape((leaf.shape[0], n_pages, t) + leaf.shape[3:])
            # Only live pages are written: ``None`` entries (born-reclaimed
            # out-of-window pages) have no physical page to hold them.
            pool[name] = pool[name].at[:, phys].set(buf[:, logical])
        new_cache["pool"] = pool
    state_groups = _STATE_GROUPS.get(fam, ())
    if state_groups:
        import jax

        state = dict(cache["state"])
        for g in state_groups:
            state[g] = jax.tree.map(
                lambda dst, src: dst.at[:, slot].set(
                    src[:, 0].astype(dst.dtype)),
                state[g], prefill_cache[g])
        new_cache["state"] = state
    return new_cache


# ---------------------------------------------------------------------------
# Sharding axes for the pooled layout (consumed by serve.steps)
# ---------------------------------------------------------------------------


def paged_cache_logical_axes(cfg: ModelConfig, cache: PyTree) -> PyTree:
    """Logical sharding axes for the pooled cache: pool KV shards over
    heads exactly like the dense cache (``with_kv_sharding`` decides
    whether "kv_heads" maps to the model axis); the page dim ("kv_pages")
    is a pool dim and never shards -- a page is the VMEM streaming granule
    of ONE chip.  Per-slot state reuses the dense cache's axis names via
    ``launch.specs.cache_logical_axes``."""
    from repro.launch.specs import cache_logical_axes

    axes: Dict[str, Any] = {
        "table": (None, None),
        "pos": (None,),
        "pool": {},
        "state": {},
    }
    if cache.get("pool"):
        nd = cache["pool"]["k"].ndim      # (L, P, T, KV, HD)
        pool_ax = ("layers", "kv_pages", None, "kv_heads", None)[:nd]
        axes["pool"] = {"k": pool_ax, "v": pool_ax}
    if cache.get("state"):
        axes["state"] = cache_logical_axes(cfg, cache["state"], False)
    return axes
