"""The global KV page pool: plan-sized pages, per-slot tables, slot-level
admission (DESIGN.md §8).

PR 4's cohort engine made the plan's VMEM page the *growth* granule, but
allocation stayed per cohort: a finished slot's pages were pinned until its
whole cohort retired (or the next growth-boundary compaction).  This module
makes the page a real ALLOCATION unit across requests, the way hierarchical
runtimes own placement instead of the caller (Thibault et al.; Rasch's
(de/re)-composition):

  * ``PagePool`` -- the physical pool: ``pages_total`` pages of
    ``page_plan()["page_tokens"]`` tokens each, a free list, and cumulative
    alloc/release counters (the accounting the property tests pin).
    Physical page 0 is the reserved *null page*: empty slots' decode
    writes land there and nothing ever reads it unmasked.
  * ``PagedScheduler`` -- slot-level admission, pure python: a fixed batch
    of decode *slots*, FIFO admission of one request per free slot
    (``pages_for(prompt + 1)`` pages up front), one-page growth, youngest
    -slot recompute preemption, and sliding-window page reclaim (a page
    wholly below ``pos - window`` frees immediately -- the paged answer to
    the ring buffer).  A finished slot frees its pages at once and is
    backfilled by the next pending request mid-flight: continuous batching
    at slot granularity.
  * ``init_paged_cache`` / ``reset_slot`` -- the pooled cache pytree the
    paged decode step (``Model.decode_step_paged``) consumes: ``pool``
    (one shared ``(L, P, T, KV, D)`` buffer per attention-layer group;
    MLA's is a single ``lat`` latent buffer), ``state`` (per-slot
    recurrent/conv buffers, batch on axis 1; enc-dec adds per-slot cross
    K/V), ``table`` (the per-slot page table) and the per-slot position
    vector ``pos``.  ``reset_slot`` re-initializes a slot's state rows at
    admission; prompt KV reaches the pages via chunked prefill
    (``Model.prefill_chunk``), never via a post-prefill copy.

One decode jit bucket serves the whole run -- pool, table and slot count
are static shapes -- where the cohort engine retraces per capacity step.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.serve.kvcache import PageSpec
from repro.serve.scheduler import Request

PyTree = Any

#: Families with a per-slot paged decode path (``Model.decode_step_paged``).
#: MLA's latent cache pages like KV (one shared "lat" pool buffer) and
#: enc-dec pages its decoder self-attn KV (cross K/V is per-slot state --
#: it never grows).  Only vlm still falls back to cohort batching: its
#: 3-D mrope positions don't fit the per-slot position vector yet.
PAGED_FAMILIES = ("dense", "moe", "hybrid_ssm", "xlstm", "mla_moe",
                  "enc_dec")


# ---------------------------------------------------------------------------
# Physical pool
# ---------------------------------------------------------------------------


class PagePool:
    """Refcounting free-list allocator over the physical page pool.

    ``pages_total`` includes the reserved null page 0, which is never
    allocated or freed.  Every page carries a reference count: ``alloc``
    hands out pages at refcount 1, ``incref`` adds a read-only mapping
    (the prefix cache sharing one physical page into several slot tables
    and/or its radix tree), and ``free`` *decrefs* -- the page returns to
    the free list only when its last reference drops.  Freeing a page
    that holds no reference (double free, or a scheduler bug returning a
    page it never owned) raises instead of silently corrupting the free
    list.

    ``pages_allocated`` / ``pages_released`` count PHYSICAL transitions
    (free list -> used and back), so
    ``pages_allocated - pages_released == used_pages`` stays an invariant
    under sharing and ``assert_reconciled`` pins it after every op.
    """

    def __init__(self, pages_total: int, obs=None, tracer=None):
        if pages_total < 2:
            raise ValueError(
                f"pages_total must be >= 2 (null page + one usable page), "
                f"got {pages_total}")
        self.pages_total = int(pages_total)
        # pop() yields ascending physical ids -- deterministic layouts.
        self._free = list(range(self.pages_total - 1, 0, -1))
        self._rc = [0] * self.pages_total
        self.pages_allocated = 0
        self.pages_released = 0
        # Observability hooks (DESIGN.md §13): the pool is the single
        # writer of the occupancy gauges the engine's stats() view, the
        # cluster router's ``free_pages`` policy and the plan-vs-actual
        # report all read; alloc/free land in the trace as instants.
        self.obs = obs
        self.tracer = tracer
        self._publish()

    def _publish(self) -> None:
        if self.obs is not None:
            self.obs.set("free_pages", self.free_pages, unit="pages")
            self.obs.set("used_pages", self.used_pages, unit="pages")
            self.obs.set_max("pool_peak_pages", self.used_pages,
                             unit="pages")

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.pages_total - 1) - len(self._free)

    @property
    def total_refs(self) -> int:
        """Sum of live refcounts: slot-table references + prefix-tree
        references (the ledger the engine reconciles every tick)."""
        return sum(self._rc)

    def refcount(self, pid: int) -> int:
        return self._rc[pid]

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` physical pages at refcount 1, or None when the pool
        cannot hold them (never a partial grant)."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for i in out:
            self._rc[i] = 1
        self.pages_allocated += n
        self._publish()
        if self.tracer is not None:
            self.tracer.instant("page_alloc",
                                args={"n": n, "free": self.free_pages})
        return out

    def incref(self, pid: int) -> None:
        """Add a reference to a LIVE page (a shared read-only mapping).
        Increffing a free page would resurrect it without removing it
        from the free list, so that raises."""
        if pid <= 0 or pid >= self.pages_total:
            raise ValueError(f"incref of invalid page id {pid}")
        if self._rc[pid] <= 0:
            raise ValueError(f"incref of free page {pid}")
        self._rc[pid] += 1

    def free(self, ids: Sequence[int]) -> None:
        """Drop one reference per id; a page returns to the free list
        (and counts as released) only at refcount zero."""
        for i in ids:
            if i == 0:
                raise ValueError("page 0 is the reserved null page")
            if i < 0 or i >= self.pages_total or self._rc[i] <= 0:
                raise ValueError(
                    f"double free (or free of never-allocated page) {i}")
            self._rc[i] -= 1
            if self._rc[i] == 0:
                self._free.append(i)
                self.pages_released += 1
        self._publish()
        if self.tracer is not None:
            self.tracer.instant("page_free",
                                args={"n": len(ids),
                                      "free": self.free_pages})

    def assert_reconciled(self) -> None:
        """Flow counters vs free list vs refcounts (the property tests'
        per-op pin)."""
        assert self.pages_allocated - self.pages_released == \
            self.used_pages, "page flow counters do not reconcile"
        assert len(set(self._free)) == len(self._free), \
            "free list holds a duplicate page"
        assert all(self._rc[i] == 0 for i in self._free), \
            "free list holds a referenced page"
        assert self._rc[0] == 0, "null page acquired a refcount"
        live = sum(1 for c in self._rc if c > 0)
        assert live == self.used_pages, \
            "refcounted pages do not match used pages"


# ---------------------------------------------------------------------------
# Slot-level scheduler
# ---------------------------------------------------------------------------


@dataclass
class SlotState:
    """One occupied decode slot.  ``pages`` maps logical page index ->
    physical page id, ``None`` marking a window-reclaimed page (its tokens
    fell out of the sliding window; the table keeps pointing at the null
    page and the kernel's window mask never reads them)."""

    rid: int
    req: Request
    pos: int                        # resident tokens (prompt, then +1/step)
    pages: List[Optional[int]] = field(default_factory=list)

    @property
    def live_pages(self) -> List[int]:
        return [p for p in self.pages if p is not None]


class PagedScheduler:
    """Slot-level admission under the page-pool budget (pure python).

    The schedulable unit is one SLOT of a fixed decode batch -- not a
    cohort -- so a finished sequence's pages free immediately and the slot
    is backfilled by the next pending request between decode ticks.
    Rules:

      * **admit**   FIFO: the head request takes any free slot iff the pool
        can grant its LIVE page demand -- ``pages_for(prompt + 1)`` minus
        the pages wholly below ``prompt - window`` for sliding-window
        families (those logical pages are born reclaimed: placeholder
        ``None`` entries, never allocated, masked by the kernel), and 0
        for token-free families.  A lone head that can never fit an empty
        pool raises.
      * **grow**    one page per slot when ``pos + 1`` crosses the slot's
        capacity; refusal (pool empty) makes the engine preempt or stall.
      * **victim**  the slot holding the newest request strictly younger
        than the grower's (least sunk cost; rids survive requeueing so a
        preempted request keeps its seniority).  A grower with no younger
        victim STALLS for the tick instead -- pages pinned, decode
        skipped -- so mutual eviction ping-pong cannot happen and the
        oldest request always progresses.
      * **reclaim** pages wholly below ``pos - window`` free immediately
        (sliding-window families only).
    """

    def __init__(self, pool: PagePool, page: PageSpec, n_slots: int,
                 pages_per_slot: int, window: int = 0, prefix=None):
        self.pool = pool
        self.page = page
        self.n_slots = max(1, n_slots)
        self.pages_per_slot = max(1, pages_per_slot)
        self.window = max(0, window)
        self.prefix = prefix            # serve.prefix.RadixPrefixCache|None
        self.slots: List[Optional[SlotState]] = [None] * self.n_slots
        self.pending: Deque[Request] = deque()
        self.n_evictions = 0

    def _alloc(self, n: int) -> Optional[List[int]]:
        """Pool alloc with prefix-cache back-pressure: when the free list
        cannot grant ``n`` pages, evict unreferenced radix-tree pages (LRU)
        before giving up -- live slots outrank cached prefixes."""
        ids = self.pool.alloc(n)
        if ids is None and self.prefix is not None:
            self.prefix.release_pages(need=n)
            ids = self.pool.alloc(n)
        return ids

    # ----------------------------------------------------------- inventory
    def has_work(self) -> bool:
        return bool(self.pending) or any(s is not None for s in self.slots)

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def used_pages_by_slots(self) -> int:
        return sum(len(s.live_pages) for s in self.slots if s is not None)

    def _admit_pages(self, req: Request) -> Tuple[int, int]:
        """``(live, dead)`` logical page counts at admission: only ``live``
        pages are allocated; ``dead`` pages are wholly below
        ``prompt - window`` (their tokens can never attend) and enter the
        slot as ``None`` placeholders -- the same state window reclaim
        leaves behind -- so a long windowed prompt is billed for its
        RESIDENT window, not its full length."""
        if self.page.page_bytes <= 0:
            return 0, 0                   # token-free family (xLSTM)
        total = self.page.pages_for(req.prompt_len + 1)
        dead = 0
        if self.window:
            dead = max(0, req.prompt_len - self.window) \
                // self.page.page_tokens
        return total - dead, dead

    # ----------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def admit(self, chunked: bool = False
              ) -> List[Tuple[int, Request, List[Optional[int]], Any]]:
        """Fill free slots from the queue head.  Returns
        ``[(slot, request, logical_pages, hit), ...]`` where
        ``logical_pages`` maps logical page index -> physical id, with
        ``None`` marking born-reclaimed out-of-window pages, and ``hit``
        is the ``serve.prefix.PrefixHit`` this admission matched (None
        without a prefix cache or on a miss); the engine prefills each
        request and installs it into its slot.

        ``chunked`` admits for CHUNKED prefill: the slot starts at
        ``pos = 0`` with only its FIRST page allocated -- the engine grows
        it page by page ahead of the chunk front (``ensure_capacity(slot,
        upto=...)``) and window-reclaims behind it, so a long windowed
        prompt's peak page usage is its resident window, same as the
        monolithic admission bill.  With a prefix cache attached, chunked
        admission first consults the radix tree: a hit starts the slot at
        ``pos = hit.tokens`` with the shared prefix pages mapped read-only
        (increffed) into its table -- prefill covers only the unshared
        suffix."""
        out: List[Tuple[int, Request, List[Optional[int]], Any]] = []
        for slot, s in enumerate(self.slots):
            if s is not None or not self.pending:
                continue
            head = self.pending[0]
            live, dead = self._admit_pages(head)
            if chunked:
                hit = None
                if self.prefix is not None and head.features and \
                        "tokens" in head.features:
                    import numpy as np
                    hit = self.prefix.admit(
                        np.asarray(head.features["tokens"]).reshape(-1))
                if hit is not None:
                    self.pending.popleft()
                    self.slots[slot] = SlotState(
                        rid=head.rid, req=head, pos=hit.tokens,
                        pages=list(hit.pages))
                    out.append((slot, head, list(hit.pages), hit))
                    continue
                first = min(live, 1)
                ids = self._alloc(first)
                if ids is None and first:
                    if not any(x is not None for x in self.slots) and not out:
                        raise ValueError(
                            f"request {head.rid} needs at least 1 KV page; "
                            f"the pool holds {self.pool.pages_total - 1} -- "
                            f"raise kv_budget_bytes")
                    break
                self.pending.popleft()
                self.slots[slot] = SlotState(rid=head.rid, req=head,
                                             pos=0, pages=list(ids or []))
                out.append((slot, head, list(ids or []), None))
                continue
            ids = self._alloc(live)
            if ids is None:
                if not any(x is not None for x in self.slots) and not out:
                    raise ValueError(
                        f"request {head.rid} needs {live} KV pages; the "
                        f"pool holds {self.pool.pages_total - 1} -- raise "
                        f"kv_budget_bytes or shorten the prompt")
                break                     # wait for running slots to free
            self.pending.popleft()
            pages: List[Optional[int]] = [None] * dead + list(ids)
            self.slots[slot] = SlotState(rid=head.rid, req=head,
                                         pos=head.prompt_len,
                                         pages=pages)
            out.append((slot, head, list(pages), None))
        return out

    # -------------------------------------------------------------- growth
    def ensure_capacity(self, slot: int, upto: Optional[int] = None) -> bool:
        """Make room in ``slot`` for tokens up to position ``upto``
        (exclusive; default ``pos + 1`` -- one more decode token).  Grows
        page by page.  True when capacity exists or was granted; False
        when the pool is exhausted (the engine then preempts and retries)
        or the slot's logical page table is full (``pages_per_slot`` --
        check ``table_full`` to tell the cases apart: eviction cannot
        help a full table).  Chunked prefill passes ``upto = done +
        chunk`` to allocate just ahead of the chunk front."""
        s = self.slots[slot]
        if self.page.page_bytes <= 0:
            return True
        need = s.pos + 1 if upto is None else upto
        while need > len(s.pages) * self.page.page_tokens:
            if len(s.pages) >= self.pages_per_slot:
                return False
            ids = self._alloc(1)
            if ids is None:
                return False
            s.pages.extend(ids)
        return True

    def table_full(self, slot: int) -> bool:
        """True when the slot has exhausted its logical page table (its
        sequence hit the ``pages_per_slot`` bound)."""
        s = self.slots[slot]
        return self.page.page_bytes > 0 and len(s.pages) >= \
            self.pages_per_slot

    def victim(self, protect: int) -> Optional[int]:
        """Preemption victim: the occupied slot holding the newest request
        STRICTLY YOUNGER than ``protect``'s (rids are assigned at
        submission and survive requeueing, so re-admitted requests keep
        their seniority).  Restricting victims to younger slots is what
        makes preemption livelock-free: two growing slots can never evict
        each other in a ping-pong -- the younger one *stalls* (keeps its
        pages, skips the tick) until the older finishes, and the oldest
        slot always makes progress."""
        mine = self.slots[protect].rid
        others = [i for i, s in enumerate(self.slots)
                  if s is not None and i != protect and s.rid > mine]
        if not others:
            return None
        return max(others, key=lambda i: self.slots[i].rid)

    def evict(self, slot: int) -> Request:
        """Recompute preemption: free the slot's pages and requeue its
        request at the FRONT of the queue."""
        s = self.slots[slot]
        self.pool.free(s.live_pages)
        self.slots[slot] = None
        self.pending.appendleft(s.req)
        self.n_evictions += 1
        return s.req

    # ---------------------------------------------------------- retirement
    def finish(self, slot: int) -> None:
        s = self.slots[slot]
        self.pool.free(s.live_pages)
        self.slots[slot] = None

    def reclaim_window(self, slot: int, window: int) -> List[int]:
        """Free pages wholly below ``pos - window`` (their tokens can never
        attend again).  The page table keeps its logical shape; freed
        entries are masked by the kernel's window mask even after the
        physical page is rewritten by another slot."""
        s = self.slots[slot]
        if not window or self.page.page_bytes <= 0:
            return []
        lo = s.pos - window
        freed: List[int] = []
        for j, p in enumerate(s.pages):
            if p is not None and (j + 1) * self.page.page_tokens <= lo:
                freed.append(p)
                s.pages[j] = None
        if freed:
            self.pool.free(freed)
        return freed


# ---------------------------------------------------------------------------
# Pooled cache pytree
# ---------------------------------------------------------------------------


def _n_attn_apps(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return -(-cfg.n_layers // s.attn_every) if (s and s.attn_every) else 0


def init_paged_cache(cfg: ModelConfig, model, n_slots: int, n_pages: int,
                     page_tokens: int, n_logical_pages: int,
                     dtype, enc_len: int = 0) -> PyTree:
    """The pooled cache pytree ``Model.decode_step_paged`` consumes.

    ``pool`` holds the shared page pool per attention-layer group
    (``(L, n_pages, page_tokens, KV, D)`` -- MLA's is one ``lat`` buffer
    of ``concat(ckv, k_rope)`` rows with a single shared latent head),
    ``state`` the per-slot recurrent/conv buffers (batch on axis 1, taken
    from the family's ``init_cache`` shapes; enc-dec adds the per-slot
    cross K/V -- sized ``enc_len``, the max encoder length this run
    serves -- and its valid-length vector), ``table`` the
    ``(n_slots, n_logical_pages)`` page table (0 = null page) and ``pos``
    the per-slot position vector.
    """
    import jax.numpy as jnp

    fam = cfg.family
    if fam not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged serving is not implemented for family {fam!r}")
    kv, hd = cfg.n_kv_heads, cfg.head_dim

    def pool_kv(nl):
        return {"k": jnp.zeros((nl, n_pages, page_tokens, kv, hd), dtype),
                "v": jnp.zeros((nl, n_pages, page_tokens, kv, hd), dtype)}

    cache: Dict[str, Any] = {
        "table": jnp.zeros((n_slots, n_logical_pages), jnp.int32),
        "pos": jnp.zeros((n_slots,), jnp.int32),
        "pool": {},
        "state": {},
    }
    if fam in ("dense", "moe"):
        cache["pool"] = pool_kv(cfg.n_layers)
    elif fam == "mla_moe":
        m = cfg.mla
        lat_dim = m.kv_lora_rank + m.rope_head_dim
        cache["pool"] = {"lat": jnp.zeros(
            (cfg.n_layers, n_pages, page_tokens, 1, lat_dim), dtype)}
    elif fam == "hybrid_ssm":
        base = model.init_cache(n_slots, page_tokens, dtype)
        cache["state"] = {"mamba": base["mamba"]}
        n_apps = _n_attn_apps(cfg)
        if n_apps:
            cache["pool"] = pool_kv(n_apps)
    elif fam == "xlstm":
        base = model.init_cache(n_slots, page_tokens, dtype)
        cache["state"] = {"mlstm": base["mlstm"], "slstm": base["slstm"]}
    elif fam == "enc_dec":
        nd = cfg.enc_dec.n_decoder_layers
        cache["pool"] = pool_kv(nd)
        cache["state"] = {
            "cross_k": jnp.zeros((nd, n_slots, enc_len, kv, hd), dtype),
            "cross_v": jnp.zeros((nd, n_slots, enc_len, kv, hd), dtype),
            "enc_len": jnp.zeros((n_slots,), jnp.int32),
        }
    return cache


#: Per-slot recurrent-state groups per family (reset at admission).
_STATE_GROUPS = {"hybrid_ssm": ("mamba",), "xlstm": ("mlstm", "slstm")}


def reset_slot(cfg: ModelConfig, model, cache: PyTree, slot: int,
               cross_kv: Optional[Tuple[Any, Any]] = None,
               enc_len: int = 0) -> PyTree:
    """Reset one slot's per-slot state rows for a fresh (chunked) prefill.

    Chunked prefill writes KV straight into pool pages, so admission only
    has to (a) reset the slot's recurrent/conv state rows to the family's
    ``init_cache`` values -- NOT zeros: mLSTM/sLSTM stabilizer rows
    initialize to the running-max floor -- and (b) for enc-dec, install
    the request's pre-computed cross K/V (``cross_kv``: ``(nd, 1, Se, KV,
    HD)`` each) and its valid length.  The pool itself needs no reset:
    chunk writes land exactly on the slot's allocated pages.
    """
    import jax

    state_groups = _STATE_GROUPS.get(cfg.family, ())
    new_cache = dict(cache)
    if state_groups:
        fresh = model.init_cache(1, cache["pool"]["k"].shape[2]
                                 if cache.get("pool") else 1,
                                 jax.tree.leaves(cache["state"])[0].dtype)
        state = dict(cache["state"])
        for g in state_groups:
            state[g] = jax.tree.map(
                lambda dst, src: dst.at[:, slot].set(
                    src[:, 0].astype(dst.dtype)),
                state[g], fresh[g])
        new_cache["state"] = state
    if cfg.family == "enc_dec":
        import jax.numpy as jnp

        ck, cv = cross_kv
        state = dict(new_cache["state"])
        se = ck.shape[2]
        for name, src in (("cross_k", ck), ("cross_v", cv)):
            dst = state[name]
            row = jnp.zeros(dst.shape[:1] + dst.shape[2:], dst.dtype)
            row = row.at[:, :se].set(src[:, 0].astype(dst.dtype))
            state[name] = dst.at[:, slot].set(row)
        state["enc_len"] = state["enc_len"].at[slot].set(enc_len)
        new_cache["state"] = state
    return new_cache


# ---------------------------------------------------------------------------
# Sharding axes for the pooled layout (consumed by serve.steps)
# ---------------------------------------------------------------------------


def paged_cache_logical_axes(cfg: ModelConfig, cache: PyTree) -> PyTree:
    """Logical sharding axes for the pooled cache: pool KV shards over
    heads exactly like the dense cache (``with_kv_sharding`` decides
    whether "kv_heads" maps to the model axis); the page dim ("kv_pages")
    is a pool dim and never shards -- a page is the VMEM streaming granule
    of ONE chip.  Per-slot state reuses the dense cache's axis names via
    ``launch.specs.cache_logical_axes``."""
    from repro.launch.specs import cache_logical_axes

    axes: Dict[str, Any] = {
        "table": (None, None),
        "pos": (None,),
        "pool": {},
        "state": {},
    }
    if cache.get("pool"):
        pool_ax = ("layers", "kv_pages", None, "kv_heads", None)
        axes["pool"] = {name: pool_ax[:cache["pool"][name].ndim]
                        for name in cache["pool"]}
    if cache.get("state"):
        state = dict(cache["state"])
        cross = {}
        for name in ("cross_k", "cross_v"):
            if name in state:
                state.pop(name)
                cross[name] = ("layers", None, None, "kv_heads", None)
        if "enc_len" in state:
            state.pop("enc_len")
            cross["enc_len"] = (None,)
        axes["state"] = cache_logical_axes(cfg, state, False) if state \
            else {}
        axes["state"].update(cross)
    return axes


# ---------------------------------------------------------------------------
# Page serialization (prefill/decode disaggregation, repro.cluster)
# ---------------------------------------------------------------------------


def export_pool_pages(cache: PyTree, page_ids: Sequence[int]) -> List[Dict[str, Any]]:
    """Serialize physical pool pages as host arrays, one payload per page.

    Each payload maps the pool's buffer names ("k"/"v", or "lat" for MLA)
    to an ``(L, page_tokens, KV, D)`` numpy array -- the full cross-layer
    slice of ONE physical page.  This is the wire unit of prefill/decode
    disaggregation: a prefill replica exports the pages a finished prompt
    occupies and streams them (in ring order) to a decode replica, which
    installs them into its own pool under fresh physical ids.  Payloads
    are keyed by *position in the logical page chain*, never by physical
    id: physical numbering is private to each replica's pool.
    """
    import numpy as np

    payloads: List[Dict[str, Any]] = []
    for pid in page_ids:
        payloads.append({name: np.asarray(buf[:, int(pid)])
                         for name, buf in cache["pool"].items()})
    return payloads


def install_pool_pages(cache: PyTree, page_ids: Sequence[int],
                       payloads: Sequence[Dict[str, Any]]) -> PyTree:
    """Install serialized page payloads into this pool's physical pages.

    ``page_ids`` are freshly allocated pages in the *receiving* pool
    (same length as ``payloads``); the i-th payload lands on the i-th
    page.  Buffer names and per-page shapes must match the receiving
    pool's layout -- geometry comes from the same ``HierarchicalPlan``
    on both sides, so a mismatch means the replicas were planned against
    different hierarchies and is an error, not a fallback.
    """
    import jax.numpy as jnp

    if len(page_ids) != len(payloads):
        raise ValueError(f"{len(page_ids)} pages for {len(payloads)} payloads")
    pool = dict(cache["pool"])
    for name in pool:
        buf = pool[name]
        for pid, payload in zip(page_ids, payloads):
            if name not in payload:
                raise ValueError(f"payload missing pool buffer {name!r}")
            data = jnp.asarray(payload[name], buf.dtype)
            if data.shape != buf.shape[:1] + buf.shape[2:]:
                raise ValueError(
                    f"page payload {name!r} shape {data.shape} != pool "
                    f"page shape {buf.shape[:1] + buf.shape[2:]}")
            buf = buf.at[:, int(pid)].set(data)
        pool[name] = buf
    new_cache = dict(cache)
    new_cache["pool"] = pool
    return new_cache
