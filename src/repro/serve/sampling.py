"""Sampling API for the serving engine: greedy / temperature / top-k,
seeded and deterministic for a fixed run.

``sample`` consumes the last-token logits of a decode (or prefill) step,
``(B, V)``, and returns ``(B,)`` int32 token ids.  Greedy is exact argmax
(the mode the token-identity tests pin against the legacy loop);
temperature and top-k draw from ``jax.random.categorical`` under a key the
engine derives from ``SamplingConfig.seed`` and the global step counter,
so a run replays bit-identically under the same seed and schedule.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

KINDS = ("greedy", "temperature", "top_k")


@dataclass(frozen=True)
class SamplingConfig:
    kind: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0                 # used by kind="top_k"
    seed: int = 0
    eos_id: Optional[int] = None   # stop decoding a slot on this token

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown sampling kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.kind == "top_k" and self.top_k <= 0:
            raise ValueError("kind='top_k' needs top_k >= 1")


def sample(logits: jax.Array, cfg: SamplingConfig,
           key: Optional[jax.Array] = None) -> jax.Array:
    """Draw one token per row of ``logits`` (B, V) -> (B,) int32."""
    if cfg.kind == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError(f"sampling kind {cfg.kind!r} needs a PRNG key")
    scaled = logits.astype(jnp.float32) / max(1e-6, cfg.temperature)
    if cfg.kind == "top_k":
        k = min(cfg.top_k, scaled.shape[-1])
        kth = jnp.sort(scaled, axis=-1)[..., -k][..., None]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def step_key(cfg: SamplingConfig, step: int) -> Optional[jax.Array]:
    """The engine's per-step key (None for greedy: no randomness)."""
    if cfg.kind == "greedy":
        return None
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
