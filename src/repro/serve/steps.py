"""Serve-step factory: jitted prefill + single-token decode, plan-driven.

Moved here from ``launch/trainer.py`` (which keeps a re-export): serving
is now owned by ``repro.serve``, and the step factory is where the decode
plan meets the lowered program.  Pass ``decode_plan`` (a
``HierarchicalPlan`` from ``repro.serve.plan_decode``) and the factory

  * realizes the plan's mesh-level **KV head sharding** through
    ``dist.sharding.with_kv_sharding`` (the cache's head dim is sharded
    over "model" exactly when the plan's ``kv_shard > 1``), and
  * sizes the cache buffers in whole **pages** (the plan's VMEM-leaf page
    level): ``max_len_extra`` callers are legacy; the engine passes a
    page-aligned capacity instead.

Without a plan the legacy ``cache_policy`` auto heuristics apply
unchanged (baseline dry-runs, perf_iter variants).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import (
    ShardingRules,
    arch_rules,
    param_shardings,
    resolve_collectives,
    use_mesh_rules,
    with_batch_guard,
    with_kv_sharding,
)
from repro.launch.specs import (
    batch_logical_axes,
    cache_logical_axes,
    decode_footprint,
)
from repro.models.model import Model, build_model

PyTree = Any


@dataclass
class ServeSteps:
    prefill: Callable               # (params, batch) -> (logits, cache)
    decode: Callable                # (params, cache, batch) -> (logits, cache)
    param_sharding: PyTree
    cache_sharding: PyTree
    model: Model
    plan: Any = None                # the decode HierarchicalPlan (if any)
    max_len: int = 0                # cache token capacity at prefill


@dataclass
class PagedServeSteps:
    """The paged engine's programs: one decode jit bucket for the whole
    run (pool, table and slot count are static shapes), plus chunked
    prefill -- one extra bucket per distinct chunk length, i.e. the full
    planned chunk and one per partial-final-chunk remainder."""

    decode: Callable                # (params, paged_cache, batch) -> (logits, cache)
    prefill_chunk: Callable         # (params, cache, tokens, pos0, slot) -> (logits, cache)
    param_sharding: PyTree
    cache_sharding: PyTree
    model: Model
    plan: Any = None
    encode: Optional[Callable] = None   # enc-dec: (params, enc_embeds) -> (ck, cv)


def make_serve_steps(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    rules: Optional[ShardingRules] = None,
    dtype=jnp.bfloat16,
    jit: bool = True,
    max_len_extra: int = 0,
    weights_tp_only: bool = False,
    cache_head_sharded: bool = False,
    cache_seq_sharded: bool = False,
    cache_policy: str = "auto",
    collectives: str = "gspmd",
    plan: Optional[Any] = None,
    decode_plan: Optional[Any] = None,
) -> ServeSteps:
    """Serve-step factory.

    ``cache_policy="auto"`` applies the §Perf-winning placement: shard the
    KV cache over heads when kv_heads divides the model axis (attention
    stays shard-local, zero cache collectives, cell 3: -93% bound), else
    over the sequence dim with grouped-GQA decode (cell 2: -80% bound);
    explicit ``cache_head_sharded`` / ``cache_seq_sharded`` flags override
    (used by the baseline dry-run via ``cache_policy="baseline"`` and by
    perf_iter).  ``decode_plan`` overrides all of it with the hierarchical
    planner's decode-workload choice (see module docstring).
    """
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    heads_divide = cfg.n_kv_heads % model_size == 0
    # The sharded buffer is the padded cache (seq_len + extra) -- pjit
    # in/out shardings require exact divisibility.
    seq_divides = (shape.seq_len + max_len_extra) % model_size == 0
    kv_shard = 0
    if decode_plan is not None:
        kv_shard = decode_plan.kv_shard()
        cache_head_sharded = kv_shard > 1 and heads_divide
        cache_seq_sharded = False
        cache_policy = "plan"
    if cache_policy == "auto" and not (cache_head_sharded or cache_seq_sharded):
        if not heads_divide and seq_divides and shape.kind == "decode":
            cache_seq_sharded = True
        elif heads_divide:
            cache_head_sharded = True
    long_context = shape.seq_len >= 262144 or cache_seq_sharded
    if cache_head_sharded and heads_divide:
        # Head sharding: attention local per head shard, no distributed
        # softmax; preferred whenever the head count divides the axis.
        long_context = False
    if rules is None:
        # Serving memory model: bf16 weights only (no master copy /
        # moments), and the KV cache as the reserved term -- it shards over
        # both the batch (data) and head (model) axes, so the global
        # footprint divides by the full mesh.
        rules = arch_rules(
            cfg, mesh, seq_sharded=long_context,
            state_bytes_per_param=2,
            act_bytes=decode_footprint(
                cfg, shape, shape.seq_len + max_len_extra) // mesh.size,
            plan=plan)
    rules = with_batch_guard(rules, mesh, shape.global_batch)
    rules = resolve_collectives(rules, collectives)
    if decode_plan is not None:
        rules = with_kv_sharding(rules, kv_shard if cache_head_sharded else 1)
    if weights_tp_only:
        # Perf variant: serving replicates weights across the data axes
        # (memory permitting) so no per-step FSDP all-gather is emitted.
        pr = dict(rules.param_rules)
        pr["embed"] = None
        rules = ShardingRules(pr, dict(rules.act_rules), meta=dict(rules.meta))
    model = build_model(cfg, remat="none")
    specs = model.param_specs()
    p_shard = param_shardings(mesh, rules, specs)
    max_len = shape.seq_len + max_len_extra

    cache_tpl = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, max_len, dtype,
                                 enc_len=shape.seq_len))
    c_axes = cache_logical_axes(cfg, cache_tpl, long_context)
    c_shard = jax.tree.map(
        lambda ax: NamedSharding(mesh, rules.act_spec(ax)),
        c_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
    d_axes = batch_logical_axes(cfg, "decode")
    d_shard = {k: NamedSharding(mesh, rules.act_spec(v))
               for k, v in d_axes.items()}
    t_axes = batch_logical_axes(cfg, "train")
    t_shard = {k: NamedSharding(mesh, rules.act_spec(v))
               for k, v in t_axes.items() if k != "labels"}

    def prefill_fn(params, batch):
        with use_mesh_rules(mesh, rules):
            return model.prefill(params, batch, max_len, dtype=dtype)

    def decode_fn(params, cache, batch):
        with use_mesh_rules(mesh, rules):
            return model.decode_step(params, cache, batch, dtype=dtype)

    if jit:
        prefill_fn = jax.jit(
            prefill_fn,
            in_shardings=(p_shard, t_shard),
            out_shardings=(None, c_shard),
        )
        decode_fn = jax.jit(
            decode_fn,
            in_shardings=(p_shard, c_shard, d_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
    return ServeSteps(prefill=prefill_fn, decode=decode_fn,
                      param_sharding=p_shard, cache_sharding=c_shard,
                      model=model, plan=decode_plan, max_len=max_len)


def make_paged_steps(
    cfg: ModelConfig,
    mesh: Mesh,
    cache_tpl: PyTree,
    n_slots: int,
    max_len: int,
    dtype=jnp.bfloat16,
    jit: bool = True,
    decode_plan: Optional[Any] = None,
    collectives: str = "gspmd",
) -> PagedServeSteps:
    """Lower the paged decode step (``Model.decode_step_paged``).

    ``cache_tpl`` is the pooled cache pytree from
    ``serve.pages.init_paged_cache`` (shapes only are read).  The plan's
    KV head sharding applies to the pool exactly as it does to the dense
    cache -- ``with_kv_sharding`` maps the pool's "kv_heads" axis and pins
    the page dim ("kv_pages") unsharded, since a page is the VMEM
    streaming granule of ONE chip.  Unlike the cohort factory there is
    exactly one jit bucket: pool, table and slot count are static.
    """
    from repro.serve.pages import paged_cache_logical_axes

    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    heads_divide = cfg.n_kv_heads % model_size == 0
    kv_shard = decode_plan.kv_shard() if decode_plan is not None else 1
    shape = ShapeConfig("paged", 1, n_slots, "decode")
    rules = arch_rules(
        cfg, mesh, state_bytes_per_param=2,
        act_bytes=decode_footprint(cfg, shape, max_len) // mesh.size)
    rules = with_batch_guard(rules, mesh, n_slots)
    rules = resolve_collectives(rules, collectives)
    rules = with_kv_sharding(rules, kv_shard if heads_divide else 1)
    model = build_model(cfg, remat="none")
    p_shard = param_shardings(mesh, rules, model.param_specs())

    c_axes = paged_cache_logical_axes(cfg, cache_tpl)
    c_shard = jax.tree.map(
        lambda ax: NamedSharding(mesh, rules.act_spec(ax)),
        c_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
    d_axes = batch_logical_axes(cfg, "decode")
    d_shard = {k: NamedSharding(mesh, rules.act_spec(v))
               for k, v in d_axes.items()}

    def decode_fn(params, cache, batch):
        with use_mesh_rules(mesh, rules):
            return model.decode_step_paged(params, cache, batch, dtype=dtype)

    def prefill_chunk_fn(params, cache, tokens, pos0, slot):
        with use_mesh_rules(mesh, rules):
            return model.prefill_chunk(
                params, cache,
                {"tokens": tokens, "pos0": pos0, "slot": slot}, dtype=dtype)

    encode_fn = None
    if cfg.family == "enc_dec":
        def encode_fn(params, enc_embeds):
            with use_mesh_rules(mesh, rules):
                return model.encode_cross(
                    params, {"enc_embeds": enc_embeds}, dtype=dtype)

    if jit:
        from jax.sharding import PartitionSpec

        repl = NamedSharding(mesh, PartitionSpec())
        decode_fn = jax.jit(
            decode_fn,
            in_shardings=(p_shard, c_shard, d_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        # One retrace per distinct chunk length: the engine cuts prompts
        # into planned-page-sized chunks, so the buckets are {page, each
        # distinct prompt_len % page} -- bounded, and the full-chunk
        # bucket dominates.
        prefill_chunk_fn = jax.jit(
            prefill_chunk_fn,
            in_shardings=(p_shard, c_shard, repl, repl, repl),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        if encode_fn is not None:
            encode_fn = jax.jit(encode_fn, in_shardings=(p_shard, repl))
    return PagedServeSteps(decode=decode_fn, prefill_chunk=prefill_chunk_fn,
                           param_sharding=p_shard, cache_sharding=c_shard,
                           model=model, plan=decode_plan, encode=encode_fn)
