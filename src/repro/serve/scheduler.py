"""Continuous batching scheduler: admission/growth/eviction under the
planned KV budget.

Pure bookkeeping -- no jax anywhere -- so the admission invariant is
directly property-testable: **allocated KV bytes never exceed the planned
budget**, where allocated bytes are what the dense cache buffers actually
pin (pages x page_bytes per slot, plus each sequence's token-free state).

The schedulable unit is a *cohort*: requests admitted together with the
same prompt shape, decoded as one batch.  The family decode step carries
one scalar position for the whole batch (``cache["pos"]``), so a batch
must be position-homogeneous; mixed prompt lengths are served by running
several cohorts concurrently, interleaving one decode step per cohort per
engine tick with prefills of newly admitted cohorts in between
(iteration-level scheduling at cohort granularity).

Rules (DESIGN.md §7):

  * **admit**   FIFO by head-of-queue; a cohort is the head request plus
    every queued request with the same group key (up to ``max_slots``).
    Admitted iff ``allocated + sum_r(pages(admit_tokens_r) * page_bytes +
    state_r) <= budget`` -- ``admit_tokens`` is prompt + first decode page
    for growable caches, the full window-clamped capacity for fixed-extent
    (ring) buffers that allocate up front.
  * **reserve** growing a cohort's capacity by one page costs
    ``slots * page_bytes``; refused (False) when it would cross the
    budget -- the engine then evicts the youngest other cohort
    (recompute-style preemption: its unfinished requests requeue at the
    *front*, keeping FIFO order) and retries.
  * **release** pages free only when the whole cohort retires (the dense
    buffers are batch-shared) or when the engine compacts the batch to
    the surviving slots (``shrink_slots``).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, Hashable, List, Optional, Tuple

from repro.serve.kvcache import PageSpec


@dataclass
class Request:
    """One sequence to serve. ``features`` is the engine's opaque prompt
    payload (token ids and any family extras); ``group`` keys cohort
    compatibility (prompt length, and encoder length for enc-dec).

    ``admit_tokens`` is the KV token extent one slot actually PINS at
    admission -- prompt + first decode page for growable caches (the
    default), the full window-clamped capacity for fixed-extent buffers
    (sliding-window rings allocate up front and never grow), so the
    scheduler's accounting always matches the dense allocation."""

    rid: int
    prompt_len: int
    max_new: int
    state_bytes: int = 0
    features: Any = None
    group: Hashable = None
    admit_tokens: Optional[int] = None

    def __post_init__(self):
        if self.group is None:
            self.group = (self.prompt_len,)
        self.max_new = max(1, int(self.max_new))
        if self.admit_tokens is None:
            self.admit_tokens = self.prompt_len + 1


@dataclass
class _Cohort:
    cid: int
    reqs: List[Request]
    pages_per_slot: int
    done: set = field(default_factory=set)

    @property
    def slots(self) -> int:
        return len(self.reqs)


class ServeScheduler:
    """Admission control for ``ServeEngine`` (see module docstring)."""

    def __init__(self, budget_bytes: int, page: PageSpec,
                 max_slots: int = 8):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be positive: {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self.page = page
        self.max_slots = max(1, max_slots)
        self.pending: Deque[Request] = deque()
        self._cohorts: Dict[int, _Cohort] = {}
        self._next_cid = 0
        self.peak_bytes = 0
        self.n_evictions = 0
        # Cumulative page flow: every page a slot pins is counted once in
        # ``pages_allocated`` and credited back in ``pages_released`` when
        # it frees -- INCLUDING compaction (``shrink_slots``), which used
        # to release bytes silently without crediting the flow counters,
        # so the engine's metrics could not reconcile against the pool.
        # Invariant (property-tested): allocated - released == resident.
        self.pages_allocated = 0
        self.pages_released = 0

    # ------------------------------------------------------------- accounting
    def _cohort_bytes(self, c: _Cohort) -> int:
        per_slot = c.pages_per_slot * self.page.page_bytes
        return sum(per_slot + r.state_bytes for r in c.reqs)

    @property
    def allocated_bytes(self) -> int:
        return sum(self._cohort_bytes(c) for c in self._cohorts.values())

    @property
    def allocated_pages(self) -> int:
        """Resident pages across all live cohorts (slots x pages each)."""
        return sum(c.pages_per_slot * c.slots for c in self._cohorts.values())

    def assert_reconciled(self) -> None:
        """Pool-accounting invariant: the cumulative flow counters must
        reproduce the resident page count exactly."""
        flow = self.pages_allocated - self.pages_released
        assert flow == self.allocated_pages, (
            f"page accounting leak: allocated {self.pages_allocated} - "
            f"released {self.pages_released} = {flow} != resident "
            f"{self.allocated_pages}")

    def _note_peak(self) -> None:
        self.peak_bytes = max(self.peak_bytes, self.allocated_bytes)

    def capacity_tokens(self, cid: int) -> int:
        return self.page.capacity(self._cohorts[cid].pages_per_slot)

    def has_work(self) -> bool:
        return bool(self.pending) or bool(self._cohorts)

    def running(self) -> List[int]:
        return list(self._cohorts)

    # --------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _admission_cost(self, reqs: List[Request], pages: int) -> int:
        return sum(pages * self.page.page_bytes + r.state_bytes for r in reqs)

    def admit(self) -> List[Tuple[int, List[Request]]]:
        """Admit pending cohorts while the head of the queue fits.  Returns
        ``[(cohort_id, requests), ...]`` admitted this call.  Raises when a
        lone head request can never fit an empty budget (it would starve
        the queue forever)."""
        admitted: List[Tuple[int, List[Request]]] = []
        while self.pending:
            head = self.pending[0]
            batch = [r for r in self.pending
                     if r.group == head.group][:self.max_slots]
            # Every slot shares the cohort capacity: the widest admission
            # need sets the page count.
            pages = max(self.page.pages_for(r.admit_tokens) for r in batch)
            cost = self._admission_cost(batch, pages)
            if self.allocated_bytes + cost > self.budget_bytes:
                if not self._cohorts and len(batch) == 1:
                    raise ValueError(
                        f"request {head.rid} needs {cost} KV bytes; the "
                        f"planned budget is {self.budget_bytes} -- raise "
                        f"kv_budget_bytes or shorten the prompt")
                if not self._cohorts and len(batch) > 1:
                    # Shrink the cohort until it fits before giving up.
                    while len(batch) > 1 and self.allocated_bytes + cost \
                            > self.budget_bytes:
                        batch = batch[:-1]
                        cost = self._admission_cost(batch, pages)
                    if self.allocated_bytes + cost > self.budget_bytes:
                        raise ValueError(
                            f"request {head.rid} alone exceeds the planned "
                            f"KV budget {self.budget_bytes}")
                else:
                    break               # wait for running cohorts to retire
            ids = {id(r) for r in batch}
            self.pending = deque(r for r in self.pending
                                 if id(r) not in ids)
            cid = self._next_cid
            self._next_cid += 1
            self._cohorts[cid] = _Cohort(cid=cid, reqs=batch,
                                         pages_per_slot=pages)
            self.pages_allocated += pages * len(batch)
            admitted.append((cid, batch))
            self._note_peak()
        return admitted

    # ------------------------------------------------------------------ growth
    def reserve(self, cid: int, capacity_tokens: int) -> bool:
        """Grow cohort ``cid``'s per-slot capacity to cover
        ``capacity_tokens``.  True iff the extra pages fit the budget."""
        c = self._cohorts[cid]
        new_pages = self.page.pages_for(capacity_tokens)
        delta = (new_pages - c.pages_per_slot) * c.slots * self.page.page_bytes
        if delta <= 0:
            return True
        if self.allocated_bytes + delta > self.budget_bytes:
            return False
        self.pages_allocated += (new_pages - c.pages_per_slot) * c.slots
        c.pages_per_slot = new_pages
        self._note_peak()
        return True

    # -------------------------------------------------------------- retirement
    def finish(self, cid: int, rid: int) -> bool:
        """Mark one slot finished; True (and pages released) when the whole
        cohort is done."""
        c = self._cohorts[cid]
        c.done.add(rid)
        if len(c.done) == c.slots:
            self.pages_released += c.pages_per_slot * c.slots
            del self._cohorts[cid]
            return True
        return False

    def shrink_slots(self, cid: int, keep_rids: List[int]) -> None:
        """Compact a cohort to ``keep_rids`` (engine sliced the batch dim);
        the dropped slots' pages and state free immediately -- and are
        credited back to the flow counters (the compaction accounting
        fix: previously only ``allocated_bytes`` shrank, so the released
        pages never showed up in any cumulative metric)."""
        c = self._cohorts[cid]
        keep = set(keep_rids)
        dropped = sum(1 for r in c.reqs if r.rid not in keep)
        self.pages_released += c.pages_per_slot * dropped
        c.reqs = [r for r in c.reqs if r.rid in keep]
        c.done = {rid for rid in c.done if rid in keep}
        if not c.reqs:
            del self._cohorts[cid]

    def evict(self, cid: int) -> List[Request]:
        """Preempt a cohort: free everything, requeue its unfinished
        requests at the FRONT of the queue (FIFO order preserved), and
        return them (the engine re-prefills from scratch -- recompute
        preemption)."""
        c = self._cohorts.pop(cid)
        self.pages_released += c.pages_per_slot * c.slots
        revived = [r for r in c.reqs if r.rid not in c.done]
        for r in reversed(revived):
            self.pending.appendleft(r)
        self.n_evictions += 1
        return revived

    def youngest_other(self, cid: int) -> Optional[int]:
        """The eviction victim: the cohort holding the *newest work* other
        than ``cid`` (least sunk cost).  Age is the oldest original request
        id in the cohort -- rids are assigned at submission and survive
        eviction, so a previously preempted cohort that re-admitted keeps
        its seniority and is not picked again ahead of genuinely newer
        arrivals (no starvation by re-admission)."""
        others = [k for k in self._cohorts if k != cid]
        if not others:
            return None
        return max(others,
                   key=lambda k: min(r.rid for r in self._cohorts[k].reqs))
