"""``repro.serve`` -- the plan-driven serving engine (DESIGN.md §7).

The only serving surface: ``ServeEngine(cfg, mesh, policy)`` owns the
paged KV cache (page size from the hierarchical planner's decode
workload), the continuous-batching scheduler (admission under the planned
KV budget), and the sampling API.  ``launch/serve.py`` is a thin CLI over
``ServeEngine.generate``; ``make_serve_steps`` (ex ``launch.trainer``)
lives in ``repro.serve.steps``.
"""

from repro.serve.engine import (  # noqa: F401
    PlanError,
    ServeEngine,
    ServePolicy,
    plan_decode,
)
from repro.serve.kvcache import (  # noqa: F401
    PageSpec,
    align_capacity,
    grow_cache,
    kv_token_bytes,
    page_spec_from_plan,
    request_state_bytes,
)
from repro.serve.pages import (  # noqa: F401
    PAGED_FAMILIES,
    PagePool,
    PagedScheduler,
    init_paged_cache,
    reset_slot,
    paged_cache_logical_axes,
)
from repro.serve.prefix import (  # noqa: F401
    PREFIX_FAMILIES,
    PrefixHit,
    RadixPrefixCache,
)
from repro.serve.sampling import SamplingConfig, sample  # noqa: F401
from repro.serve.scheduler import Request, ServeScheduler  # noqa: F401
from repro.serve.steps import (  # noqa: F401
    PagedServeSteps,
    ServeSteps,
    make_paged_steps,
    make_serve_steps,
)

__all__ = [
    "PAGED_FAMILIES",
    "PREFIX_FAMILIES",
    "PagePool",
    "PagedScheduler",
    "PagedServeSteps",
    "PageSpec",
    "PlanError",
    "PrefixHit",
    "RadixPrefixCache",
    "Request",
    "SamplingConfig",
    "ServeEngine",
    "ServePolicy",
    "ServeScheduler",
    "ServeSteps",
    "align_capacity",
    "grow_cache",
    "init_paged_cache",
    "reset_slot",
    "kv_token_bytes",
    "make_paged_steps",
    "make_serve_steps",
    "page_spec_from_plan",
    "paged_cache_logical_axes",
    "plan_decode",
    "request_state_bytes",
    "sample",
]
