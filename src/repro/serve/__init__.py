"""``repro.serve`` -- the plan-driven serving engine (DESIGN.md §7).

The only serving surface: ``ServeEngine(cfg, mesh, policy)`` owns the
paged KV cache (page size from the hierarchical planner's decode
workload), the continuous-batching scheduler (admission under the planned
KV budget), and the sampling API.  ``launch/serve.py`` is a thin CLI over
``ServeEngine.generate``; ``make_serve_steps`` (ex ``launch.trainer``)
lives in ``repro.serve.steps``.
"""

from repro.serve.engine import (  # noqa: F401
    ServeEngine,
    ServePolicy,
    plan_decode,
)
from repro.serve.kvcache import (  # noqa: F401
    PageSpec,
    align_capacity,
    grow_cache,
    kv_token_bytes,
    page_spec_from_plan,
    request_state_bytes,
)
from repro.serve.sampling import SamplingConfig, sample  # noqa: F401
from repro.serve.scheduler import Request, ServeScheduler  # noqa: F401
from repro.serve.steps import ServeSteps, make_serve_steps  # noqa: F401

__all__ = [
    "PageSpec",
    "Request",
    "SamplingConfig",
    "ServeEngine",
    "ServePolicy",
    "ServeScheduler",
    "ServeSteps",
    "align_capacity",
    "grow_cache",
    "kv_token_bytes",
    "make_serve_steps",
    "page_spec_from_plan",
    "plan_decode",
    "request_state_bytes",
    "sample",
]
