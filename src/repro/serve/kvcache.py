"""Paged KV cache: plan-sized pages over the family cache pytrees.

The hierarchical planner's decode workload (``repro.plan``) fits one
streaming *page* -- a sublane-aligned run of tokens of one layer's KV slice
-- to the VMEM leaf; this module turns that page into the allocation
granule of the serving engine:

  * ``kv_token_bytes`` / ``request_state_bytes`` -- the per-family memory
    model (the decode analogue of ``launch.specs.decode_footprint``, split
    into the token-proportional KV term and the token-free state term).
  * ``PageSpec`` -- page math: tokens -> pages -> capacity -> global bytes,
    the units the scheduler budgets in.
  * ``grow_cache`` / ``cache_capacity`` / ``take_slots`` -- page-granular
    operations on the family cache pytrees from ``Model.init_cache``: the
    sequence dim of every growable KV buffer is always a whole number of
    pages, grown one page at a time as decode fills it (each new capacity
    is one more jit bucket, the standard static-shape serving trade).

Sliding-window ring caches are deliberately *not* growable: the ring's
slot map is ``pos mod buffer_len``, so resizing the buffer mid-stream
would scramble it -- windowed models allocate their (window-clamped)
capacity at admission instead.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.plan import HierarchicalPlan

PyTree = Any

#: Fallback page size (tokens) for families with no paged KV at all
#: (pure-recurrent xLSTM: the planner has no page level to size).
DEFAULT_PAGE_TOKENS = 64

#: Cache leaves whose axis 2 is the paged sequence dim.  ``cross_k`` /
#: ``cross_v`` (enc-dec) are keyed by *encoder* position and never grow.
GROWABLE_LEAVES = ("k", "v", "ckv", "krope")


# ---------------------------------------------------------------------------
# Per-family KV memory model
# ---------------------------------------------------------------------------


def kv_token_bytes(cfg: ModelConfig, dtype_bytes: int = 2
                   ) -> Tuple[int, int, int]:
    """``(bytes_per_token, kv_layers, kv_heads)`` of the growing KV state.

    ``bytes_per_token`` is the *global* per-token footprint across all KV
    layers and heads (the ISSUE's "per-token KV bytes x heads x layers"),
    ``kv_layers`` how many layers hold a per-token cache, and ``kv_heads``
    the head extent the mesh level may shard (0 = not head-shardable:
    MLA's latent cache is rank-compressed, not per-head).  Families whose
    caches are token-count-independent (xLSTM; the SSM part of hybrids)
    return ``(0, 0, 0)`` -- their cost is all in
    ``request_state_bytes``.
    """
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        per_layer = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        return per_layer * dtype_bytes * cfg.n_layers, cfg.n_layers, 0
    if cfg.family == "hybrid_ssm":
        s = cfg.ssm
        n_apps = -(-cfg.n_layers // s.attn_every) if s.attn_every else 0
        if not n_apps:
            return 0, 0, 0
        return 2 * kv * hd * dtype_bytes * n_apps, n_apps, kv
    if cfg.family == "xlstm":
        return 0, 0, 0
    if cfg.family == "enc_dec":
        nd = cfg.enc_dec.n_decoder_layers
        return 2 * kv * hd * dtype_bytes * nd, nd, kv
    return 2 * kv * hd * dtype_bytes * cfg.n_layers, cfg.n_layers, kv


def request_state_bytes(cfg: ModelConfig, enc_len: int = 0,
                        dtype_bytes: int = 2) -> int:
    """Per-sequence, token-count-independent cache bytes (the scheduler's
    fixed admission cost): SSM conv+state buffers, xLSTM matrix states,
    enc-dec cross K/V (proportional to the *encoder* length, pinned at
    admission).  Mirrors ``Model.init_cache`` shapes per batch element.
    """
    d = cfg.d_model
    if cfg.family == "hybrid_ssm":
        s = cfg.ssm
        d_inner = s.expand * d
        h = d_inner // s.head_dim
        conv_ch = d_inner + 2 * s.state_dim
        conv = cfg.n_layers * (s.conv_width - 1) * conv_ch * dtype_bytes
        ssm = cfg.n_layers * h * s.head_dim * s.state_dim * 4  # fp32
        return conv + ssm
    if cfg.family == "xlstm":
        x = cfg.xlstm
        di = -(-int(x.mlstm_proj_factor * d) // 128) * 128  # _round128
        h = cfg.n_heads
        dh, dhs = di // h, d // h
        n_s = cfg.n_layers // x.slstm_every
        n_m = cfg.n_layers - n_s
        mlstm = n_m * ((x.conv_width - 1) * di * dtype_bytes
                       + (h * dh * dh + h * dh + h) * 4)
        slstm = n_s * 4 * h * dhs * 4
        return mlstm + slstm
    if cfg.family == "enc_dec":
        nd = cfg.enc_dec.n_decoder_layers
        return 2 * nd * enc_len * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
    return 0


# ---------------------------------------------------------------------------
# Page math
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PageSpec:
    """The serving engine's allocation granule, read off the plan tree.

    ``page_tokens`` comes from the decode plan's page level;
    ``token_bytes`` is the *global* per-token KV footprint (all layers,
    unsharded), so ``page_bytes = page_tokens * token_bytes`` is what one
    page costs the fleet-wide budget the scheduler enforces.
    """

    page_tokens: int
    token_bytes: int

    @property
    def page_bytes(self) -> int:
        return self.page_tokens * self.token_bytes

    def pages_for(self, tokens: int) -> int:
        return max(1, -(-max(0, tokens) // self.page_tokens))

    def capacity(self, pages: int) -> int:
        return max(1, pages) * self.page_tokens


def page_spec_from_plan(plan: Optional[HierarchicalPlan],
                        cfg: ModelConfig,
                        dtype_bytes: int = 2) -> PageSpec:
    """PageSpec from a decode plan tree (fallback when no page level --
    token-free families -- keeps the scheduler's units well defined)."""
    tok_bytes, _, _ = kv_token_bytes(cfg, dtype_bytes)
    page = plan.page_plan() if plan is not None else None
    if page is None:
        return PageSpec(page_tokens=DEFAULT_PAGE_TOKENS,
                        token_bytes=tok_bytes)
    return PageSpec(page_tokens=int(page["page_tokens"]),
                    token_bytes=tok_bytes)


def align_capacity(tokens: int, page: PageSpec) -> int:
    """Smallest whole-page capacity >= ``tokens``."""
    return page.capacity(page.pages_for(tokens))


# ---------------------------------------------------------------------------
# Page-granular cache pytree ops
# ---------------------------------------------------------------------------


def _walk(node: PyTree, fn, path=()):
    if isinstance(node, dict):
        return {k: _walk(v, fn, path + (k,)) for k, v in node.items()}
    return fn(path, node)


def _is_growable(cfg: ModelConfig, path, leaf) -> bool:
    name = path[-1] if path else ""
    if name not in GROWABLE_LEAVES or getattr(leaf, "ndim", 0) < 3:
        return False
    if cfg.sliding_window and leaf.shape[2] <= cfg.sliding_window:
        return False                      # ring buffer: fixed extent
    return True


def cache_capacity(cfg: ModelConfig, cache: PyTree) -> Optional[int]:
    """Token capacity of the cache's growable KV buffers (None when the
    family has none -- recurrent state is position-unbounded)."""
    caps = []

    def visit(path, leaf):
        if _is_growable(cfg, path, leaf):
            caps.append(leaf.shape[2])
        return leaf

    _walk(cache, visit)
    return min(caps) if caps else None


def grow_cache(cfg: ModelConfig, cache: PyTree, new_capacity: int) -> PyTree:
    """Zero-pad every growable KV buffer's sequence dim up to
    ``new_capacity`` (a whole number of pages -- the engine grows one page
    at a time).  Attention correctness does not depend on the extra slots:
    decode masks keys at ``k_pos >= kv_len``.
    """
    import jax.numpy as jnp

    def visit(path, leaf):
        if not _is_growable(cfg, path, leaf):
            return leaf
        pad = new_capacity - leaf.shape[2]
        if pad <= 0:
            return leaf
        widths = [(0, 0)] * leaf.ndim
        widths[2] = (0, pad)
        return jnp.pad(leaf, widths)

    return _walk(cache, visit)


def take_slots(cache: PyTree, idx) -> PyTree:
    """Select batch slots ``idx`` (cohort compaction: retired sequences'
    pages are released by shrinking the batch dim).  Every array leaf with
    >= 2 dims carries the batch on axis 1 (layer-stacked caches); ``len``
    (per-layer) and ``pos`` (scalar) are batch-free."""
    import jax.numpy as jnp

    idx = jnp.asarray(idx)

    def visit(path, leaf):
        name = path[-1] if path else ""
        if name == "len" or getattr(leaf, "ndim", 0) < 2:
            return leaf
        return jnp.take(leaf, idx, axis=1)

    return _walk(cache, visit)
