"""Cross-request KV reuse: a radix prefix cache over the page pool
(DESIGN.md §11).

Millions of users share system prompts and few-shot prefixes.  The KV
entries for a prompt's first ``i`` tokens depend only on those tokens
(per-token projections + RoPE at absolute positions), so two requests
that agree on a prefix produce bitwise-identical KV for it -- there is no
reason to prefill it twice.  This module makes the serving runtime, not
the caller, decide which prefixes stay resident -- the paper's thesis
applied ACROSS requests: finished prompt pages are keyed by their token
content in a radix tree whose nodes hold refcounted pool pages, and
admission walks the tree so a matching request starts chunked prefill at
the first unshared token.

  * **Node = one completed page.**  A radix node is keyed by the exact
    token tuple of one ``page_tokens``-sized block (dict hashing IS the
    token-prefix hash -- exact, no collision risk); its path from the
    root spells the full prefix.  Each node holds one reference on its
    physical page (``PagePool.incref``), so slot tables and the tree
    share pages safely: ``pool.total_refs == slot refs + tree refs`` is
    the engine's per-tick ledger.
  * **Full pages map read-only.**  A hit increfs the matched chain's
    pages straight into the new slot's page table.  Writes never land
    there: the suffix starts at or after the shared frontier, and decode
    positions only grow, so table-scattered KV writes only ever touch the
    slot's PRIVATE pages (asserted by the engine every chunk/decode).
  * **Mid-page divergence = copy-on-write.**  When the shared prefix
    ends inside a page (attention families), the hit allocates a fresh
    page, the engine device-copies the partially-matching node's page
    into it, and the slot writes its suffix into the private copy -- only
    that one page is duplicated.
  * **Recurrent state snapshots.**  Hybrid-SSM/xLSTM KV is not enough:
    the recurrent state after token ``i`` must be restored too.  Chunked
    prefill snapshots each slot's state rows at page boundaries; nodes
    store the snapshot and hits for these families round DOWN to the
    deepest node boundary (no mid-page CoW -- there is no state to
    restore inside a page).
  * **Plan-consulted eviction.**  A prefix is worth caching iff its
    pages fit the mesh-level HBM leftover the planner already recorded
    (``HierarchicalPlan.prefix_budget()``, from
    ``detail["page_table"]["prefix_budget_bytes"]``).  Inserting past
    the budget evicts least-recently-used refcount-zero leaves (nodes
    whose page no slot maps) until the new node fits; pool pressure from
    live slots evicts the same way first (``PagedScheduler._alloc``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PyTree = Any

#: Families whose prefix KV is exactly reusable across requests.  Pure
#: attention families reuse at token granularity (CoW inside a page);
#: recurrent-state families reuse at page granularity (state snapshots
#: exist only at page boundaries).  enc_dec is excluded: its decoder
#: self-KV depends on the encoder output through cross-attention, so
#: equal decoder prefixes do NOT imply equal KV.  vlm never pages.
PREFIX_FAMILIES = ("dense", "moe", "hybrid_ssm", "xlstm", "mla_moe")

#: Families that need a state snapshot restored at the hit boundary.
STATE_FAMILIES = ("hybrid_ssm", "xlstm")


@dataclass
class PrefixHit:
    """One admission-time match against the radix tree.

    ``tokens`` prompt tokens are already resident (always ``<
    prompt_len`` -- at least one suffix token remains so the final-token
    logits are computed, never replayed).  ``pages`` maps the slot's
    logical pages ``0..len-1``; all but a CoW page are SHARED (increffed)
    read-only mappings.  ``cow = (src, dst)`` asks the engine to
    device-copy page ``src`` into the private page ``dst`` (already
    allocated, last entry of ``pages``) before the suffix chunk runs.
    ``state`` is the host-side recurrent-state snapshot to restore into
    the slot's state rows (state families only)."""

    tokens: int
    pages: List[int] = field(default_factory=list)
    cow: Optional[Tuple[int, int]] = None
    state: Optional[PyTree] = None


class _Node:
    __slots__ = ("key", "page", "state", "parent", "children",
                 "last_used", "cost")

    def __init__(self, key: Tuple[int, ...], page: Optional[int],
                 state: Optional[PyTree], parent: Optional["_Node"],
                 cost: int):
        self.key = key
        self.page = page                # physical pool page id (or None)
        self.state = state              # host snapshot after this block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0
        self.cost = cost                # logical bytes billed to budget


def _state_nbytes(state: Optional[PyTree]) -> int:
    if state is None:
        return 0
    import jax

    return int(sum(np.asarray(a).nbytes for a in jax.tree.leaves(state)))


class RadixPrefixCache:
    """The radix tree + page-sharing policy (pure python, like the
    scheduler).  One instance persists across ``generate`` calls on the
    engine's paged session; ``PagedScheduler`` consults it at admission
    and squeezes it under pool pressure."""

    def __init__(self, page_tokens: int, page_bytes: int,
                 budget_bytes: int, pool, has_state: bool = False,
                 obs=None, tracer=None):
        self.page_tokens = max(1, int(page_tokens))
        self.page_bytes = max(0, int(page_bytes))   # logical, 0=token-free
        self.budget_bytes = max(0, int(budget_bytes))
        self.pool = pool
        self.has_state = has_state
        self._root = _Node((), None, None, None, 0)
        self._nodes: List[_Node] = []           # flat registry (LRU scans)
        self._clock = 0                         # monotonic LRU clock
        self.resident_bytes = 0
        self.n_pages = 0                        # tree-held page references
        self.hits = 0
        self.misses = 0
        self.inserted_nodes = 0
        self.evicted_nodes = 0
        self.evicted_pages = 0
        # Observability hooks (DESIGN.md §13): hit/miss/evict land in
        # the trace; the resident-bytes gauges feed the plan-vs-actual
        # row for the mesh-level HBM leftover budgeting this tree.
        self.obs = obs
        self.tracer = tracer

    def _publish(self) -> None:
        if self.obs is not None:
            self.obs.set("prefix_resident_bytes", self.resident_bytes,
                         unit="B")
            self.obs.set_max("prefix_peak_resident_bytes",
                             self.resident_bytes, unit="B")

    def _record_miss(self) -> None:
        self.misses += 1
        if self.tracer is not None:
            self.tracer.instant("prefix_miss")

    # ----------------------------------------------------------------- LRU
    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_used = self._clock

    # ------------------------------------------------------------ matching
    def _block(self, tokens: np.ndarray, j: int) -> Tuple[int, ...]:
        t = self.page_tokens
        return tuple(int(x) for x in tokens[j * t:(j + 1) * t])

    def _walk(self, tokens: np.ndarray) -> List[_Node]:
        """The chain of fully-matching page nodes from the root."""
        chain: List[_Node] = []
        node = self._root
        for j in range(len(tokens) // self.page_tokens):
            child = node.children.get(self._block(tokens, j))
            if child is None:
                break
            chain.append(child)
            node = child
        return chain

    @property
    def n_nodes(self) -> int:
        """Resident node count (``engine.stats()`` / router telemetry)."""
        return len(self._nodes)

    def match(self, tokens: np.ndarray):
        """Read-only longest page-aligned match -- the disaggregation
        EXPORT lookup (``ServeEngine.export_pages``): no refcounts move,
        no CoW page is allocated, matched nodes are only LRU-touched.
        Returns ``(covered_tokens, page_ids, snaps)`` where ``snaps`` maps
        page-boundary token counts to host state snapshots (state
        families; empty otherwise).  A chain stops at the first node
        without a page (token-free families) or, for state families,
        without a snapshot -- a partial transfer would be unresumable."""
        tokens = np.asarray(tokens).reshape(-1)
        chain = self._walk(tokens)
        t = self.page_tokens
        covered = 0
        pages: List[int] = []
        snaps: Dict[int, PyTree] = {}
        for j, node in enumerate(chain):
            if self.page_bytes > 0 and node.page is None:
                break
            if self.has_state and node.state is None:
                break
            if node.page is not None:
                pages.append(node.page)
            if node.state is not None:
                snaps[(j + 1) * t] = node.state
            covered = (j + 1) * t
            self._touch(node)
        return covered, pages, snaps

    def admit(self, tokens: np.ndarray) -> Optional[PrefixHit]:
        """Match ``tokens`` against the tree and, on a hit, take the page
        references the new slot will hold: one incref per shared full
        page, plus one freshly-allocated private page when the prefix
        ends mid-page (the CoW copy itself is the engine's job -- this
        layer never touches device memory).  Returns None on a miss."""
        tokens = np.asarray(tokens).reshape(-1)
        plen = int(tokens.shape[0])
        t = self.page_tokens
        if plen < 2:
            self._record_miss()
            return None                   # no room for a suffix token
        chain = self._walk(tokens)
        deepest = chain[-1] if chain else self._root
        # Longest common in-page token run against the next block's
        # children -- the CoW candidate (attention families only: a
        # recurrent state cannot be restored mid-page).
        part_d, part_node = 0, None
        if not self.has_state:
            j = len(chain)
            block = tuple(int(x) for x in tokens[j * t:(j + 1) * t])
            for key, child in deepest.children.items():
                d = 0
                for a, b in zip(key, block):
                    if a != b:
                        break
                    d += 1
                if d > part_d and child.page is not None:
                    part_d, part_node = d, child
        hit = min(len(chain) * t + part_d, plen - 1)
        full = hit // t
        if full < len(chain) and not self.has_state:
            # The whole prompt is cached (the ``plen - 1`` cap bit): the
            # final partial page CoWs from the next fully-matched node.
            part_node = chain[full] if chain[full].page is not None else None
        part_d = hit - full * t
        if part_d and part_node is None:
            hit, part_d = full * t, 0     # round down: nothing to CoW from
        if self.has_state:
            full = min(full, len(chain))
            hit, part_d, part_node = full * t, 0, None
        if hit <= 0:
            self._record_miss()
            return None
        state = chain[full - 1].state if self.has_state and full else None
        pages: List[int] = []
        for node in chain[:full]:
            if node.page is None:
                break                     # token-free family: no pages
            self.pool.incref(node.page)
            pages.append(node.page)
        cow = None
        if part_d and part_node is not None:
            dst = self._alloc_private()
            if dst is None:
                hit = full * t            # degrade to the full-page hit
                if hit <= 0:
                    self._record_miss()
                    return None
            else:
                cow = (part_node.page, dst)
                pages.append(dst)
                self._touch(part_node)
        for node in chain[:full]:
            self._touch(node)
        self.hits += 1
        if self.tracer is not None:
            self.tracer.instant("prefix_hit",
                                args={"tokens": hit,
                                      "cow": cow is not None})
        return PrefixHit(tokens=hit, pages=pages, cow=cow, state=state)

    def _alloc_private(self) -> Optional[int]:
        ids = self.pool.alloc(1)
        if ids is None:
            self.release_pages(need=1)
            ids = self.pool.alloc(1)
        return ids[0] if ids else None

    # ----------------------------------------------------------- insertion
    def insert(self, tokens: np.ndarray, slot_pages: List[Optional[int]],
               snaps: Optional[Dict[int, PyTree]] = None) -> int:
        """Publish a finished prefill's COMPLETED pages into the tree.

        ``slot_pages`` is the slot's logical page table at prefill
        completion; only the ``prompt_len // page_tokens`` full prompt
        pages are cacheable (the partial tail page will be decoded into).
        ``snaps`` maps page-boundary token counts to host state snapshots
        (state families; a chain stops at the first boundary without
        one).  Existing nodes are LRU-touched, new nodes incref their
        page; insertion stops when the budget cannot be made to fit even
        after evicting every unreferenced leaf.  Returns the number of
        nodes created."""
        tokens = np.asarray(tokens).reshape(-1)
        t = self.page_tokens
        node = self._root
        created = 0
        for j in range(int(tokens.shape[0]) // t):
            key = self._block(tokens, j)
            child = node.children.get(key)
            if child is not None:
                self._touch(child)
                node = child
                continue
            page = None
            if self.page_bytes > 0:
                if j >= len(slot_pages) or slot_pages[j] is None:
                    break                 # window-reclaimed: chain ends
                page = slot_pages[j]
            state = None
            if self.has_state:
                state = (snaps or {}).get((j + 1) * t)
                if state is None:
                    break                 # no snapshot at this boundary
            cost = self.page_bytes + _state_nbytes(state)
            if not self._make_room(cost):
                break
            if page is not None:
                self.pool.incref(page)
                self.n_pages += 1
            child = _Node(key, page, state, node, cost)
            node.children[key] = child
            self._nodes.append(child)
            self.resident_bytes += cost
            self.inserted_nodes += 1
            created += 1
            self._touch(child)
            node = child
        self._publish()
        return created

    # ------------------------------------------------------------ eviction
    def _evictable(self, node: _Node) -> bool:
        """Evictable = a leaf no slot references: interior nodes keep
        their children's prefix valid, and a page some slot still maps
        (refcount > 1: tree ref + slot refs) is in active use."""
        return not node.children and (
            node.page is None or self.pool.refcount(node.page) == 1)

    def _evict_one(self, need_page: bool = False) -> bool:
        """Drop the least-recently-used evictable leaf (set ``need_page``
        to only consider page-holding leaves -- pool pressure wants
        physical pages back, not state bytes)."""
        best = None
        for node in self._nodes:
            if not self._evictable(node):
                continue
            if need_page and node.page is None:
                continue
            if best is None or node.last_used < best.last_used:
                best = node
        if best is None:
            return False
        del best.parent.children[best.key]
        self._nodes.remove(best)
        if best.page is not None:
            self.pool.free([best.page])   # decref: tree held rc 1
            self.n_pages -= 1
            self.evicted_pages += 1
        self.resident_bytes -= best.cost
        self.evicted_nodes += 1
        if self.tracer is not None:
            self.tracer.instant("prefix_evict",
                                args={"page": best.page,
                                      "resident": self.resident_bytes})
        self._publish()
        return True

    def _make_room(self, cost: int) -> bool:
        """Evict LRU leaves until ``cost`` more bytes fit the plan's
        budget.  Repeated leaf eviction IS subtree eviction: an interior
        node becomes a leaf once its children go."""
        if cost > self.budget_bytes:
            return False
        while self.resident_bytes + cost > self.budget_bytes:
            if not self._evict_one():
                return False
        return True

    def release_pages(self, need: int = 1) -> int:
        """Pool back-pressure: evict page-holding LRU leaves until the
        pool can grant ``need`` pages (or nothing evictable remains).
        Returns the number of pages returned to the free list."""
        freed = 0
        while self.pool.free_pages < need:
            if not self._evict_one(need_page=True):
                break
            freed += 1
        return freed

    def clear(self) -> int:
        """Evict every evictable node (tests / explicit cache drops)."""
        n = 0
        while self._evict_one():
            n += 1
        return n
