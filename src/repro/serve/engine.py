"""``ServeEngine`` -- the plan-driven serving engine (the only serving
surface; ``launch/serve.py`` is a thin CLI over it).

One declarative call -- ``ServeEngine(cfg, mesh, policy).generate(prompts)``
-- and every batch/page/shard choice falls out of ``plan_run``:

  * ``plan_decode`` builds the decode workload (per-token KV bytes x heads
    x layers, ``core.plan.Workload``) and walks the mesh hierarchy once.
    The innermost mesh level chooses the **KV head sharding**
    (``kv_shard``), the VMEM leaf the **page size** (``page_tokens``).
  * ``serve.kvcache.PageSpec`` turns the page into the allocation granule;
    cache buffers are whole pages, grown one page at a time.
  * ``serve.scheduler.ServeScheduler`` admits/evicts requests so the
    resident KV footprint never exceeds the planned budget (continuous
    batching at cohort granularity, prefill/decode interleaved).
  * ``serve.steps.make_serve_steps(..., decode_plan=...)`` lowers the steps
    with exactly the plan's cache sharding.

Two batching engines share the plan (``ServePolicy.batching``):

  * ``"cohort"`` (PR 4, the A/B baseline): the batch unit is a *cohort*
    of same-shape prompts (the family decode step carries one scalar
    position per batch); mixed prompt lengths run as concurrently decoded
    cohorts, one decode step per cohort per engine tick.
  * ``"paged"`` (DESIGN.md §8): a fixed batch of decode *slots* over one
    global page pool (``serve.pages``).  Decode is per-slot end to end --
    position vectors, per-row kv_len masks, a Pallas paged-attention
    gather through per-slot page tables -- so a finished slot's pages
    free immediately and the slot is backfilled by a NEW request
    mid-flight, and the whole run is one jit bucket.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan import (
    HierarchicalPlan,
    PlanError,
    PlanPolicy,
    Workload,
    plan_run,
)
from repro.obs import MetricsView, Registry, RingLog, Tracer
from repro.serve.kvcache import (
    PageSpec,
    align_capacity,
    cache_capacity,
    grow_cache,
    kv_token_bytes,
    page_spec_from_plan,
    request_state_bytes,
    take_slots,
)
from repro.serve.sampling import SamplingConfig, sample, step_key
from repro.serve.scheduler import Request, ServeScheduler
from repro.serve.steps import ServeSteps, make_serve_steps

PyTree = Any


# ---------------------------------------------------------------------------
# The decode plan
# ---------------------------------------------------------------------------


def plan_decode(
    cfg: ModelConfig,
    mesh,
    *,
    max_len: int = 4096,
    batch: int = 1,
    dtype_bytes: int = 2,
    spec=None,
    hierarchy=None,
    cluster: Optional[int] = None,
) -> HierarchicalPlan:
    """``plan_run`` over the decode workload: the serving counterpart of
    ``dist.sharding.mesh_plan``.

    The mesh hierarchy's interconnect level spans the tensor-parallel
    ("model") axis -- the axis KV heads can shard over; the KV cache's
    batch dim already shards over the data axes, so the shardable state is
    one data-shard's resident KV (``kv_bytes_per_token * max_len * batch /
    data_n``) and the per-chip weight shard rides along as the replicated
    reserve.  ``max_len`` bounds one sequence's resident tokens (the page
    search domain) and ``batch`` the concurrently resident sequences.

    ``cluster=N`` plans a MULTI-REPLICA fleet: the hierarchy grows a DCN
    level over N hosts and the requested replica count seeds the
    outermost search, so the DCN level's realized ``np`` (``replicas()``)
    is the fleet width ``repro.cluster`` stands up -- memory pressure can
    raise it, never shrink it.  Without ``cluster``, a plan containing a
    DCN level is inadmissible: one ``ServeEngine`` cannot realize
    multi-host placement, so the walk raises a structured ``PlanError``
    (the old single-replica guarantee, now a typed failure instead of a
    CI grep).
    """
    sizes = dict(mesh.shape)
    model_n = max(1, sizes.get("model", 1))
    total = 1
    for v in sizes.values():
        total *= v
    data_n = max(1, total // model_n)
    tok_bytes, layers, heads = kv_token_bytes(cfg, dtype_bytes)
    kv_state = (tok_bytes * max_len * batch) // data_n
    weights = cfg.param_count() * dtype_bytes // model_n
    stream = batch * cfg.d_model * dtype_bytes * 4
    fixed = batch * request_state_bytes(cfg, enc_len=max_len,
                                        dtype_bytes=dtype_bytes) // data_n
    if hierarchy is None:
        if spec is None:
            from repro.hw.tpu import chip_spec
            spec = chip_spec()
        hierarchy = spec.hierarchy(mesh_devices=model_n,
                                   hosts=max(1, cluster or 1))
    plan = plan_run(
        hierarchy,
        Workload(
            state_bytes=max(1, kv_state),
            replicated_bytes=weights + stream + fixed,
            overhead=cfg.overhead,
            dtype_bytes=dtype_bytes,
            kv_bytes_per_token=tok_bytes,
            kv_layers=max(1, layers),
            kv_heads=heads,
            max_tokens=max_len,
        ),
        PlanPolicy(spec=spec, n_workers=max(1, cluster or 1)),
    )
    if cluster is None and plan.level("DCN") is not None:
        raise PlanError(
            "decode plan contains a DCN level but no cluster was "
            "requested: a single ServeEngine cannot realize multi-host "
            "placement -- pass cluster=N and serve it with repro.cluster, "
            "or plan against a single-host hierarchy",
            level="DCN", plan=plan)
    return plan


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServePolicy:
    """Engine knobs. Everything memory-shaped defaults from the plan; the
    overrides exist for tests and for operators who know better.

    ``batching`` selects the engine: "cohort" (PR 4's position-homogeneous
    cohorts -- the A/B baseline), "paged" (the global page pool with
    per-slot continuous batching, DESIGN.md §8; families without a paged
    decode path -- VLM -- fall back to cohort), or "auto" (paged exactly
    when the decode plan exposes a page level to size the pool from AND
    the family has a per-slot decode path).

    ``prefill`` selects how the paged engine fills a new slot's KV:
    "chunked" cuts the prompt into planned-page-sized chunks written
    directly into pool pages, interleaving decode ticks for resident
    slots between chunks (DESIGN.md §10); "monolithic" runs the same
    direct-to-pool path as one whole-prompt chunk (the TTFT/stall A/B
    baseline -- identical tokens, no interleave).  Cohort batching
    ignores it.

    ``prefix_cache`` turns on cross-request KV reuse in the paged engine
    (DESIGN.md §11): "radix" keeps finished prompt pages resident in a
    refcounted radix tree (budgeted by ``plan.prefix_budget()``, the
    mesh-level HBM leftover) so a request sharing a cached prefix
    prefills only its unshared suffix; "off" disables it.  Families
    without exact cross-request KV reuse (enc-dec, vlm) and cohort
    batching ignore it.
    """

    max_new_tokens: int = 16
    max_slots: int = 8              # sequences per cohort / decode slots
    max_len: int = 4096             # per-sequence planning bound (tokens)
    kv_fraction: float = 0.8        # share of post-weights HBM given to KV
    kv_budget_bytes: Optional[int] = None   # override the planned budget
    batching: str = "cohort"        # | "paged" | "auto"
    prefill: str = "chunked"        # | "monolithic" (paged engine only)
    prefix_cache: str = "off"       # | "radix" (paged engine only)
    sampling: SamplingConfig = field(default_factory=SamplingConfig)

    def __post_init__(self):
        if self.batching not in ("cohort", "paged", "auto"):
            raise ValueError(f"unknown batching {self.batching!r}; "
                             f"one of ('cohort', 'paged', 'auto')")
        if self.prefill not in ("chunked", "monolithic"):
            raise ValueError(f"unknown prefill {self.prefill!r}; "
                             f"one of ('chunked', 'monolithic')")
        if self.prefix_cache not in ("off", "radix"):
            raise ValueError(f"unknown prefix_cache {self.prefix_cache!r}; "
                             f"one of ('off', 'radix')")


@dataclass
class _PagedSession:
    """Device state the paged engine keeps ALIVE between ``generate``
    calls when the prefix cache is on: the pool's refcounts, the pooled
    cache buffers (they hold the cached prefixes' KV) and the radix tree
    itself.  Rebuilt whenever the pool geometry changes (the cached pages
    would not survive a reshape)."""

    key: Any
    pool: Any                       # serve.pages.PagePool
    cache: PyTree                   # pooled cache pytree
    prefix: Any                     # serve.prefix.RadixPrefixCache


@dataclass
class _Run:
    """Engine-side state of one admitted cohort."""

    cid: int
    reqs: List[Request]
    steps: ServeSteps
    cache: PyTree
    next_tokens: Any                # (B, 1) int32 -- last sampled token
    capacity: Optional[int]         # growable token capacity (None: fixed)
    pos: int                        # tokens written so far per slot
    active: Dict[int, int]          # rid -> slot index, still decoding


class ServeEngine:
    """Plan-driven serving engine (see module docstring)."""

    #: Ring-buffer bounds (DESIGN.md §13): the tracer's event ring, the
    #: interleave log, and each request's token-time log all cap here --
    #: overflow drops the oldest entry and counts it (``tracer.dropped``,
    #: ``interleave_dropped``, ``token_times_dropped``).
    TRACE_CAPACITY = 65536
    LOG_CAPACITY = 65536
    TOKEN_TIMES_CAPACITY = 8192

    def __init__(
        self,
        cfg: ModelConfig,
        mesh=None,
        policy: ServePolicy = ServePolicy(),
        dtype=None,
        params: Optional[PyTree] = None,
        seed: int = 0,
        spec=None,
        hierarchy=None,
        replica: int = 0,
    ):
        import jax
        import jax.numpy as jnp

        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy
        self.dtype = dtype if dtype is not None else jnp.float32
        self._dtype_bytes = jnp.dtype(self.dtype).itemsize
        self.plan = plan_decode(
            cfg, mesh, max_len=policy.max_len,
            batch=policy.max_slots, dtype_bytes=self._dtype_bytes,
            spec=spec, hierarchy=hierarchy)
        self.page: PageSpec = page_spec_from_plan(self.plan, cfg,
                                                  self._dtype_bytes)
        self.scheduler = ServeScheduler(
            self._kv_budget(), self.page, max_slots=policy.max_slots)
        from repro.serve.pages import PAGED_FAMILIES
        self.batching = policy.batching
        if self.batching == "auto":
            # Paged exactly when the plan exposes a page level to size the
            # pool from (token-free families have none) and the family has
            # a per-slot decode path; explicit "paged" still serves
            # page-free families (xLSTM) at slot granularity.
            self.batching = ("paged" if self.plan.page_plan() is not None
                             and cfg.family in PAGED_FAMILIES else "cohort")
        elif self.batching == "paged" and cfg.family not in PAGED_FAMILIES:
            self.batching = "cohort"        # no paged decode path: fall back
        from repro.models.model import build_model
        self.model = build_model(cfg, remat="none")
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed),
                                            dtype=jnp.float32))
        self._steps_cache: Dict[Any, ServeSteps] = {}
        self._paged_steps_cache: Dict[Any, Any] = {}
        self._paged_session: Optional[_PagedSession] = None
        self._live_pool = None          # the CURRENT run's PagePool
        self._live_sched = None         # ... and PagedScheduler (telemetry)
        self._stream_cb = None          # per-call on_token callback
        self._stream_ix: Dict[int, int] = {}    # rid -> index in this call
        self._next_rid = 0
        self._t_submit: Dict[int, float] = {}   # rid -> submit monotonic s
        # The metrics spine (DESIGN.md §13): one typed Registry per
        # engine, one Tracer per replica (pid = replica id so a merged
        # cluster trace shows the fleet on one timeline).  The legacy
        # ``engine.metrics`` dict API lives on as a MetricsView over the
        # registry -- every pre-existing key keeps its name and meaning,
        # but counts are now monotonic Counters, peaks are Gauges, and
        # latency distributions are log-bucket Histograms.
        self.replica = int(replica)
        self.obs = Registry()
        self.tracer = Tracer(capacity=self.TRACE_CAPACITY,
                             pid=self.replica)
        o = self.obs
        for name in ("tokens", "tokens_recomputed", "decode_steps",
                     "cohorts", "evictions", "slot_steps",
                     "active_slot_steps", "backfills", "stalls",
                     "prefill_chunks", "prefill_tokens", "prefix_hits",
                     "prefix_misses", "prefix_hit_tokens", "pages_saved",
                     "cow_copies", "prefix_nodes_inserted",
                     "interleave_dropped", "token_times_dropped"):
            o.counter(name)
        o.set("page_tokens", self.page.page_tokens, unit="tokens")
        o.set("page_bytes", self.page.page_bytes, unit="B")
        o.set("budget_bytes", self.scheduler.budget_bytes, unit="B")
        o.set("kv_shard", self.plan.kv_shard())
        o.histogram("ttft_s", unit="s")
        o.histogram("inter_token_s", unit="s")
        o.histogram("queue_wait_s", unit="s")
        self.metrics: MetricsView = MetricsView(o, objects={
            "batching": self.batching,
            "plan_page_table": dict(self.plan.page_table() or {}),
            "capacities": [],
            "prefix_cache": policy.prefix_cache,
        })

    # ------------------------------------------------------------- plan reads
    def _kv_budget(self) -> int:
        """The fleet KV budget in the scheduler's *logical* bytes.

        The scheduler bills each page once (logical bytes: tokens x global
        per-token KV).  Physically the cache shards over the data axes but
        replicates over the model axis wherever the plan left it unsharded
        (``kv_shard < model_n``), so one logical byte costs
        ``model_n / kv_shard`` physical bytes -- the fleet HBM headroom is
        divided by that replication factor.  Weights are TP-sharded over
        "model" and (in the serving memory model) replicated over the data
        axes, so one weight copy per data shard is reserved first.
        """
        if self.policy.kv_budget_bytes is not None:
            return int(self.policy.kv_budget_bytes)
        ici = self.plan.level("ICI")
        sizes = dict(self.mesh.shape)
        n_dev = 1
        for v in sizes.values():
            n_dev *= v
        model_n = max(1, sizes.get("model", 1))
        data_n = max(1, n_dev // model_n)
        hbm_total = (ici.budget_bytes if ici is not None
                     else self.plan.leaf().budget_bytes) * n_dev
        weights = self.cfg.param_count() * self._dtype_bytes * data_n
        replication = max(1, model_n // max(1, self.plan.kv_shard()))
        budget = int(self.policy.kv_fraction
                     * max(0, hbm_total - weights) / replication)
        return max(self.page.page_bytes, budget)

    # -------------------------------------------------------------- telemetry
    def stats(self) -> Dict[str, Any]:
        """One consolidated telemetry dict -- pool, slots, prefix tree --
        that every consumer (the cluster router, the ``/stats`` endpoint,
        benchmarks) reads instead of poking ``metrics`` internals.

        Page counts come from the LIVE pool when one exists (mid-generate,
        or a persistent radix session holding cached prefixes), so a
        replica's memory pressure is visible from outside while a request
        is resident; otherwise they fall back to the last run's geometry,
        then to the plan's ``page_table``."""
        ptab = dict(self.plan.page_table() or {})
        pages_total = int(self.metrics.get("pages_total")
                          or ptab.get("pages_total") or 0)
        free_pages, used_pages = pages_total, 0
        slots_total = int(self.policy.max_slots)
        slots_free = slots_total
        pool = self._live_pool
        if pool is not None:
            pages_total = pool.pages_total - 1      # minus the null page
            # The pool publishes occupancy gauges on every alloc/free
            # (DESIGN.md §13); read those so the router's ``free_pages``
            # policy and this view observe the same instrument.
            free_pages = int(self.obs.value("free_pages",
                                            pool.free_pages))
            used_pages = int(self.obs.value("used_pages",
                                            pool.used_pages))
        sched = self._live_sched
        if sched is not None:
            slots_free = max(0, slots_total - len(sched.active()))
        out = {
            "batching": self.batching,
            "free_pages": int(free_pages),
            "used_pages": int(used_pages),
            "pages_total": int(pages_total),
            "slots_free": slots_free,
            "slots_total": slots_total,
            "page_tokens": self.page.page_tokens,
            "page_bytes": self.page.page_bytes,
            "kv_shard": self.plan.kv_shard(),
            "tokens": int(self.metrics.get("tokens", 0)),
            "decode_steps": int(self.metrics.get("decode_steps", 0)),
            "prefill_chunks": int(self.metrics.get("prefill_chunks", 0)),
            "prefix_nodes": 0,
            "prefix_pages": 0,
            "prefix_resident_bytes": 0,
        }
        sess = self._paged_session
        if sess is not None and sess.prefix is not None:
            out["prefix_nodes"] = sess.prefix.n_nodes
            out["prefix_pages"] = sess.prefix.n_pages
            out["prefix_resident_bytes"] = sess.prefix.resident_bytes
        return out

    # -------------------------------------------------------- token streaming
    def _notify(self, rid: int, tok: Optional[int]) -> None:
        """Forward one delivered token (or a ``None`` stream reset after a
        recompute preemption: earlier tokens will re-emit) to the caller's
        ``on_token(index_in_call, token)`` callback."""
        cb = self._stream_cb
        if cb is None:
            return
        ix = self._stream_ix.get(rid)
        if ix is not None:
            cb(ix, tok)

    # --------------------------------------------------------------- requests
    def _normalize_prompt(self, prompt) -> Dict[str, np.ndarray]:
        if isinstance(prompt, dict):
            return {k: np.asarray(v) for k, v in prompt.items()}
        return {"tokens": np.asarray(prompt, dtype=np.int32)}

    def _make_request(self, prompt, max_new: int,
                      paged: bool = False) -> Request:
        feats = self._normalize_prompt(prompt)
        if "tokens" in feats:
            plen = int(feats["tokens"].shape[-1])
        else:
            plen = int(feats["embeds"].shape[0])
        enc_len = (int(feats["enc_embeds"].shape[0])
                   if "enc_embeds" in feats else 0)
        rid = self._next_rid
        self._next_rid += 1
        # Fixed-extent caches (sliding-window rings) allocate their full
        # window-clamped capacity at admission and never grow, so the slot
        # must be billed for all of it up front; growable caches pin only
        # prompt + the first decode page (the Request default).  The paged
        # pool has no ring buffers -- windowed slots grow page by page and
        # reclaim out-of-window pages -- so admission is always prompt + 1.
        admit_tokens = None
        if not paged and not self._growable() and self.cfg.sliding_window:
            admit_tokens = min(plen + max_new + 1, self.cfg.sliding_window)
        return Request(
            rid=rid, prompt_len=plen, max_new=max_new,
            state_bytes=request_state_bytes(self.cfg, enc_len,
                                            self._dtype_bytes),
            features=feats, group=(plen, enc_len),
            admit_tokens=admit_tokens)

    # ------------------------------------------------------------------ steps
    def _growable(self) -> bool:
        tok_bytes, _, _ = kv_token_bytes(self.cfg, self._dtype_bytes)
        return tok_bytes > 0 and not self.cfg.sliding_window

    def _steps(self, n_slots: int, prompt_len: int, capacity: int
               ) -> ServeSteps:
        from repro.configs.base import ShapeConfig

        key = (n_slots, prompt_len, capacity)
        ss = self._steps_cache.get(key)
        if ss is None:
            shape = ShapeConfig("serve", prompt_len, n_slots, "decode")
            ss = make_serve_steps(
                self.cfg, shape, self.mesh, dtype=self.dtype,
                max_len_extra=capacity - prompt_len,
                decode_plan=self.plan)
            self._steps_cache[key] = ss
        return ss

    # ---------------------------------------------------------------- prefill
    def _stack_features(self, reqs: List[Request]) -> Dict[str, Any]:
        import jax.numpy as jnp

        keys = reqs[0].features.keys()
        out = {}
        for k in keys:
            arrs = [r.features[k] for r in reqs]
            axis = 1 if k == "positions_3d" else 0
            out[k] = jnp.stack([jnp.asarray(a) for a in arrs], axis=axis)
        if self.cfg.family == "vlm" and "positions_3d" not in out:
            s = out["embeds"].shape[1] if "embeds" in out else \
                out["tokens"].shape[1]
            out["positions_3d"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, None],
                (3, len(reqs), s))
        return out

    def _prefill_cohort(self, cid: int, reqs: List[Request],
                        outputs: Dict[int, List[int]],
                        scfg: SamplingConfig, step: int) -> _Run:
        prompt_len = reqs[0].prompt_len
        max_new = max(r.max_new for r in reqs)
        if self._growable():
            capacity = align_capacity(prompt_len + 1, self.page)
        else:
            capacity = prompt_len + max_new + 1
        ss = self._steps(len(reqs), prompt_len, capacity)
        batch = self._stack_features(reqs)
        for r in reqs:
            now = time.monotonic()
            t_sub = self._t_submit.get(r.rid, now)
            self.tracer.complete("queue_wait", t_sub, now, tid=r.rid + 1,
                                 args={"rid": r.rid, "cohort": cid})
            self.obs.observe("queue_wait_s", now - t_sub)
        tp0 = time.monotonic()
        logits, cache = ss.prefill(self.params, batch)
        self.tracer.complete("prefill", tp0, time.monotonic(), tid=0,
                             args={"cohort": cid, "slots": len(reqs),
                                   "prompt": prompt_len})
        toks = sample(logits, scfg, step_key(scfg, step))
        run = _Run(
            cid=cid, reqs=reqs, steps=ss, cache=cache,
            next_tokens=toks[:, None],
            capacity=(cache_capacity(self.cfg, cache)
                      if self._growable() else None),
            pos=prompt_len,
            active={r.rid: i for i, r in enumerate(reqs)})
        self.metrics["cohorts"] += 1
        if run.capacity is not None:
            self.metrics["capacities"].append(run.capacity)
        self._emit(run, toks, outputs, scfg)
        return run

    # ----------------------------------------------------------------- decode
    def _emit(self, run: _Run, toks, outputs: Dict[int, List[int]],
              scfg: SamplingConfig) -> None:
        toks = np.asarray(toks).reshape(-1)
        for r in list(run.reqs):
            slot = run.active.get(r.rid)
            if slot is None:
                continue
            t = int(toks[slot])
            outputs[r.rid].append(t)
            now = time.monotonic()
            if len(outputs[r.rid]) == 1:
                self.tracer.instant("first_token", tid=r.rid + 1,
                                    args={"rid": r.rid})
                self.obs.observe(
                    "ttft_s", now - self._t_submit.get(r.rid, now))
            self._notify(r.rid, t)
            self.metrics["tokens"] += 1
            if len(outputs[r.rid]) >= r.max_new or \
                    (scfg.eos_id is not None and t == scfg.eos_id):
                del run.active[r.rid]
                self.scheduler.finish(run.cid, r.rid)
                self.tracer.complete(
                    "request", self._t_submit.get(r.rid, now), now,
                    tid=r.rid + 1,
                    args={"rid": r.rid, "tokens": len(outputs[r.rid])})

    def _compact(self, run: _Run) -> None:
        """Drop finished slots from the cohort batch: slice the cache (and
        the pending next-token column) down to the survivors so their
        pages release immediately instead of at whole-cohort retirement.
        Called at growth boundaries -- the moment freed pages pay for
        themselves -- since each new batch shape is another jit bucket."""
        import jax.numpy as jnp

        if not run.active or len(run.active) == len(run.reqs):
            return
        keep = [r for r in run.reqs if r.rid in run.active]
        idx = [run.active[r.rid] for r in keep]
        run.cache = take_slots(run.cache, idx)
        run.next_tokens = jnp.take(run.next_tokens,
                                   jnp.asarray(idx), axis=0)
        run.reqs = keep
        run.active = {r.rid: i for i, r in enumerate(keep)}
        self.scheduler.shrink_slots(run.cid, [r.rid for r in keep])

    def _ensure_capacity(self, run: _Run, runs: Dict[int, "_Run"],
                         outputs: Dict[int, List[int]]) -> None:
        if run.capacity is None or run.pos + 1 <= run.capacity:
            return
        # Before asking for more pages, release the ones finished slots
        # still pin (growth is where a smaller batch pays for the retrace).
        self._compact(run)
        needed = run.capacity + self.page.page_tokens
        while not self.scheduler.reserve(run.cid, needed):
            victim = self.scheduler.youngest_other(run.cid)
            if victim is None or victim not in runs:
                raise RuntimeError(
                    f"KV budget {self.scheduler.budget_bytes} cannot hold "
                    f"one growing cohort; raise kv_budget_bytes")
            # Recompute preemption: requeue the victim's unfinished
            # requests.  ``tokens`` stays a monotonic count of delivered
            # tokens; the invalidated work moves into the
            # ``tokens_recomputed`` counter instead of subtracting (a
            # decrement made the count transiently negative when a
            # preemption landed before the victim's first token re-emit).
            for r in self.scheduler.evict(victim):
                self.obs.inc("tokens_recomputed", len(outputs[r.rid]))
                self.tracer.instant(
                    "preempt", tid=r.rid + 1,
                    args={"rid": r.rid, "cohort": victim,
                          "tokens_lost": len(outputs[r.rid])})
                outputs[r.rid] = []
                self._notify(r.rid, None)
            del runs[victim]
            self.metrics["evictions"] += 1
        run.cache = grow_cache(self.cfg, run.cache, needed)
        run.capacity = needed
        self.metrics["capacities"].append(needed)

    def _decode_cohort(self, run: _Run, runs: Dict[int, "_Run"],
                       outputs: Dict[int, List[int]],
                       scfg: SamplingConfig, step: int) -> None:
        import jax.numpy as jnp

        self._ensure_capacity(run, runs, outputs)
        batch = {"tokens": run.next_tokens}
        if self.cfg.family == "vlm":
            batch["positions_3d"] = jnp.broadcast_to(
                run.cache["pos"][None, None, None],
                (3, len(run.reqs), 1)).astype(jnp.int32)
        td0 = time.monotonic()
        logits, run.cache = run.steps.decode(self.params, run.cache, batch)
        toks = sample(logits, scfg, step_key(scfg, step))
        self.tracer.complete("decode_tick", td0, time.monotonic(), tid=0,
                             args={"cohort": run.cid,
                                   "active": len(run.active)})
        run.next_tokens = toks[:, None].astype(jnp.int32)
        run.pos += 1
        self.metrics["decode_steps"] += 1
        # Utilization: this step decoded len(reqs) rows, of which only the
        # still-active ones deliver a token (finished slots ride along
        # until the next growth-boundary compaction -- the cohort tax the
        # paged engine's backfill removes).
        self.metrics["slot_steps"] += len(run.reqs)
        self.metrics["active_slot_steps"] += len(run.active)
        self._emit(run, toks, outputs, scfg)

    # --------------------------------------------------------------- generate
    def generate(
        self,
        prompts: Sequence[Any],
        max_new_tokens=None,
        sampling: Optional[SamplingConfig] = None,
        on_token=None,
    ) -> List[List[int]]:
        """Serve ``prompts`` (token-id sequences, or per-family feature
        dicts without the batch dim), returning each request's generated
        token ids in submission order.  ``max_new_tokens`` is one int for
        all requests or a per-request sequence.  Continuous batching: admissions
        (prefills) interleave with one decode step per live cohort per
        tick, and the resident KV footprint stays inside the planned
        budget throughout (asserted every tick).

        ``on_token(i, tok)`` streams each delivered token as it is
        sampled (``i`` = the request's index in this call) -- the HTTP
        front end's chunked-transfer hook.  A recompute preemption
        invalidates a request's streamed tokens; the callback receives
        ``on_token(i, None)`` and the tokens re-emit from scratch.
        """
        scfg = sampling or self.policy.sampling
        max_new = (max_new_tokens if max_new_tokens is not None
                   else self.policy.max_new_tokens)
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        if len(max_new) != len(prompts):
            raise ValueError(
                f"max_new_tokens: expected one int or {len(prompts)} "
                f"entries, got {len(max_new)}")
        if not prompts:
            return []
        self._stream_cb = on_token
        try:
            if self.batching == "paged":
                return self._generate_paged(prompts, max_new, scfg)
            return self._generate_cohort(prompts, max_new, scfg)
        finally:
            self._stream_cb = None
            self._stream_ix = {}

    def _generate_cohort(self, prompts: Sequence[Any], max_new: List[int],
                         scfg: SamplingConfig) -> List[List[int]]:
        reqs = [self._make_request(p, n) for p, n in zip(prompts, max_new)]
        self._stream_ix = {r.rid: i for i, r in enumerate(reqs)}
        for r in reqs:
            self.scheduler.submit(r)
            self._t_submit[r.rid] = time.monotonic()
            self.tracer.instant("submit", tid=r.rid + 1,
                                args={"rid": r.rid,
                                      "prompt": r.prompt_len})
        outputs: Dict[int, List[int]] = {r.rid: [] for r in reqs}
        runs: Dict[int, _Run] = {}
        step = 0
        while self.scheduler.has_work():
            progressed = False
            for cid, batch in self.scheduler.admit():
                runs[cid] = self._prefill_cohort(cid, batch, outputs,
                                                 scfg, step)
                step += 1
                progressed = True
            for cid in sorted(runs):
                run = runs.get(cid)
                if run is None:
                    continue            # evicted by a sibling's growth
                if not run.active:
                    del runs[cid]
                    continue
                self._decode_cohort(run, runs, outputs, scfg, step)
                step += 1
                progressed = True
                if not run.active:
                    del runs[cid]
            assert self.scheduler.allocated_bytes <= \
                self.scheduler.budget_bytes, "resident KV exceeded the plan"
            self.scheduler.assert_reconciled()
            if not progressed:
                raise RuntimeError("scheduler stalled with pending work")
        self.metrics["peak_resident_bytes"] = self.scheduler.peak_bytes
        self.metrics["pages_allocated"] = self.scheduler.pages_allocated
        self.metrics["pages_released"] = self.scheduler.pages_released
        self._finalize_utilization()
        return [outputs[r.rid] for r in reqs]

    def _finalize_utilization(self) -> None:
        steps = self.metrics["slot_steps"]
        self.metrics["slot_utilization"] = (
            self.metrics["active_slot_steps"] / steps if steps else 0.0)

    # ------------------------------------------------------- paged batching
    def _paged_slots(self, reqs: List[Request]) -> int:
        """Decode-batch width: ``max_slots`` capped at the trace -- never
        allocate (and bill utilization for) slots no request can occupy,
        so ``slot_utilization`` is comparable with cohort mode even when
        requests < max_slots."""
        return max(1, min(self.policy.max_slots, len(reqs)))

    def _paged_geometry(self, reqs: List[Request], n_slots: int):
        """Pool geometry from the plan (DESIGN.md §8): the logical table
        width is the plan's per-slot page bound, stretched to the longest
        submitted request; the physical pool is the planned KV budget in
        pages, capped at what the slots can ever pin (plus the null
        page).  With the prefix cache on, the cap doubles (still inside
        the budget): cached prefixes occupy pool pages BESIDE the live
        slots' working set, up to ``plan.prefix_budget()``."""
        page = self.page
        if page.page_bytes <= 0:          # token-free family (xLSTM)
            return 1, 2
        ptab = self.plan.page_table() or {}
        need = max(page.pages_for(r.prompt_len + r.max_new + 1)
                   for r in reqs)
        pages_per_slot = max(int(ptab.get("pages_per_slot") or 1), need)
        budget_pages = max(1, self.scheduler.budget_bytes // page.page_bytes)
        slot_pages = n_slots * pages_per_slot
        extra = 0
        if self.policy.prefix_cache == "radix" and \
                self.cfg.family in self._prefix_families():
            budget = self.plan.prefix_budget() or 0
            extra = min(budget // page.page_bytes, slot_pages)
        pages_total = 1 + min(budget_pages, slot_pages + extra)
        return pages_per_slot, pages_total

    def _prefix_families(self):
        from repro.serve.prefix import PREFIX_FAMILIES
        return PREFIX_FAMILIES

    def _paged_steps(self, cache, n_slots: int, pages_total: int,
                     pages_per_slot: int, enc_max: int = 0):
        from repro.serve.steps import make_paged_steps

        key = (n_slots, pages_total, pages_per_slot,
               self.page.page_tokens, enc_max)
        ss = self._paged_steps_cache.get(key)
        if ss is None:
            ss = make_paged_steps(
                self.cfg, self.mesh, cache,
                n_slots=n_slots, max_len=self.policy.max_len,
                dtype=self.dtype, decode_plan=self.plan)
            self._paged_steps_cache[key] = ss
        return ss

    def _encode_req(self, steps, req: Request):
        """Enc-dec admission: run the encoder + cross projections once for
        this request (jit bucket per encoder length).  ``None`` for every
        other family."""
        if self.cfg.family != "enc_dec":
            return None
        import jax.numpy as jnp

        enc = jnp.asarray(np.asarray(req.features["enc_embeds"]))[None]
        return steps.encode(self.params, enc)

    def _apply_prefix_hit(self, cache: PyTree, slot: int, hit) -> PyTree:
        """Realize a ``PrefixHit`` on the device cache: copy the CoW
        source page into the slot's private copy (the only page-sized
        device copy in the whole hit path) and restore the recurrent
        state snapshot into the slot's state rows.  Shared full pages
        need no device work at all -- the slot's page table already
        points at them."""
        import jax
        import jax.numpy as jnp

        cache = dict(cache)
        if hit.cow is not None and cache.get("pool"):
            src, dst = hit.cow
            cache["pool"] = {
                k: buf.at[:, dst].set(buf[:, src])
                for k, buf in cache["pool"].items()}
        if hit.state is not None and cache.get("state"):
            cache["state"] = jax.tree.map(
                lambda a, s: (a.at[:, slot].set(
                    jnp.asarray(s).astype(a.dtype)) if a.ndim >= 2
                    else a.at[slot].set(jnp.asarray(s).astype(a.dtype))),
                cache["state"], hit.state)
        return cache

    def _ensure_paged_session(self, n_slots: int, pages_per_slot: int,
                              pages_total: int, enc_max: int = 0
                              ) -> _PagedSession:
        """The persistent radix session (pool + pooled cache + tree) for
        this geometry, creating or rebuilding it on a geometry change.
        Factored out of ``_generate_paged`` so the disaggregation import
        path can materialize the session BEFORE any generate call."""
        from repro.serve.pages import PagePool, init_paged_cache
        from repro.serve.prefix import STATE_FAMILIES, RadixPrefixCache

        geo_key = (n_slots, pages_per_slot, pages_total, enc_max)
        sess = self._paged_session
        if sess is not None and sess.key == geo_key:
            return sess
        pool = PagePool(pages_total, obs=self.obs, tracer=self.tracer)
        cache = init_paged_cache(self.cfg, self.model, n_slots,
                                 pages_total, self.page.page_tokens,
                                 pages_per_slot, self.dtype,
                                 enc_len=enc_max)
        budget = self.plan.prefix_budget()
        if not budget:                    # no page level (xLSTM): fall back
            budget = self.scheduler.budget_bytes
        prefix = RadixPrefixCache(
            self.page.page_tokens, max(0, self.page.page_bytes), budget,
            pool, has_state=self.cfg.family in STATE_FAMILIES,
            obs=self.obs, tracer=self.tracer)
        self._paged_session = _PagedSession(geo_key, pool, cache, prefix)
        return self._paged_session

    # ------------------------------------------- disaggregation page hooks
    def export_pages(self, tokens) -> Optional[Dict[str, Any]]:
        """Serialize the radix-cached KV pages covering ``tokens``' leading
        page-aligned blocks (prefill-role replicas: run ``generate`` with
        ``max_new_tokens=1`` first so the prompt's pages are published to
        the tree).  Returns ``{"tokens", "page_tokens", "pages", "snaps"}``
        with ``pages`` a list of per-page ``{buffer: np.ndarray}`` dicts in
        logical order, or None when nothing is cached (prefix cache off,
        family not prefix-cacheable, or a cold tree)."""
        from repro.serve.pages import export_pool_pages

        sess = self._paged_session
        if sess is None or sess.prefix is None:
            return None
        toks = np.asarray(tokens, dtype=np.int32).reshape(-1)
        covered, pids, snaps = sess.prefix.match(toks)
        if covered <= 0:
            return None
        return {
            "tokens": toks[:covered].tolist(),
            "page_tokens": int(self.page.page_tokens),
            "pages": export_pool_pages(sess.cache, pids),
            "snaps": snaps,
        }

    def import_pages(self, tokens, payloads, snaps=None,
                     n_slots: int = 1) -> int:
        """Install serialized KV pages into THIS engine's pool and radix
        tree (the foreign-pool import, decode-role replicas): allocate
        local pages, write the payload buffers, publish the chain so the
        next ``generate`` sharing the prefix starts at the boundary.
        Returns the number of prompt tokens now resident locally.

        Requires ``ServePolicy(prefix_cache="radix")`` and a stable pool
        geometry (``policy.max_len`` bounding every request) -- a later
        geometry change rebuilds the session and drops imported pages."""
        from repro.serve.pages import install_pool_pages

        if self.policy.prefix_cache != "radix" or \
                self.cfg.family not in self._prefix_families():
            raise PlanError("import_pages needs ServePolicy(prefix_cache="
                            "'radix') and a prefix-cacheable family")
        toks = np.asarray(tokens, dtype=np.int32).reshape(-1)
        req = self._make_request(toks, self.policy.max_new_tokens,
                                 paged=True)
        self._next_rid -= 1               # synthetic request: no rid burn
        pages_per_slot, pages_total = self._paged_geometry([req], n_slots)
        sess = self._ensure_paged_session(n_slots, pages_per_slot,
                                          pages_total, 0)
        pool, prefix = sess.pool, sess.prefix
        n = len(payloads)
        if n == 0:
            return 0
        pids = pool.alloc(n)
        if pids is None:
            prefix.release_pages(need=n)
            pids = pool.alloc(n)
        if pids is None:
            raise RuntimeError(
                f"page pool ({pool.pages_total - 1} pages) cannot hold a "
                f"{n}-page import; raise kv_budget_bytes")
        sess.cache = install_pool_pages(sess.cache, pids, payloads)
        prefix.insert(toks, list(pids), snaps=dict(snaps or {}))
        # The tree now holds one reference per inserted page; drop ours
        # (uninserted tail pages -- budget pressure -- return to the pool).
        pool.free(pids)
        # Resident coverage, not nodes created: a re-import of an
        # already-published prefix is an idempotent success, and tail
        # pages dropped under budget pressure are not counted.
        return int(prefix.match(toks)[0])

    def _generate_paged(self, prompts: Sequence[Any], max_new: List[int],
                        scfg: SamplingConfig) -> List[List[int]]:
        """Per-slot continuous batching over the global page pool.

        A fixed batch of ``max_slots`` decode slots shares ONE page pool,
        ONE jitted decode program (static pool/table/slot shapes -- no
        per-capacity retraces) and ONE jitted chunked-prefill program per
        distinct chunk length.  Prefill is CHUNKED (DESIGN.md §10): a new
        request's prompt is cut into planned-page-sized chunks written
        straight into the slot's pool pages -- no staging cache, no
        post-prefill copy -- and every tick runs at most one chunk per
        prefilling slot before the decode step for the resident slots, so
        a long prompt never blocks decode for more than one chunk.
        ``policy.prefill == "monolithic"`` runs the same direct-to-pool
        path as one whole-prompt chunk (the A/B baseline).  A finished
        slot's pages free immediately and the slot is backfilled
        mid-flight -- the utilization win over cohort mode.
        """
        import jax
        import jax.numpy as jnp

        from repro.serve.pages import (
            PagePool,
            PagedScheduler,
            init_paged_cache,
            reset_slot,
        )

        reqs = [self._make_request(p, n, paged=True)
                for p, n in zip(prompts, max_new)]
        self._stream_ix = {r.rid: i for i, r in enumerate(reqs)}
        outputs: Dict[int, List[int]] = {r.rid: [] for r in reqs}
        n_slots = self._paged_slots(reqs)
        page = self.page
        window = self.cfg.sliding_window
        pages_per_slot, pages_total = self._paged_geometry(reqs, n_slots)
        enc_max = max((r.group[1] for r in reqs), default=0)
        prefix_on = (self.policy.prefix_cache == "radix"
                     and self.cfg.family in self._prefix_families())
        if prefix_on:
            # Cross-call persistence: the pool's refcounts, the cached
            # prefixes' device pages and the radix tree survive between
            # generate() calls as long as the geometry matches.
            sess = self._ensure_paged_session(n_slots, pages_per_slot,
                                              pages_total, enc_max)
            pool, cache, prefix = sess.pool, sess.cache, sess.prefix
        else:
            pool = PagePool(pages_total, obs=self.obs, tracer=self.tracer)
            cache = init_paged_cache(self.cfg, self.model, n_slots,
                                     pages_total, page.page_tokens,
                                     pages_per_slot, self.dtype,
                                     enc_len=enc_max)
            prefix = None
        sched = PagedScheduler(pool, page, n_slots, pages_per_slot,
                               window=window, prefix=prefix)
        self._live_pool = pool          # router/stats() telemetry handles:
        self._live_sched = sched        # live reads while generate runs
        steps = self._paged_steps(cache, n_slots, pages_total,
                                  pages_per_slot, enc_max)
        self.metrics["pages_total"] = pages_total - 1     # usable pages
        self.metrics["pages_per_slot"] = pages_per_slot
        # Chunk length: the planner's page (KV write granule == page ->
        # every full chunk fills exactly one fresh page); token-free
        # families chunk by the planner's page token count anyway (state
        # advances chunkwise, nothing to page).  "monolithic" (or no page
        # geometry at all) degenerates to one whole-prompt chunk.
        chunk_tokens = self.plan.chunk_tokens() or page.page_tokens
        if self.policy.prefill == "monolithic" or chunk_tokens <= 0:
            chunk_tokens = 0                  # whole prompt per chunk
        trace = RingLog(maxlen=self.LOG_CAPACITY)
        self.metrics["interleave"] = trace

        table_np = np.zeros((n_slots, pages_per_slot), np.int32)
        pos_np = np.zeros((n_slots,), np.int32)
        next_np = np.zeros((n_slots, 1), np.int32)
        ever_occupied: set = set()
        requeued: set = set()           # rids re-admitting after preemption
        prefills: Dict[int, int] = {}   # slot -> prompt tokens prefilled
        chunk_snaps: Dict[int, Dict[int, Any]] = {}  # slot -> {tokens: state}
        peak_pages = 0
        t0 = time.monotonic()
        token_times: Dict[int, RingLog] = {
            r.rid: RingLog(maxlen=self.TOKEN_TIMES_CAPACITY) for r in reqs}
        self.metrics["token_times"] = token_times
        self.metrics["start_time"] = t0
        for r in reqs:
            sched.submit(r)
            self._t_submit[r.rid] = time.monotonic()
            self.tracer.instant("submit", tid=r.rid + 1,
                                args={"rid": r.rid,
                                      "prompt": r.prompt_len})
        step = 0

        def clear_slot(i: int) -> None:
            table_np[i] = 0
            pos_np[i] = 0
            next_np[i, 0] = 0

        def push_table(i: int) -> None:
            row = [p if p is not None else 0 for p in sched.slots[i].pages]
            table_np[i, :len(row)] = row
            table_np[i, len(row):] = 0

        def emit_token(slot: int, rid: int, max_new_bound: int,
                       tok: int) -> None:
            """Deliver one sampled token for a slot: record it, queue it
            as the slot's next input, reclaim out-of-window pages, and
            retire the slot when its request is done (pages free at once
            -- the next admission backfills)."""
            outputs[rid].append(tok)
            now = time.monotonic()
            times = token_times[rid]
            if len(outputs[rid]) == 1:
                self.tracer.instant("first_token", tid=rid + 1,
                                    args={"rid": rid, "slot": slot})
                self.obs.observe(
                    "ttft_s", now - self._t_submit.get(rid, t0))
            elif len(times):
                self.obs.observe("inter_token_s", now - times[-1])
            times.append(now)
            self._notify(rid, tok)
            self.metrics["tokens"] += 1
            next_np[slot, 0] = tok
            if window:
                sched.reclaim_window(slot, window)
            if len(outputs[rid]) >= max_new_bound or \
                    (scfg.eos_id is not None and tok == scfg.eos_id):
                sched.finish(slot)
                clear_slot(slot)
                self.tracer.complete(
                    "request", self._t_submit.get(rid, t0), now,
                    tid=rid + 1,
                    args={"rid": rid, "tokens": len(outputs[rid])})

        def preempt(victim: int) -> None:
            """Recompute preemption: the victim's tokens (and any partial
            prefill) regenerate from scratch after re-admission.  The
            delivered-token count stays monotonic -- invalidated tokens
            move into ``tokens_recomputed`` (subtracting here used to
            drive ``metrics["tokens"]`` transiently negative until the
            victim re-emitted)."""
            vreq = sched.evict(victim)
            self.obs.inc("tokens_recomputed", len(outputs[vreq.rid]))
            self.tracer.instant(
                "preempt", tid=vreq.rid + 1,
                args={"rid": vreq.rid, "slot": victim,
                      "tokens_lost": len(outputs[vreq.rid])})
            outputs[vreq.rid] = []
            token_times[vreq.rid].clear()   # keeps its dropped count
            self._notify(vreq.rid, None)
            requeued.add(vreq.rid)
            prefills.pop(victim, None)
            chunk_snaps.pop(victim, None)
            clear_slot(victim)
            self.metrics["evictions"] += 1

        while sched.has_work():
            progressed = False
            # Capacity FIRST, oldest request first: growth claims its pages
            # before admission can hand the last free ones to a new request
            # whose just-run prefill an older grower would immediately
            # evict.  An older slot preempts strictly-younger victims
            # (recompute); a slot with no younger victim STALLS this tick
            # (pages pinned, decode skipped) -- the oldest slot always
            # progresses, so no eviction ping-pong.  Prefilling slots
            # claim capacity in the chunk phase instead (ahead of their
            # chunk front, not their decode position).
            stalled: set = set()
            for i in sorted(sched.active(),
                            key=lambda j: sched.slots[j].rid):
                if sched.slots[i] is None or i in prefills:
                    continue                  # evicted by an older grower
                while not sched.ensure_capacity(i):
                    if sched.table_full(i):
                        stalled.add(i)    # eviction cannot widen the table
                        self.metrics["stalls"] += 1
                        break
                    victim = sched.victim(i)
                    if victim is None:
                        if len(sched.active()) == 1:
                            raise RuntimeError(
                                f"page pool ({pool.pages_total - 1} pages)"
                                f" cannot hold one growing sequence; "
                                f"raise kv_budget_bytes")
                        stalled.add(i)
                        self.metrics["stalls"] += 1
                        break
                    preempt(victim)

            # Admission: a slot + its first page (token-free: none); the
            # prompt itself streams in below, one chunk per tick, straight
            # into pool pages.  Enc-dec runs its encoder once here and
            # installs the cross K/V into the slot's state rows.  A prefix
            # hit starts the slot at ``hit.tokens`` with the shared pages
            # already in its table: CoW-copy the divergent page, restore
            # the state snapshot, and prefill covers only the suffix.
            for slot, req, pages, hit in sched.admit(chunked=True):
                now = time.monotonic()
                t_sub = self._t_submit.get(req.rid, t0)
                self.tracer.complete("queue_wait", t_sub, now,
                                     tid=req.rid + 1,
                                     args={"rid": req.rid, "slot": slot})
                self.obs.observe("queue_wait_s", now - t_sub)
                cache = reset_slot(self.cfg, self.model, cache, slot,
                                   cross_kv=self._encode_req(steps, req),
                                   enc_len=req.group[1])
                table_np[slot] = 0
                push_table(slot)
                pos_np[slot] = sched.slots[slot].pos
                next_np[slot, 0] = 0
                prefills[slot] = sched.slots[slot].pos
                if hit is not None:
                    cache = self._apply_prefix_hit(cache, slot, hit)
                    self.metrics["prefix_hits"] += 1
                    self.metrics["prefix_hit_tokens"] += hit.tokens
                    self.metrics["pages_saved"] += \
                        hit.tokens // page.page_tokens
                    if hit.cow is not None:
                        self.metrics["cow_copies"] += 1
                        self.tracer.instant(
                            "cow_copy", tid=req.rid + 1,
                            args={"rid": req.rid, "slot": slot,
                                  "src": hit.cow[0], "dst": hit.cow[1]})
                elif prefix is not None:
                    self.metrics["prefix_misses"] += 1
                # A backfill is a NEW request taking a previously used
                # slot mid-flight; a preempted request's own recompute
                # re-admission is not one.
                if slot in ever_occupied and req.rid not in requeued:
                    self.metrics["backfills"] += 1
                requeued.discard(req.rid)
                ever_occupied.add(slot)
                progressed = True

            # Chunk phase: one chunk per prefilling slot per tick, BEFORE
            # the decode step -- a prefilling slot rides through the decode
            # batch (its garbage write at the chunk front is overwritten by
            # the next chunk; its recurrent state is restored below), so
            # chunks and decode ticks interleave instead of serializing.
            for slot in sorted(prefills):
                s = sched.slots[slot]
                if s is None or slot not in prefills:
                    continue                  # preempted by a sibling chunk
                req, plen = s.req, s.req.prompt_len
                done = prefills[slot]
                # A prefix hit can start mid-page; the first suffix chunk
                # realigns to the chunk grid (cold starts reduce to the
                # plain ``min(chunk, remaining)``).
                c = plen - done if chunk_tokens <= 0 else \
                    min(chunk_tokens - done % chunk_tokens, plen - done)
                if window and prefix is None:
                    # Behind the front.  With the prefix cache on, prompt
                    # pages must SURVIVE to insertion below -- window
                    # reclaim resumes at decode (the tree's reference then
                    # keeps them resident through it).
                    sched.reclaim_window(slot, window)
                grew = True
                while not sched.ensure_capacity(slot, upto=done + c):
                    if sched.table_full(slot):
                        raise RuntimeError(
                            f"slot {slot}: prompt needs more than the "
                            f"{pages_per_slot}-page table")
                    victim = sched.victim(slot)
                    if victim is None:
                        if len(sched.active()) == 1:
                            raise RuntimeError(
                                f"page pool ({pool.pages_total - 1} pages)"
                                f" cannot hold one prefill chunk; "
                                f"raise kv_budget_bytes")
                        stalled.add(slot)
                        self.metrics["stalls"] += 1
                        grew = False
                        break
                    preempt(victim)
                if not grew:
                    continue                  # retry the chunk next tick
                peak_pages = max(peak_pages, pool.used_pages)
                if page.page_bytes > 0:
                    # CoW safety: every page this chunk writes must be
                    # PRIVATE (refcount 1) -- shared prefix pages sit
                    # strictly below the suffix front and are mapped
                    # read-only (see models/layers.paged_attention_block).
                    for j in range(done // page.page_tokens,
                                   -(-(done + c) // page.page_tokens)):
                        p = s.pages[j] if j < len(s.pages) else None
                        assert p is None or pool.refcount(p) == 1, \
                            f"chunk would write shared page {p} (rc=" \
                            f"{pool.refcount(p)})"
                push_table(slot)
                cache["table"] = jnp.asarray(table_np)
                toks = jnp.asarray(
                    np.asarray(req.features["tokens"][done:done + c],
                               np.int32))[None]
                tc0 = time.monotonic()
                logits, cache = steps.prefill_chunk(
                    self.params, cache, toks, jnp.int32(done),
                    jnp.int32(slot))
                self.tracer.complete(
                    "prefill_chunk", tc0, time.monotonic(),
                    tid=req.rid + 1,
                    args={"rid": req.rid, "slot": slot, "done": done,
                          "tokens": c})
                self.metrics["prefill_chunks"] += 1
                self.metrics["prefill_tokens"] += c
                trace.append(("chunk", slot, done, c))
                done += c
                prefills[slot] = done
                s.pos = done
                pos_np[slot] = done
                progressed = True
                if prefix is not None and prefix.has_state and \
                        cache.get("state") and \
                        done % page.page_tokens == 0:
                    # Page-boundary state snapshot (host copy): the radix
                    # node for this block restores it on a future hit.
                    chunk_snaps.setdefault(slot, {})[done] = jax.tree.map(
                        lambda a: (np.asarray(a[:, slot]) if a.ndim >= 2
                                   else np.asarray(a[slot])),
                        cache["state"])
                if done >= plen:
                    del prefills[slot]
                    if prefix is not None:
                        self.metrics["prefix_nodes_inserted"] += \
                            prefix.insert(
                                np.asarray(req.features["tokens"]),
                                list(s.pages),
                                snaps=chunk_snaps.pop(slot, None))
                    tok = int(np.asarray(
                        sample(logits, scfg,
                               step_key(scfg, step))).reshape(-1)[0])
                    step += 1
                    emit_token(slot, req.rid, req.max_new, tok)

            active = [i for i in sched.active()
                      if i not in stalled and i not in prefills]
            if active and page.page_bytes > 0 and prefix is not None:
                # CoW safety for decode writes: the write position's page
                # is always private (shared prefix pages end strictly
                # below the suffix, and positions only grow).
                for i in active:
                    s = sched.slots[i]
                    j = s.pos // page.page_tokens
                    p = s.pages[j] if j < len(s.pages) else None
                    assert p is None or pool.refcount(p) == 1, \
                        f"decode would write shared page {p} (rc=" \
                        f"{pool.refcount(p)})"
            if active:
                # Refresh the device-side page tables from the scheduler:
                # growth appended pages, reclaim nulled out-of-window ones.
                for i in sched.active():
                    push_table(i)
                cache["table"] = jnp.asarray(table_np)
                cache["pos"] = jnp.asarray(pos_np)
                # Stalled AND prefilling slots still ride through the
                # decode batch.  Their KV writes land on the null page or
                # at the chunk front (overwritten by the next chunk), but
                # RECURRENT state (Mamba/xLSTM) would advance on the
                # discarded tick and corrupt the slot on resume -- so
                # snapshot their state rows and restore them after the
                # step.
                frozen = sorted({i for i in (set(stalled) | set(prefills))
                                 if sched.slots[i] is not None})
                snapshot = None
                if frozen and cache.get("state"):
                    sl = jnp.asarray(frozen)
                    # Slot axis is 1 for layer-stacked buffers, 0 for
                    # per-slot vectors (enc-dec's ``enc_len``).
                    snapshot = jax.tree.map(
                        lambda a: a[:, sl] if a.ndim >= 2 else a[sl],
                        cache["state"])
                td0 = time.monotonic()
                logits, cache = steps.decode(
                    self.params, cache, {"tokens": jnp.asarray(next_np)})
                if snapshot is not None:
                    cache["state"] = jax.tree.map(
                        lambda ns, snap: (ns.at[:, sl].set(snap)
                                          if ns.ndim >= 2
                                          else ns.at[sl].set(snap)),
                        cache["state"], snapshot)
                self.tracer.complete("decode_tick", td0, time.monotonic(),
                                     tid=0, args={"active": len(active)})
                trace.append(("decode", tuple(active)))
                toks = np.asarray(
                    sample(logits, scfg, step_key(scfg, step))).reshape(-1)
                step += 1
                self.metrics["decode_steps"] += 1
                self.metrics["slot_steps"] += n_slots
                self.metrics["active_slot_steps"] += len(active)
                for i in active:
                    s = sched.slots[i]
                    s.pos += 1
                    pos_np[i] = s.pos
                    emit_token(i, s.rid, s.req.max_new, int(toks[i]))
                progressed = True

            peak_pages = max(peak_pages, pool.used_pages)
            if prefix is None:
                assert pool.used_pages == sched.used_pages_by_slots(), \
                    "page pool out of sync with the slot tables"
            else:
                # Shared pages carry one refcount per mapping: every slot
                # table entry plus every radix-tree node.
                assert pool.total_refs == sched.used_pages_by_slots() \
                    + prefix.n_pages, "refcount ledger out of sync"
            assert pool.pages_allocated - pool.pages_released == \
                pool.used_pages, "page accounting leak"
            if not progressed:
                raise RuntimeError("scheduler stalled with pending work")

        self.metrics["peak_resident_bytes"] = peak_pages * page.page_bytes
        self.metrics["peak_pages"] = peak_pages
        self.obs.set_max("pool_peak_pages", peak_pages, unit="pages")
        self.metrics["pages_allocated"] = pool.pages_allocated
        self.metrics["pages_released"] = pool.pages_released
        # Ring-buffer drop accounting (satellite of DESIGN.md §13): the
        # bounded interleave/token-time logs shed oldest entries instead
        # of growing without limit; surface how many were shed.
        self.obs.inc("interleave_dropped", trace.dropped)
        self.obs.inc("token_times_dropped",
                     sum(t.dropped for t in token_times.values()))
        if prefix is not None:
            seen = prefix.hits + prefix.misses
            self.metrics["prefix_hit_rate"] = \
                prefix.hits / seen if seen else 0.0
            self.metrics["prefix_resident_pages"] = prefix.n_pages
            self.metrics["prefix_resident_bytes"] = prefix.resident_bytes
            self.metrics["prefix_evicted_pages"] = prefix.evicted_pages
            self.metrics["prefix_budget_bytes"] = prefix.budget_bytes
            sess = self._paged_session
            if sess is not None and sess.pool is pool:
                sess.cache = cache    # carry the device pages forward
        self._finalize_utilization()
        return [outputs[r.rid] for r in reqs]
