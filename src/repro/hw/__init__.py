"""Hardware descriptions: TPU chip specs + host CPU detection."""

from repro.hw.tpu import (
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    TPUSpec,
    chip_spec,
)

__all__ = ["TPUSpec", "TPU_V5E", "TPU_V4", "TPU_V5P", "chip_spec"]
