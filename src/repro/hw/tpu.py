"""TPU chip specifications used by the autotiler and the roofline analysis.

The numbers for the *target* chip (TPU v5e) follow the constants mandated for
this reproduction: 197 TFLOP/s bf16 per chip, 819 GB/s HBM bandwidth,
~50 GB/s per ICI link. VMEM sizes follow public documentation (order
128 MiB on recent chips); a configurable ``vmem_reserved_bytes`` models the
compiler-reserved scratch -- the TPU analogue of the paper's observation
(§4.4.2) that the *usable* TCL is below the nominal cache size because other
state competes for it.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.hierarchy import MemoryLevel, tpu_hierarchy


@dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_bf16_flops: float          # FLOP/s per chip
    hbm_bytes: int
    hbm_bw: float                   # bytes/s
    vmem_bytes: int                 # per TensorCore
    vmem_reserved_bytes: int        # compiler scratch / semaphores / spills
    ici_bw_per_link: float          # bytes/s per link per direction
    ici_links_per_axis: int         # usable links along one torus axis
    num_cores: int                  # TensorCores per chip
    mxu: int = 128                  # systolic array dim
    sublane_bytes: int = 4 * 8      # granule: 8 sublanes of f32
    lane: int = 128

    @property
    def usable_vmem(self) -> int:
        return self.vmem_bytes - self.vmem_reserved_bytes

    def hierarchy(self, mesh_devices: int = 0, hosts: int = 1) -> MemoryLevel:
        """This chip in the paper's §3.1 JSON schema (HBM -> VMEM -> VREG);
        with ``mesh_devices`` the mesh-extended ICI -> HBM -> ... chain, and
        with ``hosts > 1`` the DCN level above it (``mesh_devices`` chips
        per host -- see ``tpu_hierarchy`` / DESIGN.md §6)."""
        return tpu_hierarchy(
            hbm_bytes=self.hbm_bytes,
            vmem_bytes=self.usable_vmem,
            lane_tile_bytes=self.sublane_bytes * self.lane,
            n_cores=self.num_cores,
            mesh_devices=mesh_devices,
            hosts=hosts,
        )

    def sublane(self, dtype_bytes: int) -> int:
        """Second-minor tile granule: 8 for f32, 16 for bf16, 32 for int8."""
        return max(8, (4 // max(1, dtype_bytes)) * 8)


# Target chip for this reproduction (constants per the assignment).
TPU_V5E = TPUSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bytes=16 << 30,
    hbm_bw=819e9,
    vmem_bytes=128 << 20,
    vmem_reserved_bytes=32 << 20,
    ici_bw_per_link=50e9,
    ici_links_per_axis=1,
    num_cores=1,
)

TPU_V4 = TPUSpec(
    name="tpu_v4",
    peak_bf16_flops=275e12,
    hbm_bytes=32 << 30,
    hbm_bw=1228e9,
    vmem_bytes=128 << 20,
    vmem_reserved_bytes=32 << 20,
    ici_bw_per_link=50e9,
    ici_links_per_axis=1,
    num_cores=2,   # megacore: the SRRC "sibling cores sharing an LLC(HBM)"
)

TPU_V5P = TPUSpec(
    name="tpu_v5p",
    peak_bf16_flops=459e12,
    hbm_bytes=96 << 30,
    hbm_bw=2765e9,
    vmem_bytes=128 << 20,
    vmem_reserved_bytes=32 << 20,
    ici_bw_per_link=50e9,
    ici_links_per_axis=3,
    num_cores=2,
)

_SPECS = {s.name: s for s in (TPU_V5E, TPU_V4, TPU_V5P)}


def chip_spec(name: str = "tpu_v5e", **overrides) -> TPUSpec:
    spec = _SPECS[name]
    return replace(spec, **overrides) if overrides else spec
