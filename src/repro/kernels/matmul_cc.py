"""Cache-conscious blocked matmul Pallas kernel.

The block shapes, grid, and traversal order come from the paper's run-time
decomposer (``core.autotile.plan_matmul``): each grid step is one *task* of
the paper -- a (bm x bk) x (bk x bn) partial product whose working set was
sized to fit VMEM -- and the sequential grid traversal is the worker's
stream of tasks (Fig. 2).

Orders:
  * ``cc``    -- row-major, K innermost: output-stationary; the f32
    accumulator block stays in VMEM across the K stream (spatial locality
    of consecutive tasks, §2.2.1).
  * ``srrc``  -- serpentine over the N-block dimension: consecutive output
    tiles in a row share the same A blocks while B blocks alternate
    direction, maximizing reuse of co-resident operands -- the
    shared-cache-aware goal of §2.2.2 mapped to the (HBM -> VMEM) level.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.autotile import MatmulTilePlan
from repro.core.plan import leaf_matmul_plan


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, gk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == gk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_cc(
    a: jax.Array,                  # (M, K)
    b: jax.Array,                  # (K, N)
    plan: Optional[MatmulTilePlan] = None,
    order: str = "cc",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Blocked matmul with decomposer-chosen tiles. Pads ragged edges."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if plan is None:
        # VMEM leaf of the hierarchical planner (memoized per shape/dtype).
        plan = leaf_matmul_plan(m, k, n, dtype_bytes=a.dtype.itemsize,
                                order=order)
    bm, bk, bn = plan.bm, plan.bk, plan.bn
    gm, gn, gk = plan.grid

    pm, pk, pn = gm * bm - m, gk * bk - k, gn * bn - n
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b

    serp = plan.order == "srrc" or order == "srrc"

    def a_map(i, j, kk):
        return (i, kk)

    def b_map(i, j, kk):
        if serp:
            j = jax.lax.select(i % 2 == 1, gn - 1 - j, j)
        return (kk, j)

    def o_map(i, j, kk):
        if serp:
            j = jax.lax.select(i % 2 == 1, gn - 1 - j, j)
        return (i, j)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    out = pl.pallas_call(
        functools.partial(_mm_kernel, gk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
