"""Paged decode attention: gather K/V through a page table, one planned
page per grid step.

The serving engine's KV pool (``repro.serve.pages``) stores every slot's
KV stream as whole *pages* -- the VMEM-sized token runs Algorithm 1 fits
at the plan's page level -- scattered across a shared physical pool.  This
kernel is the read side: for each slot it walks the slot's page table and
streams the pages through VMEM with a running (max, sum, acc) softmax, so
the working set per grid step is exactly ``PAGE_BUFFERING`` pages -- the
kernel's block size along the KV sequence IS ``page_plan()["page_tokens"]``
(asserted), which is what makes the pool's allocation granule and the
kernel's streaming granule the same object.

Grid: ``(slots, n_logical_pages)`` with pages innermost.  The page table
and per-slot lengths ride as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``) so the index map can resolve
``table[slot, page]`` before the DMA is issued -- unallocated logical
pages point at physical page 0 (the pool's reserved null page) and are
masked off by the per-row length, exactly like padded keys in the flash
kernel.  Masks are per row: causal (``kpos <= len-1``), sliding window
(``kpos > len-1-window``), and emptiness (``len == 0`` rows produce a
fully-masked, all-zero output the engine ignores).

The kernel is strictly a GATHER: it never writes KV, so the same physical
page may appear in many slots' table rows at once.  That is what the
cross-request radix prefix cache (``repro.serve.prefix``, DESIGN.md §11)
relies on -- a shared prefix's pages are mapped read-only into every
hitting slot's table, and all KV writes happen outside this kernel
through the layer-side scatters, which the engine constrains to
refcount-1 (private or copy-on-write) pages.

Runs in interpret mode on CPU (the default off-TPU), which is how the
paged-vs-cohort token-identity tests drive it.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, page_tokens: int, n_kv: int,
               n_pages: int, window: int, scale: float):
    s = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = len_ref[s] - 1                           # -1 on empty slots

    # Pages wholly past the row's live length are a no-op under the
    # running softmax (all-masked block: corr = 1, l/acc unchanged), so
    # skip their dot products entirely -- the table width covers the
    # plan's max_tokens bound, but per-token cost must track the LIVE
    # footprint (their DMAs all resolve to the cached null page 0).
    @pl.when(p * page_tokens <= qpos)
    def _accumulate():
        q = q_ref[0]                               # (H, D)
        k = k_ref[0]                               # (T, KV, D)
        v = v_ref[0]
        h, d = q.shape
        g = h // n_kv

        # Grouped GQA contraction: query heads grouped per KV head (the
        # same (kv, g) layout as layers.grouped_attention), never
        # head-repeated.
        qg = q.reshape(n_kv, g, d)
        logits = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)     # (KV, G, T)
        logits = logits.reshape(h, page_tokens) * scale

        kpos = p * page_tokens + jax.lax.broadcasted_iota(
            jnp.int32, (h, page_tokens), 1)
        mask = kpos <= qpos                         # causal + length + empty
        if window:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(logits, axis=-1)[:, None]   # (H, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        pr = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(pr, axis=-1)[:, None]
        pv = jax.lax.dot_general(
            pr.reshape(n_kv, g, page_tokens).astype(v.dtype), v,
            (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)      # (KV, G, D)
        acc_ref[...] = acc_ref[...] * corr + pv.reshape(h, d)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(p == n_pages - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,            # (S, H, D)  one query token per slot
    k_pages: jax.Array,      # (P, T, KV, D)  one layer's page pool
    v_pages: jax.Array,      # (P, T, KV, D)
    page_table: jax.Array,   # (S, NP) int32
    lengths: jax.Array,      # (S,) int32   valid tokens incl. current
    window: int = 0,
    page_tokens: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One decode step of attention against the paged KV pool.

    ``page_tokens`` is the plan's page size; when given it is asserted
    against the pool's second dim -- the kernel refuses to stream at any
    granule other than the planned page (the whole point of the plan).
    Returns ``(S, H, D)``.
    """
    s, h, d = q.shape
    p_total, t, n_kv, _ = k_pages.shape
    if page_tokens is not None and t != page_tokens:
        raise ValueError(
            f"pool page_tokens={t} != planned page_tokens={page_tokens}; "
            f"the kernel block must be the planned page")
    if h % n_kv != 0:
        raise ValueError(f"{h} query heads do not group over {n_kv} KV heads")

    # The gathered K/V block is (1, t, n_kv, d): n_kv is its sublane
    # (second-minor) dim, and Mosaic tiles it in groups of 8.  A grouped-GQA
    # head count that is not a sublane multiple must be padded explicitly --
    # zero KV heads whose (also zero-padded) query heads are sliced off the
    # output -- rather than relying on the shape happening to align.  The
    # contraction batches over the KV-head dim, so padded heads never mix
    # with real ones and real heads' outputs are bit-identical.
    if n_kv % 8:
        g = h // n_kv
        kv_pad = -(-n_kv // 8) * 8
        pad = kv_pad - n_kv
        k_pages = jnp.pad(k_pages, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_pages = jnp.pad(v_pages, ((0, 0), (0, 0), (0, pad), (0, 0)))
        qg = jnp.pad(q.reshape(s, n_kv, g, d),
                     ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = paged_attention(qg.reshape(s, kv_pad * g, d), k_pages,
                              v_pages, page_table, lengths, window=window,
                              page_tokens=page_tokens, interpret=interpret)
        return out.reshape(s, kv_pad, g, d)[:, :n_kv].reshape(s, h, d)

    n_pages = page_table.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = 1.0 / math.sqrt(d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # page_table, lengths
        grid=(s, n_pages),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda si, pi, tbl, ln: (si, 0, 0)),
            pl.BlockSpec((1, t, n_kv, d),
                         lambda si, pi, tbl, ln: (tbl[si, pi], 0, 0, 0)),
            pl.BlockSpec((1, t, n_kv, d),
                         lambda si, pi, tbl, ln: (tbl[si, pi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda si, pi, tbl, ln: (si, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),         # running max
            pltpu.VMEM((h, 1), jnp.float32),         # running sum
            pltpu.VMEM((h, d), jnp.float32),         # output accumulator
        ],
    )
    # jax 0.4.x names it TPUCompilerParams; newer releases CompilerParams.
    params_cls = getattr(pltpu, "CompilerParams", None) or \
        pltpu.TPUCompilerParams
    return pl.pallas_call(
        functools.partial(
            _pa_kernel, page_tokens=t, n_kv=n_kv, n_pages=n_pages,
            window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, h, d), q.dtype),
        compiler_params=params_cls(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
