"""Mamba2 / SSD chunked-scan Pallas kernel.

One grid step processes one (batch, chunk) cell: the intra-chunk quadratic
tile plus the running state update -- the chunk length is the decomposer's
partition size for the time axis (``mamba2.choose_chunk``), so each task's
working set (Q x Q decay tile, Q x P inputs, H x P x N state) fits VMEM.
The state scratch persists across the sequential chunk dimension of the
grid, exactly the paper's worker iterating its stream of partitions.

Layout: heads are folded into the batch grid dim (one head per step keeps
the state tile (P, N) MXU-sized).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)             # (Q, 1)
    a = a_ref[0]                                   # (1, 1) negative decay
    bm = b_ref[0].astype(jnp.float32)              # (Q, N)
    cm = c_ref[0].astype(jnp.float32)              # (Q, N)

    da = dt * a[0, 0]                              # (Q, 1) log decay
    cum = jnp.cumsum(da, axis=0)                   # (Q, 1)

    # Intra-chunk: y_i = sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) dt_j x_j
    seg = cum - cum.T                              # (Q, Q) = cum_i - cum_j
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    w = scores * L                                 # (Q, Q)
    xdt = x * dt                                   # (Q, P)
    y = jnp.dot(w, xdt, preferred_element_type=jnp.float32)

    # Inter-chunk: y_i += exp(cum_i) C_i . S_prev
    s_prev = state_ref[...]                        # (N, P)
    y += jnp.dot(cm * jnp.exp(cum), s_prev,
                 preferred_element_type=jnp.float32)

    # State update: S = exp(cum_last) S_prev + sum_j exp(cum_last - cum_j)
    #                       dt_j B_j x_j^T
    total = cum[chunk - 1]
    decay_out = jnp.exp(total - cum)               # (Q, 1)
    s_new = s_prev * jnp.exp(total)[0] + jnp.dot(
        (bm * decay_out * dt).T, x, preferred_element_type=jnp.float32)
    state_ref[...] = s_new
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(
    x: jax.Array,       # (B, S, H, P)
    dt: jax.Array,      # (B, S, H)   post-softplus
    A: jax.Array,       # (H,)        negative
    Bm: jax.Array,      # (B, S, N)
    Cm: jax.Array,      # (B, S, N)
    chunk: int = 64,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Returns y (B, S, H, P). Heads fold into the grid's parallel dim."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = max(8, min(chunk, s))
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q

    # (B, S, H, P) -> (B*H, S, P); dt -> (B*H, S, 1); B/C broadcast per head.
    xh = jnp.moveaxis(x, 2, 1).reshape(b * h, sp, p)
    dth = jnp.moveaxis(dt, 2, 1).reshape(b * h, sp, 1)
    ah = jnp.tile(A[None, :], (b, 1)).reshape(b * h, 1, 1)
    bmh = jnp.repeat(Bm, h, axis=0).reshape(b * h, sp, n)
    cmh = jnp.repeat(Cm, h, axis=0).reshape(b * h, sp, n)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=q),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, q, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, q, n), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(xh, dth, ah, bmh, cmh)

    y = y.reshape(b, h, sp, p)[:, :, :s]
    return jnp.moveaxis(y, 1, 2)                   # (B, S, H, P)
