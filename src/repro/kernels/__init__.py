"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ``ref.py`` and decomposer-driven BlockSpecs.

  * ``matmul_cc``       -- cache-conscious blocked matmul (CC/SRRC orders)
  * ``flash_attention`` -- streaming-softmax attention, VMEM-sized KV blocks
  * ``ssd_scan``        -- Mamba2/SSD chunked scan with persistent state
"""

from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul_cc import matmul_cc
from repro.kernels.ops import attention, matmul, ssd
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["matmul_cc", "flash_attention", "ssd_scan", "matmul",
           "attention", "ssd"]
