"""Flash attention Pallas kernel with decomposer-sized blocks.

The KV sequence is streamed in ``block_kv`` partitions chosen by the paper's
run-time decomposition (``core.autotile.plan_attention``): each grid step's
working set (Q tile, K/V tiles, f32 score tile, running softmax state) fits
the VMEM budget. The (m, l, acc) running-softmax state is the task-stream
carry -- the paper's Fig. 2 worker iterating its partition stream.

Grid: (batch*heads, q_blocks, kv_blocks) with kv innermost (output-
stationary, CC order). Causal masking is applied per tile from absolute
positions.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.autotile import (
    AttentionTilePlan,
    clamp_attention_plan,
    plan_attention,
)

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
               *, scale: float, causal: bool, gkv: int,
               block_q: int, block_kv: int, q_offset: int, kv_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bkv, d)
    v = v_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    kpos = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = kpos < kv_len                           # padded keys never attend
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0) + q_offset
        mask &= kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]           # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == gkv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                  # (B, H, Sq, D)
    k: jax.Array,                  # (B, H, Sk, D)
    v: jax.Array,                  # (B, H, Sk, D)
    causal: bool = True,
    plan: Optional[AttentionTilePlan] = None,
    interpret: Optional[bool] = None,
    return_plan: bool = False,
):
    """With ``return_plan`` the result is ``(out, effective_plan)`` where
    the plan records the blocks the kernel actually ran -- when the
    sequence forces a clamp below the plan's choice, ``source`` carries a
    ``+clamped`` marker instead of diverging silently (tuning sweeps must
    measure the executed block, not the requested one)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if plan is None:
        plan = plan_attention(sq, sk, d, dtype_bytes=q.dtype.itemsize)
    plan = clamp_attention_plan(plan, sq, sk, dtype_bytes=q.dtype.itemsize)
    bq, bkv = plan.block_q, plan.block_kv

    gq = -(-sq // bq)
    gkv = -(-sk // bkv)
    pq, pk = gq * bq - sq, gkv * bkv - sk
    # Pad queries at the FRONT so causal alignment (ends aligned) holds,
    # and keys at the back (masked by causal positions).
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))

    bh = b * h
    qp = qp.reshape(bh, gq * bq, d)
    kp = kp.reshape(bh, gkv * bkv, d)
    vp = vp.reshape(bh, gkv * bkv, d)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = 1.0 / math.sqrt(d)
    q_offset = sk - sq  # align sequence ends (decode-style)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, causal=causal, gkv=gkv,
            block_q=bq, block_kv=bkv, q_offset=q_offset, kv_len=sk),
        grid=(bh, gq, gkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bhi, qi, kj: (bhi, qi, 0)),
            pl.BlockSpec((1, bkv, d), lambda bhi, qi, kj: (bhi, kj, 0)),
            pl.BlockSpec((1, bkv, d), lambda bhi, qi, kj: (bhi, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bhi, qi, kj: (bhi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, gq * bq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),       # running max
            pltpu.VMEM((bq, 1), jnp.float32),       # running sum
            pltpu.VMEM((bq, d), jnp.float32),       # output accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(qp, kp, vp)
    out = out.reshape(b, h, gq * bq, d)[:, :, :sq]
    return (out, plan) if return_plan else out
