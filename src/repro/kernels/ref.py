"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def flash_attention_ref(
    q: jax.Array,        # (B, H, Sq, D)
    k: jax.Array,        # (B, H, Sk, D)
    v: jax.Array,        # (B, H, Sk, D)
    causal: bool = True,
) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        qpos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends
        mask = jnp.arange(sk)[None, :] <= qpos
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def ssd_ref(
    x: jax.Array,        # (B, S, H, P)
    dt: jax.Array,       # (B, S, H)
    A: jax.Array,        # (H,) negative
    Bm: jax.Array,       # (B, S, N)
    Cm: jax.Array,       # (B, S, N)
) -> jax.Array:
    """Sequential SSD recurrence (the definition, O(S) steps)."""

    def step(state, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        dec = jnp.exp(dtt * A)                      # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt.astype(jnp.float32),
                         bt.astype(jnp.float32))
        state = state * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    b, s, h, p = x.shape
    n = Bm.shape[-1]
    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)    # (B, S, H, P)
