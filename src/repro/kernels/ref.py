"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def flash_attention_ref(
    q: jax.Array,        # (B, H, Sq, D)
    k: jax.Array,        # (B, H, Sk, D)
    v: jax.Array,        # (B, H, Sk, D)
    causal: bool = True,
) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        qpos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends
        mask = jnp.arange(sk)[None, :] <= qpos
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def paged_attention_ref(
    q: jax.Array,            # (S, H, D)  one query token per slot
    k_pages: jax.Array,      # (P, T, KV, D)  page pool, one layer
    v_pages: jax.Array,      # (P, T, KV, D)
    page_table: jax.Array,   # (S, NP) int32  physical page per logical page
    lengths: jax.Array,      # (S,) int32  valid tokens incl. the current one
    window: int = 0,
) -> jax.Array:
    """Paged decode attention, defined by gather: materialize each slot's
    logical KV stream through its page table, then grouped GQA attention
    with per-row causal/window/length masks.  ``lengths[s] == 0`` marks an
    empty slot (output row undefined -- the engine ignores it)."""
    s, h, d = q.shape
    kv = k_pages.shape[2]
    g = h // kv
    k = k_pages[page_table].reshape(s, -1, kv, d)     # (S, NP*T, KV, D)
    v = v_pages[page_table].reshape(s, -1, kv, d)
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(s, kv, g, d)
    logits = jnp.einsum("skgd,stkd->skgt", qg, k).astype(jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])[None, :]            # (1, NP*T)
    qpos = lengths[:, None].astype(jnp.int32) - 1     # (S, 1)
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("skgt,stkd->skgd", probs, v)
    return out.reshape(s, h, d)


def ssd_ref(
    x: jax.Array,        # (B, S, H, P)
    dt: jax.Array,       # (B, S, H)
    A: jax.Array,        # (H,) negative
    Bm: jax.Array,       # (B, S, N)
    Cm: jax.Array,       # (B, S, N)
) -> jax.Array:
    """Sequential SSD recurrence (the definition, O(S) steps)."""

    def step(state, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        dec = jnp.exp(dtt * A)                      # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt.astype(jnp.float32),
                         bt.astype(jnp.float32))
        state = state * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    b, s, h, p = x.shape
    n = Bm.shape[-1]
    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)    # (B, S, H, P)
