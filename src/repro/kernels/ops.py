"""Jitted public wrappers around the Pallas kernels.

On CPU these run the kernels in interpret mode (the Python-level execution
of the kernel body -- bit-faithful to the block program); on TPU they
compile via Mosaic. The wrappers take care of planning (via the paper's
decomposer), padding, and layout.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul_cc import matmul_cc
from repro.kernels.ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("order", "interpret"))
def matmul(a: jax.Array, b: jax.Array, order: str = "cc",
           interpret: Optional[bool] = None) -> jax.Array:
    """Cache-conscious blocked matmul: C[m,n] = A[m,k] @ B[k,n]."""
    return matmul_cc(a, b, order=order, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "interpret"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention over (B, H, S, D) tensors."""
    return flash_attention(q, k, v, causal=causal, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, chunk: int = 64,
        interpret: Optional[bool] = None) -> jax.Array:
    """Chunked selective-state-space scan (Mamba2/SSD)."""
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
