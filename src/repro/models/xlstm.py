"""xLSTM blocks (arXiv:2405.04517): chunkwise-parallel mLSTM (matrix memory,
exponential gating, stabilizer) and sequential sLSTM (scalar memory with
recurrent gate mixing).

The mLSTM training path is chunkwise -- the same cache-conscious structure
as SSD: a (Q x Q) stabilized intra-chunk tile plus a cross-chunk (C, n, m)
state scan; the chunk length is the decomposer-chosen partition size. The
step form (``mlstm_step`` / ``slstm_step``) serves decode and is the oracle
for the chunkwise path in tests.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.mamba2 import causal_conv1d
from repro.models.params import ParamSpec

NEG = -1e30


def _round128(x: float) -> int:
    """Projection dims rounded to lane multiples (mesh- and MXU-friendly)."""
    return max(128, int(-(-x // 128)) * 128)


# ---------------------------------------------------------------------------
# mLSTM cell: chunkwise parallel + sequential step
# ---------------------------------------------------------------------------

def mlstm_chunkwise(
    q: jax.Array,       # (B, S, H, D)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,   # (B, S, H) input-gate pre-activations
    f_pre: jax.Array,   # (B, S, H) forget-gate pre-activations
    chunk: int,
    state: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    """Stabilized chunkwise mLSTM. Returns (h (B,S,H,D), (C, n, m))."""
    b, s, h, d = q.shape
    qs = min(chunk, s)
    pad = (-s) % qs
    if pad:
        zf = lambda a, val=0.0: jnp.pad(
            a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
            constant_values=val)
        q, k, v = zf(q), zf(k), zf(v)
        i_pre = zf(i_pre, NEG)         # padded tokens contribute nothing
        f_pre = zf(f_pre, 30.0)        # ~no decay through padding (log_sigmoid~0)
    nc = q.shape[1] // qs
    scale = 1.0 / math.sqrt(d)

    def resh(a):
        return jnp.moveaxis(
            a.reshape(b, nc, qs, h, *a.shape[3:]), 3, 2
        )  # (B, nc, H, Q, ...)

    qc, kc, vc = resh(q), resh(k), resh(v)
    ic = jnp.moveaxis(i_pre.reshape(b, nc, qs, h), 3, 2).astype(jnp.float32)
    fc = jnp.moveaxis(f_pre.reshape(b, nc, qs, h), 3, 2).astype(jnp.float32)

    logf = jax.nn.log_sigmoid(fc)                       # (B,nc,H,Q)
    bcum = jnp.cumsum(logf, axis=-1)                    # within-chunk cumsum

    if state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), NEG, jnp.float32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((qs, qs), bool))

    def chunk_step(carry, inp):
        C, n, m = carry
        qq, kk, vv, bb, ii = inp    # (B,H,Q,D)x3, (B,H,Q), (B,H,Q)

        # Intra-chunk log weights D_ij = b_i - b_j + i_j  (j <= i).
        Dlog = bb[..., :, None] - bb[..., None, :] + ii[..., None, :]
        Dlog = jnp.where(tri, Dlog, NEG)                # (B,H,Q,Q)
        # Inter-chunk log weight for token i: b_i + m_prev.
        inter_log = bb + m[..., None]                   # (B,H,Q)
        m_new = jnp.maximum(Dlog.max(-1), inter_log)    # (B,H,Q)
        m_new = jnp.maximum(m_new, -m_new * 0 - 50.0)   # floor for stability

        sc = jnp.einsum("bhqd,bhkd->bhqk",
                        qq.astype(jnp.float32), kk.astype(jnp.float32)) * scale
        W = jnp.exp(Dlog - m_new[..., None]) * sc       # (B,H,Q,Q)
        num_intra = jnp.einsum("bhqk,bhkd->bhqd", W, vv.astype(jnp.float32))
        den_intra = W.sum(-1)                           # (B,H,Q)

        inter_w = jnp.exp(inter_log - m_new)            # (B,H,Q)
        q32 = qq.astype(jnp.float32) * scale
        num_inter = jnp.einsum("bhqd,bhde->bhqe", q32, C) * inter_w[..., None]
        den_inter = jnp.einsum("bhqd,bhd->bhq", q32, n) * inter_w

        num = num_intra + num_inter
        den = den_intra + den_inter
        hloc = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]

        # State update to chunk end.
        btot = bb[..., -1:]                             # (B,H,1)
        m_end = jnp.maximum(btot[..., 0] + m, (btot - bb + ii).max(-1))
        decay_C = jnp.exp(btot[..., 0] + m - m_end)     # (B,H)
        kw = jnp.exp(btot - bb + ii - m_end[..., None])  # (B,H,Q)
        C_new = C * decay_C[..., None, None] + jnp.einsum(
            "bhq,bhqd,bhqe->bhde", kw, kk.astype(jnp.float32),
            vv.astype(jnp.float32))
        n_new = n * decay_C[..., None] + jnp.einsum(
            "bhq,bhqd->bhd", kw, kk.astype(jnp.float32))
        return (C_new, n_new, m_end), hloc

    inputs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, bcum, ic))
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0), inputs)
    hs = jnp.moveaxis(hs, 0, 1)                         # (B,nc,H,Q,D)
    out = jnp.moveaxis(hs, 2, 3).reshape(b, nc * qs, h, d)[:, :s]
    return out.astype(q.dtype), (Cf, nf, mf)


def mlstm_step(
    q: jax.Array,      # (B, H, D)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (B, H)
    f_pre: jax.Array,  # (B, H)
    state: Tuple[jax.Array, jax.Array, jax.Array],
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    C, n, m = state
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i32 = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, i32)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(i32 - m_new)
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)
    C_new = C * fw[..., None, None] + iw[..., None, None] * (
        k32[..., :, None] * v32[..., None, :])
    n_new = n * fw[..., None] + iw[..., None] * k32
    q32 = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhde->bhe", q32, C_new)
    den = jnp.einsum("bhd,bhd->bh", q32, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM cell (sequential; scalar memory + recurrent gate mixing)
# ---------------------------------------------------------------------------

def slstm_scan(
    gx: jax.Array,     # (B, S, H, 4, D) gate pre-activations from input
    R: jax.Array,      # (H, D, 4, D) block-diagonal recurrent weights
    state: Tuple[jax.Array, ...],   # (c, n, h, m): each (B, H, D)
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    def step(carry, g_t):
        c, n, hprev, m = carry
        rec = jnp.einsum("bhd,hdge->bhge", hprev, R.astype(jnp.float32))
        g = g_t.astype(jnp.float32) + rec               # (B,H,4,D)
        z_pre, i_pre, f_pre, o_pre = (g[:, :, 0], g[:, :, 1],
                                      g[:, :, 2], g[:, :, 3])
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        fw = jnp.exp(logf + m - m_new)
        iw = jnp.exp(i_pre - m_new)
        z = jnp.tanh(z_pre)
        c_new = fw * c + iw * z
        n_new = fw * n + iw
        h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    gseq = jnp.moveaxis(gx, 1, 0)                       # (S,B,H,4,D)
    new_state, hs = jax.lax.scan(step, state, gseq)
    return jnp.moveaxis(hs, 0, 1), new_state            # (B,S,H,D)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def mlstm_param_specs(cfg: ModelConfig, layers: int = 0) -> dict:
    x = cfg.xlstm
    d = cfg.d_model
    di = _round128(x.mlstm_proj_factor * d)
    h = cfg.n_heads
    ls = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "w_up": ParamSpec(ls + (d, 2 * di), la + ("embed", "mlp")),
        "conv_w": ParamSpec(ls + (x.conv_width, di), la + (None, "mlp")),
        "conv_b": ParamSpec(ls + (di,), la + ("mlp",), init="zeros"),
        "wq": ParamSpec(ls + (di, di), la + ("embed", "heads")),
        "wk": ParamSpec(ls + (di, di), la + ("embed", "heads")),
        "wv": ParamSpec(ls + (di, di), la + ("embed", "heads")),
        "wif": ParamSpec(ls + (di, 2 * h), la + ("mlp", None)),
        "out_norm": ParamSpec(ls + (di,), la + ("mlp",), init="ones"),
        "w_down": ParamSpec(ls + (di, d), la + ("mlp", "embed"),
                            scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers))),
    }


def mlstm_block(
    params: dict,
    hidden: jax.Array,               # (B, S, d)
    cfg: ModelConfig,
    cache: Optional[dict] = None,    # {"conv": ..., "C": ..., "n": ..., "m": ...}
    chunk: int = 256,
) -> Tuple[jax.Array, Optional[dict]]:
    x_cfg = cfg.xlstm
    b, s, d = hidden.shape
    di = _round128(x_cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    dh = di // h

    up = hidden @ params["w_up"].astype(hidden.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = causal_conv1d(xm, params["conv_w"], params["conv_b"],
                                 conv_state)
    q = (xc @ params["wq"].astype(xc.dtype)).reshape(b, s, h, dh)
    k = (xc @ params["wk"].astype(xc.dtype)).reshape(b, s, h, dh)
    v = (xm @ params["wv"].astype(xm.dtype)).reshape(b, s, h, dh)
    gif = xm @ params["wif"].astype(xm.dtype)            # (B,S,2H)
    i_pre, f_pre = jnp.split(gif, 2, axis=-1)

    new_cache = None
    if cache is not None and s == 1:
        hout, (C, n, m) = mlstm_step(
            q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0],
            (cache["C"], cache["n"], cache["m"]),
        )
        hout = hout[:, None]
        new_cache = {"conv": new_conv, "C": C, "n": n, "m": m}
    else:
        state = None
        if cache is not None:
            state = (cache["C"], cache["n"], cache["m"])
        hout, (C, n, m) = mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk, state)
        if cache is not None:
            new_cache = {"conv": new_conv, "C": C, "n": n, "m": m}

    hout = hout.reshape(b, s, di)
    hout = rms_norm(hout, params["out_norm"], cfg.norm_eps)
    out = (hout * jax.nn.silu(z)) @ params["w_down"].astype(hout.dtype)
    return out, new_cache


def slstm_param_specs(cfg: ModelConfig, layers: int = 0) -> dict:
    x = cfg.xlstm
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dff = _round128(x.slstm_proj_factor * d)
    ls = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "w_gates": ParamSpec(ls + (d, 4 * d), la + ("embed", "mlp")),
        # True head-count leading dim (4): too small to shard over the
        # 16-way model axis; replicated (25M params).
        "r_gates": ParamSpec(ls + (h, dh, 4, dh), la + (None, None, None, None),
                             scale=0.5),
        "out_norm": ParamSpec(ls + (d,), la + ("embed",), init="ones"),
        "w_up_g": ParamSpec(ls + (d, dff), la + ("embed", "mlp")),
        "w_up_v": ParamSpec(ls + (d, dff), la + ("embed", "mlp")),
        "w_down": ParamSpec(ls + (dff, d), la + ("mlp", "embed"),
                            scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers))),
    }


def slstm_block(
    params: dict,
    hidden: jax.Array,
    cfg: ModelConfig,
    cache: Optional[dict] = None,   # {"c","n","h","m"} each (B,H,dh)
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d = hidden.shape
    h = cfg.n_heads
    dh = d // h
    gx = (hidden @ params["w_gates"].astype(hidden.dtype)).reshape(b, s, 4, h, dh)
    gx = jnp.moveaxis(gx, 2, 3)                          # (B,S,H,4,dh)
    if cache is not None:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        zero = jnp.zeros((b, h, dh), jnp.float32)
        state = (zero, zero, zero, jnp.full((b, h, dh), NEG, jnp.float32))
    hs, (c, n, hstate, m) = slstm_scan(gx, params["r_gates"], state)
    new_cache = None
    if cache is not None:
        new_cache = {"c": c, "n": n, "h": hstate, "m": m}
    hs = hs.astype(hidden.dtype).reshape(b, s, d)
    hs = rms_norm(hs, params["out_norm"], cfg.norm_eps)
    up = jax.nn.gelu(hs @ params["w_up_g"].astype(hs.dtype)) * (
        hs @ params["w_up_v"].astype(hs.dtype))
    out = up @ params["w_down"].astype(up.dtype)
    return out, new_cache
