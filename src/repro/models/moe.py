"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Dispatch is scatter/gather based rather than the GShard one-hot einsum: the
(T, E, C) dispatch tensor contraction costs T*E*C*d FLOPs (over half the
expert FLOPs for DeepSeek-V2's 160 experts), whereas scatter+gather moves
each routed token exactly once. Capacity is per batch row so routed tokens
stay on their row's device under data sharding; expert weights carry an
"experts" logical axis sharded over the model axis (EP).

Routing: softmax gates -> top-k -> renormalize (Mixtral/DeepSeek style),
plus the standard load-balancing auxiliary loss.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.params import ParamSpec


def moe_param_specs(cfg: ModelConfig, layers: int = 0) -> dict:
    mo = cfg.moe
    d = cfg.d_model
    f = mo.d_ff_expert or cfg.d_ff
    ls = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    specs = {
        "router": ParamSpec(ls + (d, mo.n_experts), la + ("embed", None)),
        "wi": ParamSpec(ls + (mo.n_experts, d, f), la + ("experts", "embed", "mlp_expert")),
        "wg": ParamSpec(ls + (mo.n_experts, d, f), la + ("experts", "embed", "mlp_expert")),
        "wo": ParamSpec(ls + (mo.n_experts, f, d), la + ("experts", "mlp_expert", "embed"),
                        scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers))),
    }
    if mo.n_shared_experts:
        fs = f * mo.n_shared_experts
        specs["shared_wi"] = ParamSpec(ls + (d, fs), la + ("embed", "mlp"))
        specs["shared_wg"] = ParamSpec(ls + (d, fs), la + ("embed", "mlp"))
        specs["shared_wo"] = ParamSpec(ls + (fs, d), la + ("mlp", "embed"),
                                       scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers)))
    return specs


def moe_ffn(
    params: dict,
    x: jax.Array,                 # (B, S, d)
    moe: MoEConfig,
    capacity_factor: Optional[float] = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).

    ``capacity_factor=None`` dispatches DROPLESS (``cap = s``, the per-row
    worst case -- an expert can appear at most once in a token's top-k).
    Chunked prefill uses it: capacity is a function of the dispatch length,
    so a capacity-dropped token would make the result depend on where the
    chunk boundaries fall; dropless dispatch makes any chunking of the
    prompt produce identical tokens (single-token decode is dropless by
    the same bound, so decode agrees for free).
    """
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    cap = s if capacity_factor is None else \
        max(1, math.ceil(s * k * capacity_factor / e))

    gates = jax.nn.softmax(
        jnp.einsum("bsd,de->bse", x, params["router"].astype(jnp.float32)
                   .astype(x.dtype)).astype(jnp.float32),
        axis=-1,
    )                                                    # (B, S, E) f32
    top_v, top_i = jax.lax.top_k(gates, k)               # (B, S, K)
    # Renormalize in f32, combine in the compute dtype. (Measured: the
    # combine-path psum dtype is unaffected -- XLA keeps f32 reduction
    # accumulators regardless; see EXPERIMENTS.md §Perf cell 4, H9.)
    top_v = (top_v / jnp.maximum(top_v.sum(-1, keepdims=True), 1e-9)
             ).astype(x.dtype)

    # Position of each (token, k) slot within its expert's buffer: exclusive
    # cumulative count over the flattened (S, K) stream, per batch row.
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.int32)   # (B, S, K, E)
    flat = onehot.reshape(b, s * k, e)
    pos_in_e = jnp.cumsum(flat, axis=1) - 1              # (B, S*K, E)
    pos_tok = jnp.sum(pos_in_e * flat, axis=-1).reshape(b, s, k)
    keep = pos_tok < cap                                  # (B, S, K)

    # Scatter tokens into (B, E, C, d) buffers -- one scatter per k slot so
    # the token activations are never replicated K times.
    buf = jnp.zeros((b, e, cap, d), x.dtype)
    b_idx = jnp.arange(b)[:, None]
    for kk in range(k):
        w = keep[:, :, kk].astype(x.dtype)[..., None]    # (B, S, 1)
        buf = buf.at[b_idx, top_i[:, :, kk], pos_tok[:, :, kk]].add(
            x * w, mode="drop",
        )

    # Expert FFN (SwiGLU), e as a batch dim; EP shards it over "model".
    from repro.dist.sharding import active_rule, constrain

    # TP-expert mode (experts % model != 0, e.g. Mixtral's 8 over 16):
    # pin the dispatch buffers and expert-hidden activations, else GSPMD
    # leaves the row-parallel contraction partially sharded and
    # all-reduces (B, E, C, f)-sized f32 tensors (measured: -43.7% step
    # bound on mixtral-8x7b train_4k). In EP mode the same constraints
    # force token buffers onto the expert axis and explode the dispatch
    # collectives (+434% on deepseek-v2 -- measured, refuted); GSPMD's own
    # propagation is better there, so constrain nothing.
    tp_expert_mode = active_rule("experts") is None
    if tp_expert_mode:
        buf = constrain(buf, ("batch", "experts", None, None))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["wg"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", buf, params["wi"].astype(x.dtype))
    if tp_expert_mode:
        h = constrain(h, ("batch", "experts", None, "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, params["wo"].astype(x.dtype))
    if tp_expert_mode:
        out_buf = constrain(out_buf, ("batch", "experts", None, None))

    # Gather-combine.
    y = jnp.zeros_like(x)
    for kk in range(k):
        gathered = out_buf[b_idx, top_i[:, :, kk], pos_tok[:, :, kk]]  # (B,S,d)
        w = (top_v[:, :, kk]
             * keep[:, :, kk].astype(x.dtype))[..., None]
        y = y + gathered * w

    # Shared experts (DeepSeek): always-on dense SwiGLU branch.
    if "shared_wi" in params:
        hs = jax.nn.silu(x @ params["shared_wg"].astype(x.dtype)) * (
            x @ params["shared_wi"].astype(x.dtype)
        )
        y = y + hs @ params["shared_wo"].astype(x.dtype)

    # Load-balancing aux loss (Switch/GShard): E * sum_e f_e * p_e.
    me = jnp.mean(gates, axis=(0, 1))                             # (E,)
    ce = jnp.mean(onehot.astype(jnp.float32).sum(2), axis=(0, 1))  # (E,)
    aux = moe.router_aux_weight * e * jnp.sum(me * ce)
    return y, aux
