"""Model assembly for the 10 assigned architectures.

A ``Model`` bundles: declarative param specs (with logical sharding axes),
the training loss, prefill, and the single-token decode step with the
family-appropriate cache (full KV, sliding-window ring, MLA latent, SSD
state, xLSTM states, enc-dec self+cross).

Homogeneous layer stacks run under ``lax.scan`` over stacked params (one
traced layer regardless of depth -- essential for compiling 60-layer models
on this container); heterogeneous patterns (Zamba2's shared attention,
xLSTM's sLSTM interleave, DeepSeek-V2's leading dense layer) use small
Python loops around scanned homogeneous runs.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import xlstm as XL
from repro.models.params import (
    ParamSpec,
    abstract_params,
    count_params,
    init_params,
    param_axes,
)

PyTree = Any


def _norm_spec(cfg, layers=0, name="ln"):
    ls = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return ParamSpec(ls + (cfg.d_model,), la + ("embed",), init="ones")


# ---------------------------------------------------------------------------
# Decoder-layer family bodies (dense / moe / mla_moe / vlm)
# ---------------------------------------------------------------------------


def _tf_layer_specs(cfg: ModelConfig, layers: int, kind: str) -> dict:
    specs = {"ln1": _norm_spec(cfg, layers), "ln2": _norm_spec(cfg, layers)}
    if kind == "mla":
        specs["attn"] = MLA.mla_param_specs(cfg, layers)
    else:
        specs["attn"] = L.attention_param_specs(cfg, layers)
    if kind in ("moe", "mla"):
        specs["moe"] = MOE.moe_param_specs(cfg, layers)
    else:
        specs["ffn"] = L.ffn_param_specs(cfg, layers=layers)
    return specs


def _tf_layer(
    lp: dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    attn,
    capacity_factor: float,
) -> Tuple[jax.Array, Any, jax.Array]:
    """ONE decoder-layer body for every execution mode (the ROADMAP's
    de-forked layer): pre-norm attention + residual, pre-norm FFN +
    residual.

    ``attn(lp["attn"], h) -> (attn_out, new_kv)`` is the mode-specific
    attention hook -- cached cohort/prefill attention (``_cached_attn``),
    the per-slot paged decode gather (``_paged_attn``), or the
    chunked-prefill page writer (``_chunk_attn``) -- and ``new_kv`` is
    whatever cache state the hook threads (None for stateless modes).
    ``kind`` picks the FFN only: "moe"/"mla" route through ``moe_ffn``,
    everything else SwiGLU.  Every step resolves this module global at
    call time, so a layer change lands in cohort, paged, and
    prefill-chunk paths at once -- and the unified-body regression test
    counts calls by monkeypatching it.
    """
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, new_kv = attn(lp["attn"], h)
    x = constrain(x + a, ("batch", "seq", "embed"))
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("moe", "mla"):
        f, aux = MOE.moe_ffn(lp["moe"], h, cfg.moe, capacity_factor)
    else:
        f = L.swiglu_ffn(lp["ffn"], h)
    x = constrain(x + f, ("batch", "seq", "embed"))
    return x, new_kv, aux


def _cached_attn(cfg: ModelConfig, attn_kind: str, q_pos, cache,
                 positions_3d=None, causal: bool = True):
    """Attention hook: the cohort/prefill modes' family cache semantics
    (full KV, sliding-window ring, MLA latent) with batch-shared
    positions.  ``attn_kind`` "mla" routes to the latent-attention block;
    anything else is the GQA block."""
    if attn_kind == "mla":
        return lambda ap, h: MLA.mla_attention(ap, h, q_pos, cfg, cache)
    return lambda ap, h: L.attention_block(
        ap, h, q_pos, q_pos, cfg, cache, positions_3d, causal=causal)


def _paged_attn(cfg: ModelConfig, attn_kind: str, pos, table, layer,
                *pools):
    """Attention hook: per-slot paged decode against the page pool.
    ``new_kv`` is the updated pool tuple the scan carry threads."""
    if attn_kind == "mla":
        (lat,) = pools

        def hook(ap, h):
            a, nlat = MLA.paged_mla_attention_block(
                ap, h, pos, cfg, lat, layer, table)
            return a, (nlat,)
        return hook
    kp, vp = pools

    def hook(ap, h):
        a, nkp, nvp = L.paged_attention_block(
            ap, h, pos, cfg, kp, vp, layer, table)
        return a, (nkp, nvp)
    return hook


def _chunk_attn(cfg: ModelConfig, attn_kind: str, positions, table_row,
                layer, *pools):
    """Attention hook: one page-sized prefill chunk written directly into
    the slot's pool pages (the tentpole's zero-copy prefill path)."""
    if attn_kind == "mla":
        (lat,) = pools

        def hook(ap, h):
            a, nlat = MLA.paged_mla_prefill_block(
                ap, h, positions, cfg, lat, layer, table_row)
            return a, (nlat,)
        return hook
    kp, vp = pools

    def hook(ap, h):
        a, nkp, nvp = L.paged_prefill_block(
            ap, h, positions, cfg, kp, vp, layer, table_row)
        return a, (nkp, nvp)
    return hook


def _dec_layer(lp: dict, x: jax.Array, cfg: ModelConfig, self_attn,
               cross_attn) -> Tuple[jax.Array, Any]:
    """The enc-dec decoder-layer body, shared by training, prefill,
    cohort decode, chunked prefill, and paged decode: pre-norm
    self-attention, pre-norm cross-attention, pre-norm FFN.  Both hooks
    follow the ``_tf_layer`` convention; only ``self_attn`` carries
    cache state."""
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, new_kv = self_attn(lp["attn"], h)
    x = constrain(x + a, ("batch", "seq", "embed"))
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = constrain(x + cross_attn(lp["cross"], h), ("batch", "seq", "embed"))
    h = L.rms_norm(x, lp["ln3"], cfg.norm_eps)
    x = constrain(x + L.swiglu_ffn(lp["ffn"], h), ("batch", "seq", "embed"))
    return x, new_kv


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclass
class Model:
    cfg: ModelConfig
    remat: str = "full"
    capacity_factor: float = 1.25

    # ------------------------------------------------------------- params
    def param_specs(self) -> dict:
        cfg = self.cfg
        specs = L.embed_param_specs(cfg)
        fam = cfg.family
        if fam in ("dense", "vlm"):
            specs["layers"] = _tf_layer_specs(cfg, cfg.n_layers, "dense")
        elif fam == "moe":
            specs["layers"] = _tf_layer_specs(cfg, cfg.n_layers, "moe")
        elif fam == "mla_moe":
            kd = cfg.moe.first_k_dense
            if kd:
                dense_cfg = cfg
                specs["dense_layers"] = {
                    "ln1": _norm_spec(cfg, kd), "ln2": _norm_spec(cfg, kd),
                    "attn": MLA.mla_param_specs(cfg, kd),
                    "ffn": L.ffn_param_specs(cfg, d_ff=cfg.moe.dense_d_ff, layers=kd),
                }
            specs["layers"] = _tf_layer_specs(cfg, cfg.n_layers - kd, "mla")
        elif fam == "hybrid_ssm":
            specs["mamba_layers"] = M2.mamba2_param_specs(cfg, cfg.n_layers)
            if cfg.ssm.attn_every:
                specs["shared_attn"] = {
                    "ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                    "attn": L.attention_param_specs(cfg),
                    "ffn": L.ffn_param_specs(cfg),
                }
        elif fam == "xlstm":
            n_s = cfg.n_layers // cfg.xlstm.slstm_every
            n_m = cfg.n_layers - n_s
            specs["mlstm_layers"] = XL.mlstm_param_specs(cfg, n_m)
            specs["mlstm_ln"] = _norm_spec(cfg, n_m)
            specs["slstm_layers"] = XL.slstm_param_specs(cfg, n_s)
            specs["slstm_ln"] = _norm_spec(cfg, n_s)
        elif fam == "enc_dec":
            e = cfg.enc_dec
            specs["enc_layers"] = {
                "ln1": _norm_spec(cfg, e.n_encoder_layers),
                "ln2": _norm_spec(cfg, e.n_encoder_layers),
                "attn": L.attention_param_specs(cfg, e.n_encoder_layers),
                "ffn": L.ffn_param_specs(cfg, layers=e.n_encoder_layers),
            }
            specs["dec_layers"] = {
                "ln1": _norm_spec(cfg, e.n_decoder_layers),
                "ln2": _norm_spec(cfg, e.n_decoder_layers),
                "ln3": _norm_spec(cfg, e.n_decoder_layers),
                "attn": L.attention_param_specs(cfg, e.n_decoder_layers),
                "cross": L.attention_param_specs(cfg, e.n_decoder_layers),
                "ffn": L.ffn_param_specs(cfg, layers=e.n_decoder_layers),
            }
            specs["enc_final_norm"] = _norm_spec(cfg)
        else:
            raise ValueError(f"unknown family {fam}")
        return specs

    def init(self, rng, dtype=jnp.float32) -> PyTree:
        return init_params(self.param_specs(), rng, dtype)

    def abstract_params(self, dtype=jnp.float32) -> PyTree:
        return abstract_params(self.param_specs(), dtype)

    def axes(self) -> PyTree:
        return param_axes(self.param_specs())

    def n_params(self) -> int:
        return count_params(self.param_specs())

    # ------------------------------------------------------------ forward
    def _maybe_remat(self, fn):
        if self.remat == "none":
            return fn
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return jax.checkpoint(fn)

    def _embed_in(self, params, batch, dtype):
        cfg = self.cfg
        if cfg.input_embeds and "embeds" in batch:
            return batch["embeds"].astype(dtype)
        return L.embed_tokens(params, batch["tokens"], dtype)

    def forward(self, params: PyTree, batch: Dict[str, jax.Array],
                dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
        """Training/prefill forward. Returns (logits, aux_loss)."""
        cfg = self.cfg
        fam = cfg.family
        pos3d = batch.get("positions_3d")
        if fam == "enc_dec":
            return self._forward_encdec(params, batch, dtype)
        x = self._embed_in(params, batch, dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        s = x.shape[1]
        q_pos = jnp.arange(s)
        aux_total = jnp.zeros((), jnp.float32)

        if fam in ("dense", "vlm", "moe", "mla_moe"):
            kind = {"dense": "dense", "vlm": "dense",
                    "moe": "moe", "mla_moe": "mla"}[fam]
            if fam == "mla_moe" and cfg.moe.first_k_dense:
                def dense_body(lp, x):
                    y, _, _ = _tf_layer(
                        lp, x, cfg, "dense",
                        _cached_attn(cfg, "mla", q_pos, None),
                        self.capacity_factor)
                    return y
                body = self._maybe_remat(dense_body)

                def dscan(x, lp):
                    return body(lp, x), None
                x, _ = jax.lax.scan(dscan, x, params["dense_layers"])

            def layer_body(lp, x):
                y, _, aux = _tf_layer(
                    lp, x, cfg, kind,
                    _cached_attn(cfg, kind, q_pos, None, pos3d),
                    self.capacity_factor)
                return y, aux
            body = self._maybe_remat(layer_body)

            def scan_body(carry, lp):
                x, aux = carry
                y, a = body(lp, x)
                return (y, aux + a), None
            (x, aux_total), _ = jax.lax.scan(
                scan_body, (x, aux_total), params["layers"])

        elif fam == "hybrid_ssm":
            x, aux_total = self._hybrid_stack(params, x, q_pos, None)[0:2]
        elif fam == "xlstm":
            x = self._xlstm_stack(params, x, None)[0]
        else:
            raise ValueError(fam)

        logits = L.lm_logits(params, x, cfg)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        return logits, aux_total

    # Hybrid (Zamba2): mamba stack with a weight-shared attn block applied
    # every ``attn_every`` layers. Returns (x, aux, new_caches).
    def _hybrid_stack(self, params, x, q_pos, caches):
        cfg = self.cfg
        per = cfg.ssm.attn_every or cfg.n_layers
        n_apps = -(-cfg.n_layers // per) if cfg.ssm.attn_every else 0

        mam_body = self._maybe_remat(
            lambda lp, x, c: M2.mamba2_block(lp, x, cfg, c))
        attn_body = self._maybe_remat(
            lambda ap, x, c: self._shared_attn(ap, x, q_pos, c))

        new_mamba_caches = [] if caches is not None else None
        new_attn_caches = [] if caches is not None else None
        app = 0
        for start in range(0, cfg.n_layers, per):
            stop = min(start + per, cfg.n_layers)
            if cfg.ssm.attn_every:
                ac = None if caches is None else jax.tree.map(
                    lambda a: a[app], caches["attn"])
                x, nac = attn_body(params["shared_attn"], x, ac)
                if caches is not None:
                    new_attn_caches.append(nac)
                app += 1
            lp_slice = jax.tree.map(lambda a: a[start:stop],
                                    params["mamba_layers"])
            if caches is None:
                def mscan(carry, lp):
                    y, _ = mam_body(lp, carry, None)
                    return y, None
                x, _ = jax.lax.scan(mscan, x, lp_slice)
            else:
                c_slice = jax.tree.map(lambda a: a[start:stop],
                                       caches["mamba"])
                def mscan_c(carry, inp):
                    lp, c = inp
                    y, nc = mam_body(lp, carry, c)
                    return y, nc
                x, ncs = jax.lax.scan(mscan_c, x, (lp_slice, c_slice))
                new_mamba_caches.append(ncs)
        aux = jnp.zeros((), jnp.float32)
        new_caches = None
        if caches is not None:
            new_caches = {
                "mamba": jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *new_mamba_caches),
                "attn": jax.tree.map(
                    lambda *xs: jnp.stack(xs, 0), *new_attn_caches),
            }
        return x, aux, new_caches

    def _shared_attn(self, ap, x, q_pos, cache):
        y, new_cache, _ = _tf_layer(
            ap, x, self.cfg, "dense",
            _cached_attn(self.cfg, "dense", q_pos, cache),
            self.capacity_factor)
        return y, new_cache

    # xLSTM: periods of (slstm_every - 1) mLSTM + 1 sLSTM.
    def _xlstm_stack(self, params, x, caches):
        cfg = self.cfg
        per = cfg.xlstm.slstm_every
        n_periods = cfg.n_layers // per
        m_per = per - 1
        chunk = min(cfg.ssm.chunk if cfg.ssm else 256, max(16, x.shape[1]))

        def m_body(lp, ln, x, c):
            h = L.rms_norm(x, ln, cfg.norm_eps)
            y, nc = XL.mlstm_block(lp, h, cfg, c, chunk)
            return x + y, nc
        m_body = self._maybe_remat(m_body)

        def s_body(lp, ln, x, c):
            h = L.rms_norm(x, ln, cfg.norm_eps)
            y, nc = XL.slstm_block(lp, h, cfg, c)
            return x + y, nc
        s_body = self._maybe_remat(s_body)

        new_m = [] if caches is not None else None
        new_s = [] if caches is not None else None
        for p in range(n_periods):
            mslice = jax.tree.map(
                lambda a: a[p * m_per:(p + 1) * m_per], params["mlstm_layers"])
            lnslice = params["mlstm_ln"][p * m_per:(p + 1) * m_per]
            if caches is None:
                def mscan(carry, inp):
                    lp, ln = inp
                    y, _ = m_body(lp, ln, carry, None)
                    return y, None
                x, _ = jax.lax.scan(mscan, x, (mslice, lnslice))
                sp = jax.tree.map(lambda a: a[p], params["slstm_layers"])
                x, _ = s_body(sp, params["slstm_ln"][p], x, None)
            else:
                cslice = jax.tree.map(
                    lambda a: a[p * m_per:(p + 1) * m_per], caches["mlstm"])
                def mscan_c(carry, inp):
                    lp, ln, c = inp
                    y, nc = m_body(lp, ln, carry, c)
                    return y, nc
                x, ncs = jax.lax.scan(mscan_c, x, (mslice, lnslice, cslice))
                new_m.append(ncs)
                sp = jax.tree.map(lambda a: a[p], params["slstm_layers"])
                sc = jax.tree.map(lambda a: a[p], caches["slstm"])
                x, nsc = s_body(sp, params["slstm_ln"][p], x, sc)
                new_s.append(nsc)
        new_caches = None
        if caches is not None:
            new_caches = {
                "mlstm": jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *new_m),
                "slstm": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_s),
            }
        return x, new_caches

    def _encode(self, params, enc_embeds, dtype):
        """Run the encoder stack (shared by training forward, monolithic
        prefill, and the paged engine's admission-time encode).  The
        encoder layer IS ``_tf_layer`` with a non-causal hook.  Returns
        the final-normed encoder output ``(B, Se, d)``."""
        cfg = self.cfg
        enc = enc_embeds.astype(dtype)
        enc_pos = jnp.arange(enc.shape[1])

        def enc_body(lp, x):
            y, _, _ = _tf_layer(
                lp, x, cfg, "dense",
                _cached_attn(cfg, "dense", enc_pos, None, causal=False),
                self.capacity_factor)
            return y
        enc_body = self._maybe_remat(enc_body)

        def escan(x, lp):
            return enc_body(lp, x), None
        enc, _ = jax.lax.scan(escan, enc, params["enc_layers"])
        return L.rms_norm(enc, params["enc_final_norm"], cfg.norm_eps)

    def cross_kv(self, params, enc) -> Tuple[jax.Array, jax.Array]:
        """Per-decoder-layer cross K/V from the encoder output:
        ``(nd, B, Se, KV, HD)`` each.  Computed once per request (the
        cross cache never grows) -- both prefill paths and the paged
        engine's admission install consume this."""
        cfg = self.cfg
        b, se = enc.shape[0], enc.shape[1]
        kv, hd = cfg.n_kv_heads, cfg.head_dim

        def one(cp):
            k = (enc @ cp["wk"].astype(enc.dtype)).reshape(b, se, kv, hd)
            v = (enc @ cp["wv"].astype(enc.dtype)).reshape(b, se, kv, hd)
            return k, v
        return jax.vmap(one)(
            jax.tree.map(lambda a: a, params["dec_layers"]["cross"]))

    def _forward_encdec(self, params, batch, dtype):
        cfg = self.cfg
        enc = self._encode(params, batch["enc_embeds"], dtype)
        enc_pos = jnp.arange(enc.shape[1])

        x = L.embed_tokens(params, batch["tokens"], dtype)
        sd = x.shape[1]
        dec_pos = jnp.arange(sd)

        def dec_body(lp, x):
            y, _ = _dec_layer(
                lp, x, cfg,
                _cached_attn(cfg, "dense", dec_pos, None),
                lambda cp, h: self._cross_attn(cp, h, enc, dec_pos, enc_pos))
            return y
        dec_body = self._maybe_remat(dec_body)

        def dscan(x, lp):
            return dec_body(lp, x), None
        x, _ = jax.lax.scan(dscan, x, params["dec_layers"])
        logits = L.lm_logits(params, x, cfg)
        return logits, jnp.zeros((), jnp.float32)

    def _cross_attn(self, cp, x, enc, q_pos, k_pos, kv=None, kv_len=None):
        """Cross attention; ``kv`` overrides (pre-projected cache) and
        ``kv_len`` masks past the valid encoder length (scalar or per-row
        vector -- the paged engine packs slots with different enc lengths
        into one batch).

        Projections go through ``tp_matmul`` so the overlap layer's
        ring/serpentine collectives apply here too (DESIGN.md §5)."""
        cfg = self.cfg
        b, s, d = x.shape
        h, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = L.tp_matmul(x, cp["wq"].astype(x.dtype), "column").reshape(b, s, h, hd)
        if kv is None:
            k, v = L.fused_column_matmul(
                enc, (cp["wk"].astype(x.dtype), cp["wv"].astype(x.dtype)))
            k = k.reshape(b, -1, nkv, hd)
            v = v.reshape(b, -1, nkv, hd)
        else:
            k, v = kv
        out = L.attention_op(q, k.astype(x.dtype), v.astype(x.dtype),
                             q_pos, k_pos, cfg, causal=False, kv_len=kv_len)
        return L.tp_matmul(out.reshape(b, s, h * hd), cp["wo"].astype(x.dtype), "row")

    # --------------------------------------------------------------- loss
    def loss(self, params: PyTree, batch: Dict[str, jax.Array],
             dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch, dtype)
        labels = batch["labels"]
        nll = L.cross_entropy_loss(logits, labels)
        loss = nll + aux
        return loss, {"nll": nll, "aux": aux}

    # -------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   enc_len: int = 0) -> PyTree:
        cfg = self.cfg
        fam = cfg.family
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        window = cfg.sliding_window
        s_kv = min(max_len, window) if window else max_len

        def kvc(nl):
            return {
                "k": jnp.zeros((nl, batch, s_kv, kv, hd), dtype),
                "v": jnp.zeros((nl, batch, s_kv, kv, hd), dtype),
                "len": jnp.zeros((nl,), jnp.int32),
            }

        if fam in ("dense", "vlm", "moe"):
            return {"layers": kvc(cfg.n_layers), "pos": jnp.zeros((), jnp.int32)}
        if fam == "mla_moe":
            m = cfg.mla
            nl, kd = cfg.n_layers, cfg.moe.first_k_dense

            def mlac(n):
                return {
                    "ckv": jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
                    "krope": jnp.zeros((n, batch, max_len, m.rope_head_dim), dtype),
                    "len": jnp.zeros((n,), jnp.int32),
                }
            out = {"layers": mlac(nl - kd), "pos": jnp.zeros((), jnp.int32)}
            if kd:
                out["dense_layers"] = mlac(kd)
            return out
        if fam == "hybrid_ssm":
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            h = d_inner // s.head_dim
            conv_ch = d_inner + 2 * s.state_dim
            n_apps = -(-cfg.n_layers // s.attn_every) if s.attn_every else 0
            out = {
                "mamba": {
                    "conv": jnp.zeros((cfg.n_layers, batch, s.conv_width - 1,
                                       conv_ch), dtype),
                    "ssm": jnp.zeros((cfg.n_layers, batch, h, s.head_dim,
                                      s.state_dim), jnp.float32),
                },
                "pos": jnp.zeros((), jnp.int32),
            }
            if n_apps:
                out["attn"] = {
                    "k": jnp.zeros((n_apps, batch, max_len, kv, hd), dtype),
                    "v": jnp.zeros((n_apps, batch, max_len, kv, hd), dtype),
                    "len": jnp.zeros((n_apps,), jnp.int32),
                }
            return out
        if fam == "xlstm":
            from repro.models.xlstm import _round128
            x = cfg.xlstm
            di = _round128(x.mlstm_proj_factor * cfg.d_model)
            h = cfg.n_heads
            dh = di // h
            dhs = cfg.d_model // h
            n_s = cfg.n_layers // x.slstm_every
            n_m = cfg.n_layers - n_s
            return {
                "mlstm": {
                    "conv": jnp.zeros((n_m, batch, x.conv_width - 1, di), dtype),
                    "C": jnp.zeros((n_m, batch, h, dh, dh), jnp.float32),
                    "n": jnp.zeros((n_m, batch, h, dh), jnp.float32),
                    "m": jnp.full((n_m, batch, h), XL.NEG, jnp.float32),
                },
                "slstm": {
                    "c": jnp.zeros((n_s, batch, h, dhs), jnp.float32),
                    "n": jnp.zeros((n_s, batch, h, dhs), jnp.float32),
                    "h": jnp.zeros((n_s, batch, h, dhs), jnp.float32),
                    "m": jnp.full((n_s, batch, h, dhs), XL.NEG, jnp.float32),
                },
                "pos": jnp.zeros((), jnp.int32),
            }
        if fam == "enc_dec":
            nd = cfg.enc_dec.n_decoder_layers
            return {
                "layers": kvc(nd),
                "cross_k": jnp.zeros((nd, batch, enc_len, kv, hd), dtype),
                "cross_v": jnp.zeros((nd, batch, enc_len, kv, hd), dtype),
                "pos": jnp.zeros((), jnp.int32),
            }
        raise ValueError(fam)

    # ------------------------------------------------------------- decode
    def decode_step(self, params: PyTree, cache: PyTree,
                    batch: Dict[str, jax.Array], dtype=jnp.bfloat16
                    ) -> Tuple[jax.Array, PyTree]:
        """One-token decode against the cache. ``batch["tokens"]``: (B, 1)."""
        cfg = self.cfg
        fam = cfg.family
        pos = cache["pos"]
        q_pos = pos[None] + jnp.arange(1)
        x = self._embed_in(params, batch, dtype)
        x = constrain(x, ("batch", None, "embed"))
        pos3d = batch.get("positions_3d")

        if fam in ("dense", "vlm", "moe", "mla_moe"):
            kind = {"dense": "dense", "vlm": "dense",
                    "moe": "moe", "mla_moe": "mla"}[fam]
            # The cache rides the scan CARRY with per-layer indexed reads и
            # in-place indexed writes: XLA aliases while-loop carries, so
            # the cache is updated in place. Threading it through xs/ys
            # instead re-materializes the full (L, ...) stack every step
            # (measured: 78% of decode HBM traffic on deepseek-coder-33b).
            if fam == "mla_moe" and cfg.moe.first_k_dense:
                def dbody(carry, inp):
                    x, cstack = carry
                    lp, i = inp
                    # Read the loop-INVARIANT input stack (closure), write
                    # the carry: no read-after-write hazard on the carry,
                    # so XLA updates it in place without a per-step copy.
                    c = jax.tree.map(lambda a: a[i], cache["dense_layers"])
                    y, nc, _ = _tf_layer(
                        lp, x, cfg, "dense",
                        _cached_attn(cfg, "mla", q_pos, c),
                        self.capacity_factor)
                    cstack = _cache_update(cstack, nc, i)
                    return (y, cstack), None
                kd = cfg.moe.first_k_dense
                (x, new_dense), _ = jax.lax.scan(
                    dbody, (x, cache["dense_layers"]),
                    (params["dense_layers"], jnp.arange(kd)))

            def body(carry, inp):
                x, cstack = carry
                lp, i = inp
                c = jax.tree.map(lambda a: a[i], cache["layers"])  # invariant read
                y, nc, _aux = _tf_layer(
                    lp, x, cfg, kind,
                    _cached_attn(cfg, kind, q_pos, c, pos3d),
                    self.capacity_factor)
                cstack = _cache_update(cstack, nc, i)
                return (y, cstack), None
            n_scan = jax.tree.leaves(params["layers"])[0].shape[0]
            (x, new_layer_cache), _ = jax.lax.scan(
                body, (x, cache["layers"]),
                (params["layers"], jnp.arange(n_scan)))
            new_cache = dict(cache)
            new_cache["layers"] = new_layer_cache
            if fam == "mla_moe" and cfg.moe.first_k_dense:
                new_cache["dense_layers"] = new_dense
            new_cache["pos"] = pos + 1

        elif fam == "hybrid_ssm":
            caches = {"mamba": _split_cache(cache["mamba"])}
            if "attn" in cache:
                caches["attn"] = _split_cache(cache["attn"])
            x, _aux, ncs = self._hybrid_stack(params, x, q_pos, caches)
            new_cache = {"mamba": _merge_cache(ncs["mamba"]),
                         "pos": pos + 1}
            if "attn" in cache:
                new_cache["attn"] = _merge_cache(ncs["attn"])

        elif fam == "xlstm":
            caches = {"mlstm": _split_cache(cache["mlstm"]),
                      "slstm": _split_cache(cache["slstm"])}
            x, ncs = self._xlstm_stack(params, x, caches)
            new_cache = {"mlstm": _merge_cache(ncs["mlstm"]),
                         "slstm": _merge_cache(ncs["slstm"]),
                         "pos": pos + 1}

        elif fam == "enc_dec":
            enc_pos = jnp.arange(cache["cross_k"].shape[2])

            def body(carry, inp):
                x, cstack = carry
                lp, i = inp
                c = jax.tree.map(lambda a: a[i], cache["layers"])  # invariant read
                ck = cache["cross_k"][i]
                cv = cache["cross_v"][i]
                y, nc = _dec_layer(
                    lp, x, cfg,
                    _cached_attn(cfg, "dense", q_pos, c),
                    lambda cp, h: self._cross_attn(cp, h, None, q_pos,
                                                   enc_pos, kv=(ck, cv)))
                cstack = _cache_update(cstack, nc, i)
                return (y, cstack), None
            nd = cfg.enc_dec.n_decoder_layers
            (x, nlc), _ = jax.lax.scan(
                body, (x, cache["layers"]),
                (params["dec_layers"], jnp.arange(nd)))
            new_cache = dict(cache)
            new_cache["layers"] = nlc
            new_cache["pos"] = pos + 1
        else:
            raise ValueError(fam)

        logits = L.lm_logits(params, x, cfg)
        return logits[:, -1], new_cache

    # ------------------------------------------------------- paged decode
    def decode_step_paged(self, params: PyTree, cache: PyTree,
                          batch: Dict[str, jax.Array], dtype=jnp.bfloat16
                          ) -> Tuple[jax.Array, PyTree]:
        """One-token decode against the paged KV pool, per-slot state.

        ``cache`` is the pooled layout from ``repro.serve.pages``:
        ``pool`` (the shared page-pool KV, one entry per attention layer),
        ``state`` (per-slot recurrent/conv buffers, batch on axis 1),
        ``table`` (the per-slot page table) and ``pos`` -- a per-slot
        position VECTOR, the per-slot replacement of the cohort cache's
        scalar ``pos``: every row carries its own RoPE offset and kv_len
        mask, so slots at different sequence depths decode as one batch.
        Rows are independent (attention/norms/MoE routing are all
        per-row), so empty slots -- ``pos == 0`` with a null table row --
        decode garbage the engine ignores and overwrites at admission.
        """
        cfg = self.cfg
        fam = cfg.family
        pos = cache["pos"]
        table = cache["table"]
        x = self._embed_in(params, batch, dtype)
        x = constrain(x, ("batch", None, "embed"))
        new_cache = dict(cache)

        if fam in ("dense", "moe"):
            def body(carry, inp):
                x, kp, vp = carry
                lp, i = inp
                y, (kp, vp), _ = _tf_layer(
                    lp, x, cfg, fam,
                    _paged_attn(cfg, "dense", pos, table, i, kp, vp),
                    self.capacity_factor)
                return (y, kp, vp), None

            n_scan = jax.tree.leaves(params["layers"])[0].shape[0]
            (x, kp, vp), _ = jax.lax.scan(
                body, (x, cache["pool"]["k"], cache["pool"]["v"]),
                (params["layers"], jnp.arange(n_scan)))
            new_cache["pool"] = {"k": kp, "v": vp}

        elif fam == "hybrid_ssm":
            per = cfg.ssm.attn_every or cfg.n_layers
            kp = vp = None
            if "k" in cache.get("pool", {}):
                kp, vp = cache["pool"]["k"], cache["pool"]["v"]
            mcache = cache["state"]["mamba"]
            new_mamba = []
            app = 0
            for start in range(0, cfg.n_layers, per):
                stop = min(start + per, cfg.n_layers)
                if cfg.ssm.attn_every:
                    x, (kp, vp), _ = _tf_layer(
                        params["shared_attn"], x, cfg, "dense",
                        _paged_attn(cfg, "dense", pos, table, app, kp, vp),
                        self.capacity_factor)
                    app += 1
                lp_slice = jax.tree.map(lambda a: a[start:stop],
                                        params["mamba_layers"])
                c_slice = jax.tree.map(lambda a: a[start:stop], mcache)

                def mscan_c(carry, inp):
                    lp, c = inp
                    y, nc = M2.mamba2_block(lp, carry, cfg, c)
                    return y, nc
                x, ncs = jax.lax.scan(mscan_c, x, (lp_slice, c_slice))
                new_mamba.append(ncs)
            new_cache["state"] = {"mamba": jax.tree.map(
                lambda *xs: jnp.concatenate(xs, 0), *new_mamba)}
            if kp is not None:
                new_cache["pool"] = {"k": kp, "v": vp}

        elif fam == "mla_moe":
            # The latent cache IS the paged pool: one "lat" buffer holds
            # concat(ckv, k_rope) rows for every MLA layer (dense layers
            # at pool indices [0, kd), MoE layers at kd + i).
            lat = cache["pool"]["lat"]
            kd = cfg.moe.first_k_dense
            if kd:
                def dbody(carry, inp):
                    x, lat = carry
                    lp, i = inp
                    y, (lat,), _ = _tf_layer(
                        lp, x, cfg, "dense",
                        _paged_attn(cfg, "mla", pos, table, i, lat),
                        self.capacity_factor)
                    return (y, lat), None
                (x, lat), _ = jax.lax.scan(
                    dbody, (x, lat),
                    (params["dense_layers"], jnp.arange(kd)))

            def body(carry, inp):
                x, lat = carry
                lp, i = inp
                y, (lat,), _ = _tf_layer(
                    lp, x, cfg, "mla",
                    _paged_attn(cfg, "mla", pos, table, kd + i, lat),
                    self.capacity_factor)
                return (y, lat), None
            n_scan = jax.tree.leaves(params["layers"])[0].shape[0]
            (x, lat), _ = jax.lax.scan(
                body, (x, lat), (params["layers"], jnp.arange(n_scan)))
            new_cache["pool"] = {"lat": lat}

        elif fam == "enc_dec":
            # Decoder self-attn KV lives in the pool; cross K/V is
            # per-slot STATE (it never grows -- one encoder pass per
            # request), masked per-row to each slot's encoder length.
            ck_all = cache["state"]["cross_k"]      # (nd, S, enc_max, kv, hd)
            cv_all = cache["state"]["cross_v"]
            enc_len = cache["state"]["enc_len"]     # (S,) int32
            enc_pos = jnp.arange(ck_all.shape[2])

            def body(carry, inp):
                x, kp, vp = carry
                lp, i = inp
                ck = ck_all[i]                      # invariant read
                cv = cv_all[i]
                y, (kp, vp) = _dec_layer(
                    lp, x, cfg,
                    _paged_attn(cfg, "dense", pos, table, i, kp, vp),
                    lambda cp, h: self._cross_attn(
                        cp, h, None, pos[:, None], enc_pos,
                        kv=(ck, cv), kv_len=enc_len))
                return (y, kp, vp), None
            nd = cfg.enc_dec.n_decoder_layers
            (x, kp, vp), _ = jax.lax.scan(
                body, (x, cache["pool"]["k"], cache["pool"]["v"]),
                (params["dec_layers"], jnp.arange(nd)))
            new_cache["pool"] = {"k": kp, "v": vp}

        elif fam == "xlstm":
            # Pure-recurrent: no paged KV at all -- the per-slot state is
            # the whole cache, and positions only gate the engine's
            # bookkeeping (the recurrence itself is position-free).
            caches = {"mlstm": cache["state"]["mlstm"],
                      "slstm": cache["state"]["slstm"]}
            x, ncs = self._xlstm_stack(params, x, caches)
            new_cache["state"] = {"mlstm": ncs["mlstm"],
                                  "slstm": ncs["slstm"]}
        else:
            raise NotImplementedError(
                f"paged decode is not implemented for family {fam!r}")

        new_cache["pos"] = pos + 1
        logits = L.lm_logits(params, x, cfg)
        return logits[:, -1], new_cache

    # ----------------------------------------------------- chunked prefill
    def prefill_chunk(self, params: PyTree, cache: PyTree,
                      batch: Dict[str, jax.Array], dtype=jnp.bfloat16
                      ) -> Tuple[jax.Array, PyTree]:
        """One prompt CHUNK of one slot against the paged pool.

        ``batch``: ``tokens`` (1, C) (or ``embeds``), ``pos0`` scalar --
        the chunk's first absolute position -- and ``slot`` scalar.
        Chunks are EXACT length (the engine cuts the prompt into
        ``plan.page_plan()``-sized pieces; the partial final chunk is its
        own jit bucket), so no pad token ever enters a recurrent state.
        KV/latent rows are written straight into pool pages through the
        slot's table row -- the pages ARE the prefill destination, there
        is no post-prefill copy -- and per-slot recurrent state is
        sliced/scattered on the slot axis so chunks compose: chunk i+1
        starts from the state chunk i left.  Returns the chunk's
        last-token logits (only meaningful on the final chunk) and the
        updated cache."""
        cfg = self.cfg
        fam = cfg.family
        slot = batch["slot"]
        x = self._embed_in(params, batch, dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        c = x.shape[1]
        positions = batch["pos0"] + jnp.arange(c)
        table_row = cache["table"][slot]
        new_cache = dict(cache)

        def sl(a):
            return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1)

        def upd(full, u):
            return jax.lax.dynamic_update_slice_in_dim(
                full, u.astype(full.dtype), slot, axis=1)

        if fam in ("dense", "moe"):
            def body(carry, inp):
                x, kp, vp = carry
                lp, i = inp
                y, (kp, vp), _ = _tf_layer(
                    lp, x, cfg, fam,
                    _chunk_attn(cfg, "dense", positions, table_row, i,
                                kp, vp),
                    None)     # dropless: chunk-invariant MoE
                return (y, kp, vp), None
            n_scan = jax.tree.leaves(params["layers"])[0].shape[0]
            (x, kp, vp), _ = jax.lax.scan(
                body, (x, cache["pool"]["k"], cache["pool"]["v"]),
                (params["layers"], jnp.arange(n_scan)))
            new_cache["pool"] = {"k": kp, "v": vp}

        elif fam == "mla_moe":
            lat = cache["pool"]["lat"]
            kd = cfg.moe.first_k_dense
            if kd:
                def dbody(carry, inp):
                    x, lat = carry
                    lp, i = inp
                    y, (lat,), _ = _tf_layer(
                        lp, x, cfg, "dense",
                        _chunk_attn(cfg, "mla", positions, table_row, i, lat),
                        None)     # dropless: chunk-invariant MoE
                    return (y, lat), None
                (x, lat), _ = jax.lax.scan(
                    dbody, (x, lat),
                    (params["dense_layers"], jnp.arange(kd)))

            def body(carry, inp):
                x, lat = carry
                lp, i = inp
                y, (lat,), _ = _tf_layer(
                    lp, x, cfg, "mla",
                    _chunk_attn(cfg, "mla", positions, table_row, kd + i, lat),
                    None)     # dropless: chunk-invariant MoE
                return (y, lat), None
            n_scan = jax.tree.leaves(params["layers"])[0].shape[0]
            (x, lat), _ = jax.lax.scan(
                body, (x, lat), (params["layers"], jnp.arange(n_scan)))
            new_cache["pool"] = {"lat": lat}

        elif fam == "hybrid_ssm":
            per = cfg.ssm.attn_every or cfg.n_layers
            kp = vp = None
            if "k" in cache.get("pool", {}):
                kp, vp = cache["pool"]["k"], cache["pool"]["v"]
            mcache = cache["state"]["mamba"]
            m_slice = jax.tree.map(sl, mcache)
            new_mamba = []
            app = 0
            for start in range(0, cfg.n_layers, per):
                stop = min(start + per, cfg.n_layers)
                if cfg.ssm.attn_every:
                    x, (kp, vp), _ = _tf_layer(
                        params["shared_attn"], x, cfg, "dense",
                        _chunk_attn(cfg, "dense", positions, table_row, app,
                                    kp, vp),
                        None)     # dropless: chunk-invariant MoE
                    app += 1
                lp_slice = jax.tree.map(lambda a: a[start:stop],
                                        params["mamba_layers"])
                c_slice = jax.tree.map(lambda a: a[start:stop], m_slice)

                def mscan_c(carry, inp):
                    lp, cc = inp
                    y, nc = M2.mamba2_block(lp, carry, cfg, cc)
                    return y, nc
                x, ncs = jax.lax.scan(mscan_c, x, (lp_slice, c_slice))
                new_mamba.append(ncs)
            nm = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba)
            new_cache["state"] = {"mamba": jax.tree.map(upd, mcache, nm)}
            if kp is not None:
                new_cache["pool"] = {"k": kp, "v": vp}

        elif fam == "xlstm":
            caches = {"mlstm": jax.tree.map(sl, cache["state"]["mlstm"]),
                      "slstm": jax.tree.map(sl, cache["state"]["slstm"])}
            x, ncs = self._xlstm_stack(params, x, caches)
            new_cache["state"] = {
                "mlstm": jax.tree.map(upd, cache["state"]["mlstm"],
                                      ncs["mlstm"]),
                "slstm": jax.tree.map(upd, cache["state"]["slstm"],
                                      ncs["slstm"]),
            }

        elif fam == "enc_dec":
            ck_all = cache["state"]["cross_k"]      # (nd, S, enc_max, kv, hd)
            cv_all = cache["state"]["cross_v"]
            enc_len = cache["state"]["enc_len"][slot]
            enc_pos = jnp.arange(ck_all.shape[2])

            def body(carry, inp):
                x, kp, vp = carry
                lp, i = inp
                ck = jax.lax.dynamic_slice_in_dim(ck_all[i], slot, 1, axis=0)
                cv = jax.lax.dynamic_slice_in_dim(cv_all[i], slot, 1, axis=0)
                y, (kp, vp) = _dec_layer(
                    lp, x, cfg,
                    _chunk_attn(cfg, "dense", positions, table_row, i,
                                kp, vp),
                    lambda cp, h: self._cross_attn(
                        cp, h, None, positions, enc_pos,
                        kv=(ck, cv), kv_len=enc_len))
                return (y, kp, vp), None
            nd = cfg.enc_dec.n_decoder_layers
            (x, kp, vp), _ = jax.lax.scan(
                body, (x, cache["pool"]["k"], cache["pool"]["v"]),
                (params["dec_layers"], jnp.arange(nd)))
            new_cache["pool"] = {"k": kp, "v": vp}
        else:
            raise NotImplementedError(
                f"chunked prefill is not implemented for family {fam!r}")

        logits = L.lm_logits(params, x[:, -1:], cfg)
        return logits[:, -1], new_cache

    def encode_cross(self, params: PyTree, batch: Dict[str, jax.Array],
                     dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
        """Encoder pass + per-decoder-layer cross K/V -- the paged
        engine's admission-time install for enc-dec requests."""
        enc = self._encode(params, batch["enc_embeds"], dtype)
        return self.cross_kv(params, enc)

    # ------------------------------------------------------------ prefill
    def prefill(self, params: PyTree, batch: Dict[str, jax.Array],
                max_len: int, dtype=jnp.bfloat16) -> Tuple[jax.Array, PyTree]:
        """Process a full prompt, returning (last-token logits, filled cache).

        For the dry-run ``prefill`` shapes we lower this function; it is the
        serving-side counterpart of the training forward.
        """
        cfg = self.cfg
        fam = cfg.family
        if fam == "enc_dec":
            return self._prefill_encdec(params, batch, max_len, dtype)
        b = (batch["tokens"].shape[0] if "tokens" in batch
             else batch["embeds"].shape[0])
        s = (batch["tokens"].shape[1] if "tokens" in batch
             else batch["embeds"].shape[1])
        cache = self.init_cache(b, max_len, dtype)
        x = self._embed_in(params, batch, dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        q_pos = jnp.arange(s)
        pos3d = batch.get("positions_3d")

        if fam in ("dense", "vlm", "moe", "mla_moe"):
            kind = {"dense": "dense", "vlm": "dense",
                    "moe": "moe", "mla_moe": "mla"}[fam]
            if fam == "mla_moe" and cfg.moe.first_k_dense:
                def dbody(carry, inp):
                    lp, c = inp
                    y, nc, _ = _tf_layer(
                        lp, carry, cfg, "dense",
                        _cached_attn(cfg, "mla", q_pos, c),
                        None)       # serving is dropless (see moe_ffn)
                    return y, nc
                x, ndc = jax.lax.scan(
                    dbody, x, (params["dense_layers"],
                               _split_cache(cache["dense_layers"])))
                cache["dense_layers"] = _merge_cache(ndc)

            def body(carry, inp):
                lp, c = inp
                y, nc, _ = _tf_layer(
                    lp, carry, cfg, kind,
                    _cached_attn(cfg, kind, q_pos, c, pos3d),
                    None)           # serving is dropless (see moe_ffn)
                return y, nc
            body = self._maybe_remat(body) if s > 1 else body
            x, nlc = jax.lax.scan(
                body, x, (params["layers"], _split_cache(cache["layers"])))
            cache["layers"] = _merge_cache(nlc)
            cache["pos"] = jnp.asarray(s, jnp.int32)
        elif fam == "hybrid_ssm":
            caches = {"mamba": _split_cache(cache["mamba"])}
            if "attn" in cache:
                caches["attn"] = _split_cache(cache["attn"])
            x, _aux, ncs = self._hybrid_stack(params, x, q_pos, caches)
            cache["mamba"] = _merge_cache(ncs["mamba"])
            if "attn" in cache:
                cache["attn"] = _merge_cache(ncs["attn"])
            cache["pos"] = jnp.asarray(s, jnp.int32)
        elif fam == "xlstm":
            caches = {"mlstm": _split_cache(cache["mlstm"]),
                      "slstm": _split_cache(cache["slstm"])}
            x, ncs = self._xlstm_stack(params, x, caches)
            cache["mlstm"] = _merge_cache(ncs["mlstm"])
            cache["slstm"] = _merge_cache(ncs["slstm"])
            cache["pos"] = jnp.asarray(s, jnp.int32)
        else:
            raise ValueError(fam)

        logits = L.lm_logits(params, x[:, -1:], cfg)
        return logits[:, -1], cache

    def _prefill_encdec(self, params, batch, max_len, dtype):
        cfg = self.cfg
        enc = self._encode(params, batch["enc_embeds"], dtype)
        b, se = enc.shape[0], enc.shape[1]
        enc_pos = jnp.arange(se)

        cache = self.init_cache(b, max_len, dtype, enc_len=se)

        # Precompute per-layer cross K/V from the encoder output.
        ck, cv = self.cross_kv(params, enc)
        cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cv.astype(cache["cross_v"].dtype)

        # Run the decoder over the BOS prompt tokens.
        tokens = batch["tokens"]
        sd = tokens.shape[1]
        x = L.embed_tokens(params, tokens, dtype)
        dec_pos = jnp.arange(sd)

        def body(carry, inp):
            lp, c, k_, v_ = inp
            y, nc = _dec_layer(
                lp, carry, cfg,
                _cached_attn(cfg, "dense", dec_pos, c),
                lambda cp, h: self._cross_attn(cp, h, None, dec_pos,
                                               enc_pos, kv=(k_, v_)))
            return y, nc
        body = self._maybe_remat(body) if sd > 1 else body
        x, nlc = jax.lax.scan(
            body, x, (params["dec_layers"], _split_cache(cache["layers"]),
                      cache["cross_k"], cache["cross_v"]))
        cache["layers"] = _merge_cache(nlc)
        cache["pos"] = jnp.asarray(sd, jnp.int32)
        logits = L.lm_logits(params, x[:, -1:], cfg)
        return logits[:, -1], cache


def _split_cache(c: dict) -> dict:
    """Stacked per-layer cache -> scan-compatible (leading dim consumed)."""
    return c


def _merge_cache(c: dict) -> dict:
    return c


def _cache_update(cstack: dict, new_layer_cache: dict, i) -> dict:
    """In-place indexed write of one layer's cache into the stacked carry."""
    return jax.tree.map(
        lambda stack, upd: jax.lax.dynamic_update_index_in_dim(
            stack, upd.astype(stack.dtype), i, axis=0),
        cstack, new_layer_cache)


def build_model(cfg: ModelConfig, remat: str = "full") -> Model:
    return Model(cfg=cfg, remat=remat)
