"""Mamba2 / SSD mixer (arXiv:2405.21060) with cache-conscious chunking.

The SSD duality computes the selective-SSM with a *chunked* algorithm:
quadratic attention-like work inside chunks of length ``Q`` plus a linear
state recurrence across chunks. ``Q`` is exactly the paper's partition-size
knob: the per-chunk working set (Q x Q score tile + Q x P inputs + P x N
state) must fit the target cache level, and the runtime picks it via the
decomposer (see ``choose_chunk``). A sequential step form (``ssd_step``)
serves decode and doubles as the test oracle.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# Chunk selection (the paper's decomposition applied to the SSD time axis)
# ---------------------------------------------------------------------------

def ssd_workset_bytes(chunk: int, n_heads: int, head_dim: int,
                      state_dim: int, dtype_bytes: int = 2) -> int:
    """One SSD chunk step's VMEM working set (the phi_tpu accounting:
    double-buffered inputs + f32 score tile + running state) -- the filter
    both the analytic chunk choice and the tuning sweep apply."""
    return (
        chunk * chunk * 4                         # score tile (f32)
        + 2 * chunk * head_dim * dtype_bytes * 2  # x, dt-scaled x
        + 2 * chunk * state_dim * dtype_bytes * 2  # B, C rows
        + head_dim * state_dim * 4                # running state
    ) * n_heads


def choose_chunk(seq_len: int, n_heads: int, head_dim: int, state_dim: int,
                 dtype_bytes: int = 2, spec=None, use_tuned: bool = True) -> int:
    """Pick the largest power-of-two chunk whose SSD working set fits the
    VMEM budget; with ``use_tuned`` a measured sweep winner from
    ``experiments/tuning.json`` overrides it (precedence analytic < tuned)
    after re-passing the same working-set filter."""
    from repro.hw import chip_spec

    spec = spec or chip_spec()
    budget = spec.usable_vmem // 2
    q = 64
    while q * 2 <= min(seq_len, 1024):
        nxt = q * 2
        if ssd_workset_bytes(nxt, n_heads, head_dim, state_dim,
                             dtype_bytes) > budget:
            break
        q = nxt
    if use_tuned:
        from repro.tune.cache import bucket_ssd, lookup_tuned

        entry = lookup_tuned(
            "ssd_scan", spec.name,
            bucket_ssd(seq_len, n_heads, head_dim, state_dim, dtype_bytes))
        if entry is not None:
            c = entry.get("block", {}).get("chunk")
            cap = -(-min(max(seq_len, 64), 1024) // 8) * 8
            if (isinstance(c, int) and c >= 8 and c % 8 == 0 and c <= cap
                    and ssd_workset_bytes(c, n_heads, head_dim, state_dim,
                                          dtype_bytes) <= budget):
                return c
    return q


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def mamba2_param_specs(cfg: ModelConfig, layers: int = 0) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    h = d_inner // s.head_dim
    n = s.state_dim
    conv_ch = d_inner + 2 * n                     # x, B, C convolved (G=1)
    ls = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "wz": ParamSpec(ls + (d, d_inner), la + ("embed", "mlp")),
        "wx": ParamSpec(ls + (d, d_inner), la + ("embed", "mlp")),
        "wB": ParamSpec(ls + (d, n), la + ("embed", None)),
        "wC": ParamSpec(ls + (d, n), la + ("embed", None)),
        "wdt": ParamSpec(ls + (d, h), la + ("embed", "heads")),
        "dt_bias": ParamSpec(ls + (h,), la + ("heads",), init="zeros"),
        "A_log": ParamSpec(ls + (h,), la + ("heads",), init="ones"),
        "D": ParamSpec(ls + (h,), la + ("heads",), init="ones"),
        "conv_w": ParamSpec(ls + (s.conv_width, conv_ch), la + (None, "mlp")),
        "conv_b": ParamSpec(ls + (conv_ch,), la + ("mlp",), init="zeros"),
        "norm": ParamSpec(ls + (d_inner,), la + ("mlp",), init="ones"),
        "out": ParamSpec(ls + (d_inner, d), la + ("mlp", "embed"),
                         scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers))),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """x: (B, S, C); w: (W, C) depthwise; state: (B, W-1, C) trailing inputs."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+W-1, C)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i: i + x.shape[1]] * w[i].astype(x.dtype)
    out = out + b.astype(x.dtype)
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(out), new_state


# ---------------------------------------------------------------------------
# SSD: chunked scan (train/prefill) + sequential step (decode / oracle)
# ---------------------------------------------------------------------------

def _segsum(dA: jax.Array) -> jax.Array:
    """Lower-triangular pairwise sums: out[..., i, j] = sum_{j<r<=i} dA_r."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # (..., i, j)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,       # (B, S, H, P)
    dt: jax.Array,      # (B, S, H)  (post-softplus)
    A: jax.Array,       # (H,)       (negative)
    Bm: jax.Array,      # (B, S, N)
    Cm: jax.Array,      # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = Bm.reshape(b, nc, q, n)
    Cc = Cm.reshape(b, nc, q, n)

    dA = dtc * A                                       # (B,nc,Q,H) log-decay
    dA = jnp.moveaxis(dA, -1, 2)                       # (B,nc,H,Q)
    cum = jnp.cumsum(dA, axis=-1)                      # (B,nc,H,Q)

    # Intra-chunk (attention-like) term.
    L = jnp.exp(_segsum(dA))                           # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)     # (B,nc,Q,Q)
    w = scores[:, :, None] * L                         # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]                          # x * dt (B,nc,Q,H,P)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", w.astype(x.dtype), xdt)

    # Chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j (x_j)^T.
    decay_out = jnp.exp(cum[..., -1:] - cum)           # (B,nc,H,Q)
    sdt = (decay_out * jnp.moveaxis(dtc, 2, 3)).astype(x.dtype)  # (B,nc,H,Q)
    states = jnp.einsum("bchq,bcqn,bcqhp->bchpn", sdt, Bc, xc)

    # Cross-chunk recurrence.
    chunk_decay = jnp.exp(cum[..., -1])                # (B,nc,H)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(prev, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        out_prev = prev
        new = prev * dec[..., None, None] + st.astype(jnp.float32)
        return new, out_prev

    chunk_states = jnp.moveaxis(states, 1, 0)          # (nc,B,H,P,N)
    chunk_decays = jnp.moveaxis(chunk_decay, 1, 0)     # (nc,B,H)
    final, prevs = jax.lax.scan(step, s0, (chunk_states, chunk_decays))
    prevs = jnp.moveaxis(prevs, 0, 1)                  # (B,nc,H,P,N)

    # Inter-chunk contribution: y_off_i = exp(cum_i) C_i . S_prev.
    decay_in = jnp.exp(cum)                            # (B,nc,H,Q)
    y_off = jnp.einsum(
        "bcqn,bchpn,bchq->bcqhp", Cc, prevs.astype(x.dtype),
        decay_in.astype(x.dtype),
    )

    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :s]
    return y, final


def ssd_step(
    x: jax.Array,       # (B, H, P) one token
    dt: jax.Array,      # (B, H)
    A: jax.Array,       # (H,)
    Bm: jax.Array,      # (B, N)
    Cm: jax.Array,      # (B, N)
    state: jax.Array,   # (B, H, P, N) f32
) -> Tuple[jax.Array, jax.Array]:
    dec = jnp.exp(dt * A)                              # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    new_state = state * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full mixer block
# ---------------------------------------------------------------------------

def mamba2_block(
    params: dict,
    hidden: jax.Array,                # (B, S, d)
    cfg: ModelConfig,
    cache: Optional[dict] = None,     # {"conv": (B,W-1,C), "ssm": (B,H,P,N)}
    chunk: Optional[int] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    s_cfg = cfg.ssm
    b, s, d = hidden.shape
    d_inner = s_cfg.expand * d
    h = d_inner // s_cfg.head_dim
    p = s_cfg.head_dim
    n = s_cfg.state_dim

    z = hidden @ params["wz"].astype(hidden.dtype)
    xin = hidden @ params["wx"].astype(hidden.dtype)
    Bm = hidden @ params["wB"].astype(hidden.dtype)
    Cm = hidden @ params["wC"].astype(hidden.dtype)
    dt_raw = hidden @ params["wdt"].astype(hidden.dtype)

    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                  conv_state)
    xin, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xin.reshape(b, s, h, p)
    new_cache = None
    if cache is not None and s == 1:
        y, new_state = ssd_step(
            xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], cache["ssm"]
        )
        y = y[:, None]                                  # (B,1,H,P)
        new_cache = {"conv": new_conv, "ssm": new_state}
    else:
        q = chunk or s_cfg.chunk
        init = cache["ssm"] if cache is not None else None
        y, final = ssd_chunked(xh, dt.astype(xh.dtype), A.astype(jnp.float32),
                               Bm, Cm, q, init)
        if cache is not None:
            new_cache = {"conv": new_conv, "ssm": final}

    y = y + xh * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out"].astype(y.dtype)
    return out, new_cache
