from repro.models.model import Model, build_model
from repro.models.params import (
    ParamSpec,
    abstract_params,
    count_params,
    init_params,
    param_axes,
)

__all__ = [k for k in dir() if not k.startswith("_")]
