"""Declarative parameter specs with logical sharding axes.

Every parameter is declared as a ``ParamSpec(shape, axes, init)`` where
``axes`` names one *logical* axis per dimension ("embed", "mlp", "heads",
"vocab", "experts", "layers", ...). ``repro.dist.sharding`` maps logical
axes to mesh axes through a rules table, so the same model definition lowers
to any mesh -- the two-stage decomposition the paper advocates (cluster/mesh
level vs node/chip level) stays cleanly decoupled.

Specs live in nested dicts; leaves with a leading "layers" axis are stacked
for ``lax.scan`` over homogeneous layer blocks.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float = 1.0            # multiplier on the fan-in init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn: Callable[[str, ParamSpec], Any], specs: PyTree, prefix: str = "") -> PyTree:
    """Map over a nested dict of ParamSpecs, passing the dotted path."""
    if _is_spec(specs):
        return fn(prefix, specs)
    return {
        k: spec_tree_map(fn, v, f"{prefix}.{k}" if prefix else k)
        for k, v in specs.items()
    }


def init_params(specs: PyTree, rng: jax.Array, dtype=jnp.float32) -> PyTree:
    """Initialize real arrays from specs (used by smoke tests / examples)."""

    def one(path: str, spec: ParamSpec):
        key = jax.random.fold_in(rng, hash(path) & 0x7FFFFFFF)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "embed":
            return (jax.random.normal(key, spec.shape, dtype) * 0.02 * spec.scale)
        # fan-in scaled normal; ignore leading stack axes ("layers", "experts")
        fan_dims = [s for s, a in zip(spec.shape, spec.axes)
                    if a not in ("layers", "experts")]
        fan_in = fan_dims[0] if fan_dims else spec.shape[0]
        std = spec.scale / math.sqrt(max(1, fan_in))
        return jax.random.normal(key, spec.shape, dtype) * std

    return spec_tree_map(one, specs)


def abstract_params(specs: PyTree, dtype=jnp.float32) -> PyTree:
    """ShapeDtypeStruct pytree (for .lower() without allocation)."""
    return spec_tree_map(
        lambda _, s: jax.ShapeDtypeStruct(s.shape, dtype), specs
    )


def param_axes(specs: PyTree) -> PyTree:
    """Pytree of logical-axis tuples, matching the params pytree."""
    return spec_tree_map(lambda _, s: s.axes, specs)


def count_params(specs: PyTree) -> int:
    total = 0

    def one(_, s: ParamSpec):
        nonlocal total
        total += int(np.prod(s.shape))
        return None

    spec_tree_map(one, specs)
    return total
