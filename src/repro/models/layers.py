"""Core transformer layers: norms, rotary embeddings (RoPE / M-RoPE),
GQA attention (full, sliding-window, and cache-conscious blockwise), SwiGLU
FFN, embeddings and the cross-entropy loss.

All functions are pure; parameters arrive as pytrees produced from
``repro.models.params`` specs. The blockwise attention path sizes its
blocks with the paper's decomposer (``core.autotile.plan_attention``) so
long-context attention streams VMEM-sized KV partitions -- the TPU
realization of the paper's partition streams (Fig. 2).

Every tensor-parallel projection (attention q/k/v/o, the SwiGLU FFN, the
LM head) goes through ``tp_matmul``, which routes to the overlap layer's
ring/serpentine collective matmuls when the active sharding rules request
them (DESIGN.md §5) and stays a plain einsum otherwise.  Projections that
share an input -- q/k/v, the SwiGLU wg/wi pair -- go through
``fused_column_matmul`` so ``x`` streams around the ring once per block,
not once per projection.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec

# ---------------------------------------------------------------------------
# Tensor-parallel projection dispatch (DESIGN.md §5)
# ---------------------------------------------------------------------------


def tp_matmul(x: jax.Array, w: jax.Array, parallel: str) -> jax.Array:
    """Projection ``y = x @ w`` over the last dim, overlap-aware.

    When the active sharding rules request ring/serpentine collectives
    (``dist.sharding.with_collectives``), the matmul is routed through
    ``dist.overlap``'s streaming kernels so the interconnect transfer of
    the next mesh partition overlaps the current block's compute; under
    GSPMD rules, outside any ``use_mesh_rules`` context, or when the shapes
    do not divide the ring, it is a plain einsum.  ``parallel`` is the
    weight's TP orientation: "column" (n sharded -> all-gather ring) or
    "row" (k sharded -> reduce-scatter ring).
    """
    from repro.dist.overlap import overlap_matmul

    y = overlap_matmul(x, w, parallel)
    if y is None:
        y = jnp.einsum("...k,kn->...n", x, w)
    return y


def fused_column_matmul(x: jax.Array, ws) -> list:
    """Several column-parallel projections of the same ``x``, one ring.

    Under ring/serpentine rules the q/k/v (and SwiGLU wg/wi) projections
    each streamed ``x`` around the ICI ring independently; fusing them into
    ``dist.overlap.make_ag_matmul_fused`` hops the k-chunk ONCE per ring
    step and runs one dot per weight per hop, so ``x`` streams through the
    ring once per block instead of once per projection (ROADMAP overlap
    item).  Bitwise-identical to the per-weight rings (same per-column
    accumulation order); falls back to per-weight ``tp_matmul`` under
    GSPMD rules or non-dividing shapes.
    """
    from repro.dist.overlap import overlap_matmul_fused

    ys = overlap_matmul_fused(x, tuple(ws))
    if ys is None:
        return [tp_matmul(x, w, "column") for w in ws]
    return ys


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                             # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,           # (3, B, S): temporal / height / width
    theta: float = 1e6,
    sections: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the head_dim/2 frequency slots are split
    into (t, h, w) sections, each rotated by its own position stream."""
    d = x.shape[-1]
    if sections is None:
        # Qwen2-VL proportions (16, 24, 24) of d/2 = 64, scaled to head_dim.
        t = d // 8
        h = (d // 2 - t) // 2
        sections = (t, h, d // 2 - t - h)
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                              # (D/2,)
    # Select which position stream drives each frequency slot.
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=d // 2
    )                                                         # (D/2,)
    # positions: (3, B, S) -> (B, S, D/2) by picking stream per slot.
    pos = jnp.take(positions, sec_ids, axis=0)                # (D/2, B, S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)        # (B, S, D/2)
    angles = pos * freqs                                      # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, KV*n_rep, D) by broadcast (GQA)."""
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d))
    return k.reshape(b, s, kv * n_rep, d)


def _causal_window_mask(
    q_pos: jax.Array, k_pos: jax.Array, window: int = 0
) -> jax.Array:
    """True where attention is allowed. q_pos: (Sq,), k_pos: (Sk,)."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def attn_mask(
    q_pos: jax.Array,                  # (Sq,) or (B, Sq)
    k_pos: jax.Array,                  # (Sk,) or (B, Sk)
    causal: bool = True,
    window: int = 0,
    kv_len: Optional[jax.Array] = None,   # scalar or (B,)
) -> jax.Array:
    """Per-row attention mask, shaped ``(B | 1, 1, Sq, Sk)``.

    Positions and the valid cache length may carry a leading batch dim --
    the per-slot decode path gives every sequence its own absolute
    position and ``kv_len`` -- or stay 1-D/scalar (the shared-position
    batches of training and cohort decode).  Negative ``k_pos`` marks
    empty ring-cache slots and always masks.
    """
    qp = jnp.asarray(q_pos)[..., :, None]          # (..., Sq, 1)
    kp = jnp.asarray(k_pos)[..., None, :]          # (..., 1, Sk)
    m = kp >= 0
    if causal or window:
        m = m & (kp <= qp)
        if window:
            m = m & (kp > qp - window)
    else:
        m = m & jnp.ones_like(qp, bool)            # broadcast to (.., Sq, Sk)
    if kv_len is not None:
        m = m & (kp < jnp.asarray(kv_len)[..., None, None])
    while m.ndim < 3:
        m = m[None]
    return m[:, None]                              # head axis


def full_attention(
    q: jax.Array,                  # (B, Sq, H, D)
    k: jax.Array,                  # (B, Sk, H, D)  (already GQA-repeated)
    v: jax.Array,                  # (B, Sk, H, D)
    q_pos: jax.Array,              # (Sq,) or (B, Sq) absolute positions
    k_pos: jax.Array,              # (Sk,) or (B, Sk)
    causal: bool = True,
    window: int = 0,
    kv_len: Optional[jax.Array] = None,   # valid cache length: scalar or (B,)
) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    mask = attn_mask(q_pos, k_pos, causal=causal, window=window,
                     kv_len=kv_len)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blockwise_attention(
    q: jax.Array,                  # (B, Sq, H, D)
    k: jax.Array,                  # (B, Sk, H, D)
    v: jax.Array,                  # (B, Sk, H, D)
    q_pos: jax.Array,
    k_pos: jax.Array,
    block_q: int,
    block_kv: int,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Pure-JAX flash attention: stream KV in decomposer-sized blocks with a
    running (max, sum, acc) softmax. Never materializes (Sq, Sk) logits --
    one (block_q, block_kv) tile at a time, the paper's partition stream.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)

    nq = -(-sq // block_q)
    nk = -(-sk // block_kv)
    pq = nq * block_q - sq
    pk = nk * block_kv - sk

    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, pq), constant_values=-1)
    kpos = jnp.pad(k_pos, (0, pk), constant_values=2**30)

    kb = kp.reshape(b, nk, block_kv, h, d)
    vb = vp.reshape(b, nk, block_kv, h, d)
    kposb = kpos.reshape(nk, block_kv)

    def q_block(args):
        qi, qpos_i = args                      # (B, bq, H, D), (bq,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, kpos_j = inp
            logits = jnp.einsum("bqhd,bkhd->bhqk", qi, kj).astype(jnp.float32)
            logits *= scale
            mask = _causal_window_mask(qpos_i, kpos_j, window) if (causal or window) \
                else jnp.ones((block_q, block_kv), bool)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qi.dtype), vj
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kposb),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2).astype(qi.dtype)   # (B, bq, H, D)

    qb = qp.reshape(b, nq, block_q, h, d)
    qposb = qpos.reshape(nq, block_q)
    outs = jax.lax.map(q_block, (jnp.moveaxis(qb, 1, 0), qposb))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * block_q, h, d)
    return out[:, :sq]


def grouped_attention(
    q: jax.Array,                  # (B, Sq, H, D)
    k: jax.Array,                  # (B, Sk, KV, D)  -- NOT repeated
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: int = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """GQA attention without materializing the head-repeated K/V: the query
    heads are grouped per KV head and contracted directly against the
    (possibly sequence-sharded) cache. Numerically identical to
    repeat_kv + full_attention; avoids the (B, Sk, H, D) broadcast (15 GB
    per layer for deepseek-coder decode_32k) and the cache reshard."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kvh, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits *= scale
    mask = attn_mask(q_pos, k_pos, causal=causal, window=window,
                     kv_len=kv_len)                 # (B|1, 1, Sq, Sk)
    logits = jnp.where(mask[:, :, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)


def attention_op(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    cfg: ModelConfig,
    causal: bool = True,
    kv_len: Optional[jax.Array] = None,
    blockwise_threshold: Optional[int] = None,
    tile_plan=None,
) -> jax.Array:
    """Dispatch: short sequences -> full attention; long -> blockwise with
    decomposer-chosen blocks (``tile_plan`` overrides)."""
    from repro.dist.sharding import active_rule, constrain

    if blockwise_threshold is None:
        blockwise_threshold = getattr(cfg, "attn_blockwise_threshold", 8192)
    if q.shape[1] == 1 and active_rule("kv_seq") is not None:
        # Sequence-sharded decode: grouped GQA against the sharded cache.
        k = constrain(k, ("batch", "kv_seq", None, None))
        v = constrain(v, ("batch", "kv_seq", None, None))
        return grouped_attention(q, k, v, q_pos, k_pos, causal=causal,
                                 window=cfg.sliding_window, kv_len=kv_len)
    n_rep = q.shape[2] // k.shape[2]
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    # Pin the GQA-repeated K/V to the head sharding of Q: without this,
    # GSPMD's propagation through the broadcast-reshape can leave the
    # contraction partially sharded and all-reduce full (B,H,Sq,Sk) logits
    # (observed: 541 GB/chip/step on qwen2-0.5b train_4k).
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    sk = k.shape[1]
    if kv_len is not None or sk <= blockwise_threshold or q.shape[1] == 1:
        return full_attention(
            q, k, v, q_pos, k_pos, causal=causal,
            window=cfg.sliding_window, kv_len=kv_len,
        )
    if tile_plan is None:
        from repro.core.autotile import plan_attention
        tile_plan = plan_attention(q.shape[1], sk, q.shape[-1], dtype_bytes=2)
    return blockwise_attention(
        q, k, v, q_pos, k_pos,
        block_q=int(tile_plan.block_q), block_kv=int(tile_plan.block_kv),
        causal=causal, window=cfg.sliding_window,
    )


# ---------------------------------------------------------------------------
# Attention block (projections + rope) -- GQA family
# ---------------------------------------------------------------------------


def attention_param_specs(cfg: ModelConfig, layers: int = 0) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    lead = ((layers,), ("layers",)) if layers else ((), ())
    ls, la = lead
    specs = {
        "wq": ParamSpec(ls + (d, h * hd), la + ("embed", "heads")),
        "wk": ParamSpec(ls + (d, kv * hd), la + ("embed", "heads")),
        "wv": ParamSpec(ls + (d, kv * hd), la + ("embed", "heads")),
        "wo": ParamSpec(ls + (h * hd, d), la + ("heads", "embed"), scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers))),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec(ls + (h * hd,), la + ("heads",), init="zeros")
        specs["bk"] = ParamSpec(ls + (kv * hd,), la + ("heads",), init="zeros")
        specs["bv"] = ParamSpec(ls + (kv * hd,), la + ("heads",), init="zeros")
    return specs


def attention_block(
    params: dict,
    x: jax.Array,                  # (B, S, d)
    q_pos: jax.Array,
    k_pos: jax.Array,
    cfg: ModelConfig,
    cache: Optional[dict] = None,  # {"k": (B, Smax, KV, D), "v": ..., "len": ()}
    positions_3d: Optional[jax.Array] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = fused_column_matmul(x, (params["wq"].astype(x.dtype),
                                      params["wk"].astype(x.dtype),
                                      params["wv"].astype(x.dtype)))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    from repro.dist.sharding import constrain

    q = constrain(q.reshape(b, s, h, hd), ("batch", None, "heads", None))
    k = constrain(k.reshape(b, s, kv, hd), ("batch", None, "kv_heads", None))
    v = constrain(v.reshape(b, s, kv, hd), ("batch", None, "kv_heads", None))

    if cfg.mrope and positions_3d is not None:
        q = apply_mrope(q, positions_3d, cfg.rope_theta)
        k = apply_mrope(k, positions_3d, cfg.rope_theta)
    else:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)

    kv_len = None
    new_cache = None
    if cache is not None:
        idx = cache["len"]
        w = cache["k"].shape[1]                    # cache buffer extent
        ring = bool(cfg.sliding_window) and w <= cfg.sliding_window
        if s == 1:
            slot = jnp.mod(idx, w) if ring else idx
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            new_cache = {"k": ck, "v": cv, "len": idx + s}
            k, v = ck.astype(x.dtype), cv.astype(x.dtype)
            j = jnp.arange(w)
            if ring:
                # Absolute position held by ring slot j (negative = empty).
                k_pos = idx - jnp.mod(idx - j, w)
            else:
                k_pos = j
                kv_len = idx + s
        else:
            # Prefill from an empty cache: attend within the prompt, then
            # store the tail (last ``w`` tokens, ring-rotated so position p
            # lives at slot p mod w).
            out = attention_op(q, k, v, q_pos, k_pos, cfg, causal=causal)
            out = out.reshape(b, s, h * hd)
            out = tp_matmul(out, params["wo"].astype(x.dtype), "row")
            if s >= w:
                tail_k, tail_v = k[:, s - w:], v[:, s - w:]
                if ring:
                    shift = (s - w) % w
                    tail_k = jnp.roll(tail_k, shift, axis=1)
                    tail_v = jnp.roll(tail_v, shift, axis=1)
                ck = tail_k.astype(cache["k"].dtype)
                cv = tail_v.astype(cache["v"].dtype)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            return out, {"k": ck, "v": cv, "len": idx + s}

    out = attention_op(q, k, v, q_pos, k_pos, cfg, causal=causal, kv_len=kv_len)
    out = out.reshape(b, s, h * hd)
    out = tp_matmul(out, params["wo"].astype(x.dtype), "row")
    return out, new_cache


def paged_attention_block(
    params: dict,
    x: jax.Array,                  # (S, 1, d) -- one decode token per slot
    pos: jax.Array,                # (S,) per-slot absolute position
    cfg: ModelConfig,
    k_pool: jax.Array,             # (L, P, T, KV, D) page pool
    v_pool: jax.Array,
    layer,                         # layer index into the pool (int or traced)
    table: jax.Array,              # (S, NP) int32 page table
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-slot decode attention against the paged KV pool.

    The per-slot replacement of ``attention_block``'s decode branch: each
    row carries its own absolute position (per-seq RoPE offset) and its
    own valid length (``pos + 1`` -- the per-row kv_len mask), so slots at
    different depths decode in ONE batch.  The new token's K/V is written
    through the page table (``table[s, pos // T]`` at offset ``pos % T``;
    empty slots carry ``pos == 0`` and a null table row, so their write
    lands on the pool's reserved scratch page 0), then the Pallas paged
    kernel streams the slot's pages -- block size = the planned page.
    Returns ``(out (S, 1, d), k_pool, v_pool)``.

    Write contract under prefix sharing (DESIGN.md §11): with the radix
    cache on, a table row may map pages that OTHER rows (and the tree)
    also map.  Those shared pages sit strictly below the slot's write
    frontier -- ``table[s, pos // T]`` always resolves to a page with
    pool refcount 1 (private: freshly allocated or the CoW copy), which
    the engine asserts host-side before every decode tick.  Shared pages
    are read-only here: the kernel only ever gathers from them.
    """
    from repro.kernels.paged_attention import paged_attention

    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = fused_column_matmul(x, (params["wq"].astype(x.dtype),
                                      params["wk"].astype(x.dtype),
                                      params["wv"].astype(x.dtype)))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)     # per-seq rope offset
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    t = k_pool.shape[2]
    page_slot = pos // t
    n_logical = table.shape[1]
    page_ids = jnp.take_along_axis(
        table, jnp.minimum(page_slot, n_logical - 1)[:, None], axis=1)[:, 0]
    # A position past the table (a table_full stall riding through the
    # batch) must land on the null page, not clamp onto the slot's last
    # live page and corrupt it.
    page_ids = jnp.where(page_slot < n_logical, page_ids, 0)
    off = pos % t
    k_pool = k_pool.at[layer, page_ids, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[layer, page_ids, off].set(v[:, 0].astype(v_pool.dtype))

    out = paged_attention(q[:, 0], k_pool[layer], v_pool[layer], table,
                          pos + 1, window=cfg.sliding_window or 0,
                          page_tokens=t)
    out = tp_matmul(out.reshape(b, s, h * hd),
                    params["wo"].astype(x.dtype), "row")
    return out, k_pool, v_pool


def paged_prefill_block(
    params: dict,
    x: jax.Array,                  # (1, C, d) -- one prompt chunk
    positions: jax.Array,          # (C,) absolute positions of the chunk
    cfg: ModelConfig,
    k_pool: jax.Array,             # (L, P, T, KV, D) page pool
    v_pool: jax.Array,
    layer,                         # layer index into the pool (int or traced)
    table_row: jax.Array,          # (NP,) int32 -- ONE slot's page table
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One prompt chunk's attention, KV written straight into pool pages.

    The chunked-prefill sibling of ``paged_attention_block``: a CHUNK of
    one slot's prompt (exact length, no padding -- the partial final
    chunk is its own jit bucket) projects q/k/v, ropes at its absolute
    ``positions``, scatters K/V through the slot's ``table_row`` (page
    ``positions // T`` at offset ``positions % T`` -- the pages the
    scheduler allocated ahead of the chunk front), and attends causally
    over everything written so far by treating each query token as a
    decode row of length ``position + 1`` in the Pallas paged kernel.
    Zero post-prefill copies: the pages ARE the prefill destination.
    Returns ``(out (1, C, d), k_pool, v_pool)``.

    Write contract under prefix sharing (DESIGN.md §11): on a radix
    prefix hit the chunk front starts AFTER the shared pages, so every
    ``positions // T`` this chunk scatters into is a refcount-1 page
    (the mid-page case writes into the slot's private CoW copy, never
    the cached original) -- asserted host-side by the engine before the
    chunk runs.  The shared prefix pages are only gathered from, through
    the same ``table_row``.
    """
    from repro.kernels.paged_attention import paged_attention

    b, c, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = fused_column_matmul(x, (params["wq"].astype(x.dtype),
                                      params["wk"].astype(x.dtype),
                                      params["wv"].astype(x.dtype)))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, c, h, hd)
    k = k.reshape(b, c, kv, hd)
    v = v.reshape(b, c, kv, hd)
    q = apply_rope(q, positions[None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, :], cfg.rope_theta)

    t = k_pool.shape[2]
    page_slot = positions // t
    n_logical = table_row.shape[0]
    page_ids = table_row[jnp.minimum(page_slot, n_logical - 1)]
    page_ids = jnp.where(page_slot < n_logical, page_ids, 0)
    off = positions % t
    k_pool = k_pool.at[layer, page_ids, off].set(k[0].astype(k_pool.dtype))
    v_pool = v_pool.at[layer, page_ids, off].set(v[0].astype(v_pool.dtype))

    # Each chunk token is a "decode row" over the same table with its own
    # causal length -- the paged kernel's per-row kv_len mask does the
    # intra-chunk causal masking for free.
    table = jnp.broadcast_to(table_row[None, :], (c, n_logical))
    out = paged_attention(q[0], k_pool[layer], v_pool[layer], table,
                          positions + 1, window=cfg.sliding_window or 0,
                          page_tokens=t)
    out = tp_matmul(out.reshape(b, c, h * hd),
                    params["wo"].astype(x.dtype), "row")
    return out, k_pool, v_pool


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_param_specs(cfg: ModelConfig, d_ff: Optional[int] = None, layers: int = 0) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ls = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "wi": ParamSpec(ls + (d, f), la + ("embed", "mlp")),
        "wg": ParamSpec(ls + (d, f), la + ("embed", "mlp")),
        "wo": ParamSpec(ls + (f, d), la + ("mlp", "embed"), scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers))),
    }


def swiglu_ffn(params: dict, x: jax.Array) -> jax.Array:
    g, u = fused_column_matmul(x, (params["wg"].astype(x.dtype),
                                   params["wi"].astype(x.dtype)))
    return tp_matmul(jax.nn.silu(g) * u, params["wo"].astype(x.dtype), "row")


# ---------------------------------------------------------------------------
# Embeddings & loss
# ---------------------------------------------------------------------------


def padded_vocab(cfg: ModelConfig, mult: int = 32) -> int:
    """Pad the vocab to a mesh-friendly multiple (Whisper's 51866 does not
    divide the 16/32-way axes). Pad logits are masked to -inf in
    ``lm_logits`` so the loss semantics are unchanged."""
    return ((cfg.vocab_size + mult - 1) // mult) * mult


def embed_param_specs(cfg: ModelConfig) -> dict:
    v = padded_vocab(cfg)
    specs = {
        "embedding": ParamSpec((v, cfg.d_model), ("vocab", "embed"), init="embed"),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, v), ("embed", "vocab"))
    return specs


def embed_tokens(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    return params["embedding"].astype(dtype)[tokens]


def lm_logits(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(x.dtype))
    else:
        logits = tp_matmul(x, params["lm_head"].astype(x.dtype), "column")
    if logits.shape[-1] != cfg.vocab_size:  # padded vocab: mask pad slots
        pad_mask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, NEG_INF)
    return logits


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       z_loss: float = 1e-4) -> jax.Array:
    """Mean token NLL in f32 (+ z-loss for logit drift control)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
