"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

MLA compresses the KV cache into a rank-``kv_lora_rank`` latent plus a
shared RoPE key -- itself a *cache-size* optimization very much in the
spirit of the reproduced paper: the working set is reshaped to fit the fast
memory level. Training uses the expanded form; decoding uses the absorbed
form, attending directly over the latent cache:

  logits_h = q_nope_h @ W_ukT_h @ c  +  q_rope_h @ k_rope
  out_h    = (probs_h @ c) @ W_uv_h

so the per-token cache cost is kv_lora_rank + rope_head_dim (576 floats for
DeepSeek-V2) instead of 2 * n_heads * head_dim (32768).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import NEG_INF, apply_rope, rms_norm
from repro.models.params import ParamSpec


def mla_param_specs(cfg: ModelConfig, layers: int = 0) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    ls = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    specs = {
        "wkv_a": ParamSpec(ls + (d, m.kv_lora_rank + m.rope_head_dim),
                           la + ("embed", None)),
        "kv_norm": ParamSpec(ls + (m.kv_lora_rank,), la + (None,), init="ones"),
        "wk_b": ParamSpec(ls + (m.kv_lora_rank, h, m.nope_head_dim),
                          la + (None, "heads", None)),
        "wv_b": ParamSpec(ls + (m.kv_lora_rank, h, m.v_head_dim),
                          la + (None, "heads", None)),
        "wo": ParamSpec(ls + (h, m.v_head_dim, d), la + ("heads", None, "embed"),
                        scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers))),
    }
    if m.q_lora_rank:
        specs["wq_a"] = ParamSpec(ls + (d, m.q_lora_rank), la + ("embed", None))
        specs["q_norm"] = ParamSpec(ls + (m.q_lora_rank,), la + (None,), init="ones")
        specs["wq_b"] = ParamSpec(ls + (m.q_lora_rank, h, qk),
                                  la + (None, "heads", None))
    else:
        specs["wq"] = ParamSpec(ls + (d, h, qk), la + ("embed", "heads", None))
    return specs


def _project_q(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """Returns (q_nope (B,S,H,dn), q_rope (B,S,H,dr))."""
    m = cfg.mla
    if m.q_lora_rank:
        ql = rms_norm(x @ params["wq_a"].astype(x.dtype), params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhe->bshe", ql, params["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    return q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]


def mla_attention(
    params: dict,
    x: jax.Array,                  # (B, S, d)
    q_pos: jax.Array,              # (S,)
    cfg: ModelConfig,
    cache: Optional[dict] = None,  # {"ckv": (B,Smax,R), "krope": (B,Smax,dr), "len": ()}
) -> Tuple[jax.Array, Optional[dict]]:
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)

    q_nope, q_rope = _project_q(params, x, cfg)
    q_rope = apply_rope(q_rope, q_pos, cfg.rope_theta)

    kv = x @ params["wkv_a"].astype(x.dtype)               # (B,S,R+dr)
    ckv = rms_norm(kv[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]       # (B,S,1,dr)
    k_rope = apply_rope(k_rope, q_pos, cfg.rope_theta)[:, :, 0]  # (B,S,dr)

    new_cache = None
    if cache is None:
        # Training / prefill: expanded form.
        k_nope = jnp.einsum("bsr,rhe->bshe", ckv, params["wk_b"].astype(x.dtype))
        v = jnp.einsum("bsr,rhe->bshe", ckv, params["wv_b"].astype(x.dtype))
        k_pos = q_pos
        logits = (
            jnp.einsum("bqhe,bkhe->bhqk", q_nope, k_nope)
            + jnp.einsum("bqhe,bke->bhqk", q_rope, k_rope)
        ).astype(jnp.float32) * scale
        mask = k_pos[None, :] <= q_pos[:, None]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhe->bqhe", probs, v)      # (B,S,H,dv)
    else:
        # Decode: absorbed form over the latent cache.
        idx = cache["len"]
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), idx, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), idx, axis=1)
        new_cache = {"ckv": ckv_c, "krope": kr_c, "len": idx + s}
        ckv_all = ckv_c.astype(x.dtype)                    # (B,Smax,R)
        kr_all = kr_c.astype(x.dtype)                      # (B,Smax,dr)
        # Absorb W_uk into q: (B,S,H,dn) @ (R,H,dn) -> (B,S,H,R).
        q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, params["wk_b"].astype(x.dtype))
        logits = (
            jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv_all)
            + jnp.einsum("bqhe,bke->bhqk", q_rope, kr_all)
        ).astype(jnp.float32) * scale
        k_pos = jnp.arange(ckv_all.shape[1])
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos < idx + s)[None, :]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, ckv_all)  # (B,S,H,R)
        out = jnp.einsum("bqhr,rhe->bqhe", o_lat, params["wv_b"].astype(x.dtype))

    y = jnp.einsum("bqhe,hed->bqd", out, params["wo"].astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# Paged MLA: the latent cache lives in the page pool
# ---------------------------------------------------------------------------
#
# One pooled buffer ``lat`` of shape (L, P, T, 1, R + dr) stores, per token,
# concat(rms_norm(ckv), roped k_rope) -- the exact absorbed-form cache row.
# The Pallas paged kernel is reused UNCHANGED by two observations:
#
#   * logits  = q_lat @ ckv + q_rope @ k_rope = concat(q_lat, q_rope) @ lat,
#     so passing the lat pool as ``k_pages`` with query concat(q_lat, q_rope)
#     computes MLA logits.  The kernel scales by 1/sqrt(R + dr) internally
#     where MLA wants 1/sqrt(nope + rope); the query is pre-scaled by the
#     ratio to compensate.
#   * out = probs @ ckv is the first R columns of probs @ lat, so passing
#     the SAME pool as ``v_pages`` and slicing ``[..., :R]`` recovers the
#     latent output (the discarded tail is probs @ k_rope -- never needed).
#
# The single shared latent acts as one KV head (kv = 1); the kernel's
# sublane zero-padding handles n_kv % 8 != 0.


def _mla_latent_row(params, x, positions, cfg):
    """Project ``x`` to its latent-cache rows and absorbed queries.

    positions: broadcastable to (B, S).  Returns
    (q_cat (B,S,H,R+dr) pre-scaled for the paged kernel, lat (B,S,R+dr)).
    """
    m = cfg.mla
    q_nope, q_rope = _project_q(params, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, params["wk_b"].astype(x.dtype))

    kv = x @ params["wkv_a"].astype(x.dtype)
    ckv = rms_norm(kv[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]

    lat_dim = m.kv_lora_rank + m.rope_head_dim
    ratio = math.sqrt(lat_dim) / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1) * ratio
    lat = jnp.concatenate([ckv, k_rope], axis=-1)       # (B,S,R+dr)
    return q_cat, lat


def _mla_out(params, o_lat, x_dtype):
    """Latent kernel output (…,H,R) -> d_model via wv_b then wo."""
    out = jnp.einsum("bqhr,rhe->bqhe", o_lat,
                     params["wv_b"].astype(x_dtype))
    return jnp.einsum("bqhe,hed->bqd", out, params["wo"].astype(x_dtype))


def paged_mla_attention_block(
    params: dict,
    x: jax.Array,                  # (S, 1, d) -- one decode token per slot
    pos: jax.Array,                # (S,) per-slot absolute position
    cfg: ModelConfig,
    lat_pool: jax.Array,           # (L, P, T, 1, R+dr) latent page pool
    layer,
    table: jax.Array,              # (S, NP) int32 page table
) -> Tuple[jax.Array, jax.Array]:
    """Per-slot absorbed-form MLA decode against the latent page pool."""
    from repro.kernels.paged_attention import paged_attention

    m = cfg.mla
    b, s, d = x.shape
    q_cat, lat = _mla_latent_row(params, x, pos[:, None], cfg)

    t = lat_pool.shape[2]
    page_slot = pos // t
    n_logical = table.shape[1]
    page_ids = jnp.take_along_axis(
        table, jnp.minimum(page_slot, n_logical - 1)[:, None], axis=1)[:, 0]
    page_ids = jnp.where(page_slot < n_logical, page_ids, 0)
    off = pos % t
    lat_pool = lat_pool.at[layer, page_ids, off].set(
        lat[:, 0, None, :].astype(lat_pool.dtype))

    o_lat = paged_attention(q_cat[:, 0], lat_pool[layer], lat_pool[layer],
                            table, pos + 1, window=cfg.sliding_window or 0,
                            page_tokens=t)[..., : m.kv_lora_rank]
    y = _mla_out(params, o_lat[:, None], x.dtype)
    return y, lat_pool


def paged_mla_prefill_block(
    params: dict,
    x: jax.Array,                  # (1, C, d) -- one prompt chunk
    positions: jax.Array,          # (C,)
    cfg: ModelConfig,
    lat_pool: jax.Array,           # (L, P, T, 1, R+dr)
    layer,
    table_row: jax.Array,          # (NP,) int32 -- ONE slot's page table
) -> Tuple[jax.Array, jax.Array]:
    """One prompt chunk's MLA attention, latent rows written into pages."""
    from repro.kernels.paged_attention import paged_attention

    m = cfg.mla
    b, c, d = x.shape
    q_cat, lat = _mla_latent_row(params, x, positions[None, :], cfg)

    t = lat_pool.shape[2]
    page_slot = positions // t
    n_logical = table_row.shape[0]
    page_ids = table_row[jnp.minimum(page_slot, n_logical - 1)]
    page_ids = jnp.where(page_slot < n_logical, page_ids, 0)
    off = positions % t
    lat_pool = lat_pool.at[layer, page_ids, off].set(
        lat[0, :, None, :].astype(lat_pool.dtype))

    table = jnp.broadcast_to(table_row[None, :], (c, n_logical))
    o_lat = paged_attention(q_cat[0], lat_pool[layer], lat_pool[layer],
                            table, positions + 1,
                            window=cfg.sliding_window or 0,
                            page_tokens=t)[..., : m.kv_lora_rank]
    y = _mla_out(params, o_lat[None], x.dtype)
    return y, lat_pool
