"""Cache-conscious run-time decomposition, L1 to mesh (see DESIGN.md)."""

__version__ = "0.1.0"
