"""Fault-tolerance primitives for long multi-pod runs.

  * ``PreemptionHandler`` -- SIGTERM/SIGINT -> ``should_stop`` flag the
    training loop polls each step; the loop then takes a final synchronous
    checkpoint and exits cleanly (TPU preemption notices arrive this way).
  * ``StepWatchdog`` -- wall-clock deadline per step. On expiry it invokes a
    callback (log, checkpoint, or abort). At the 1000-node scale the same
    watchdog drives *straggler mitigation*: a host that repeatedly trips the
    deadline is declared slow and the launcher swaps in a hot spare, then
    the job resumes from the last checkpoint on the refreshed slice (the
    data pipeline being stateless-resumable makes the swap coordination
    free).
  * ``StragglerPolicy`` -- bookkeeping for per-host step latencies with a
    robust (median + k*MAD) slowness test; pure logic, unit-testable.
"""

from __future__ import annotations

import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = threading.Event()
        self._prev = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._on_signal)
            except ValueError:
                pass  # non-main thread (tests)
        return self

    def _on_signal(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def request_stop(self) -> None:   # for tests / manual drain
        self._stop.set()

    def uninstall(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)


class StepWatchdog:
    """Fires ``on_timeout(step, elapsed)`` if a step exceeds its deadline."""

    def __init__(self, deadline_s: float,
                 on_timeout: Callable[[int, float], None]):
        self.deadline_s = deadline_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self._step = -1
        self._t0 = 0.0

    def start_step(self, step: int) -> None:
        self.cancel()
        self._step, self._t0 = step, time.monotonic()
        self._timer = threading.Timer(self.deadline_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def _fire(self) -> None:
        self.on_timeout(self._step, time.monotonic() - self._t0)

    def end_step(self) -> None:
        self.cancel()

    def cancel(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


@dataclass
class StragglerPolicy:
    """Median + k*MAD slowness detector over per-host step times."""

    k: float = 4.0
    min_samples: int = 8
    history: Dict[int, List[float]] = field(default_factory=dict)

    def record(self, host: int, step_time: float) -> None:
        self.history.setdefault(host, []).append(step_time)

    def _recent(self, host: int, n: int = 16) -> List[float]:
        return self.history.get(host, [])[-n:]

    def stragglers(self) -> List[int]:
        import statistics

        means = {}
        for host, times in self.history.items():
            recent = self._recent(host)
            if len(recent) >= self.min_samples:
                means[host] = statistics.median(recent)
        if len(means) < 2:
            return []
        med = statistics.median(means.values())
        mad = statistics.median(abs(v - med) for v in means.values()) or 1e-9
        return sorted(h for h, v in means.items() if v > med + self.k * mad)

    def replacement_plan(self, spares: List[int]) -> Dict[int, int]:
        """Map straggler host -> spare host (documented launcher protocol:
        drain straggler, restore latest checkpoint on spare, resume)."""
        out = {}
        for straggler, spare in zip(self.stragglers(), spares):
            out[straggler] = spare
        return out

    def forget(self, host: int) -> None:
        """Drop a host's latency history (cluster router un-drain: a
        drained replica re-admitted to service must re-earn a straggler
        verdict from fresh samples, not inherit its pre-drain tail)."""
        self.history.pop(host, None)
