from repro.ft.resilience import (
    PreemptionHandler,
    StepWatchdog,
    StragglerPolicy,
)

__all__ = ["PreemptionHandler", "StepWatchdog", "StragglerPolicy"]
