"""Ring collective matmuls: stream mesh-level partitions while computing.

The paper's CC/SRRC schedules keep one partition resident while the next is
being fetched from the level above.  At the mesh level the "level above" is
the interconnect: these kernels decompose the contraction into one partition
per chip and overlap the ``lax.ppermute`` transfer of the next partition
with the MXU work on the current one (XLA turns the independent permute
into an async collective-permute-start/done pair around the dot).
DESIGN.md §5 is the architecture reference for everything in this module.

Two streaming orders, chosen by the scheduler in ``core.schedule``
(``ring_stream_order``), not hard-coded here:

  * ``ring``       -- CC order: one ICI direction, the whole chunk hops
    forward each step.
  * ``serpentine`` -- SRRC order: both ICI directions concurrently, each
    carrying half of every chunk, so the per-link bytes per step halve and
    effective interconnect bandwidth roughly doubles (the §2.2.2
    shared-resource idea applied to the two directions of a ring link).

The kernels:

  * ``make_ag_matmul`` -- all-gather matmul: x is k-sharded (the layout a
    preceding row-parallel layer leaves it in), w is n-sharded; each ring
    step multiplies the resident k-chunk of x against the matching rows of
    the local w shard.  Output is n-sharded; globally ``y == x @ w``.
  * ``make_rs_matmul`` -- reduce-scatter matmul: x is k-sharded, w is
    k-sharded (row-parallel); the partial-sum accumulator for each output
    row block rides the ring, each chip adding its local contribution.
    Output is m-sharded; globally ``y == x @ w``.
  * ``overlap_matmul`` -- the dispatch ``models/layers.py`` calls for every
    tensor-parallel projection; routes through the kernels above when the
    active sharding rules request it and falls back (returns None) under
    GSPMD rules or non-dividing shapes.

The per-step block compute reuses the chip-level decomposer: on TPU the
local dot runs the Pallas ``matmul_cc`` kernel under the memoized VMEM
leaf of the hierarchical planner (``repro.plan.leaf_matmul_plan`` -- the
same shard shape re-plans once, not per trace); elsewhere it lowers to
``jnp.dot``.  That nesting -- a chip-level cache-conscious plan inside
every mesh-level ring step -- is the paper's hierarchy recursion
(DESIGN.md §5/§6).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

#: Collective-matmul modes the overlap layer understands ("gspmd" means
#: "do not use this module at all" and is handled by the dispatch caller).
MODES = ("ring", "serpentine")


# ---------------------------------------------------------------------------
# Plan-time ring schedule (DESIGN.md §5)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RingPlan:
    """Plan-time schedule of one ring collective (DESIGN.md §5).

    Holds the per-step chunk-owner offsets chosen by the SRRC scheduler
    (``core.schedule.ring_stream_order``) and the ``ppermute`` permutation
    lists built once here -- the kernels close over them instead of
    rebuilding the perm inside every ring step.  ``bwd_*`` fields are None
    in single-direction ("ring") mode.
    """

    p: int
    mode: str                                   # "ring" | "serpentine"
    fwd_offsets: Tuple[int, ...]                # step s consumes (rank - off)
    fwd_perm: Tuple[Tuple[int, int], ...]       # i -> i+1 ring shift
    bwd_offsets: Optional[Tuple[int, ...]] = None
    bwd_perm: Optional[Tuple[Tuple[int, int], ...]] = None

    @property
    def bidirectional(self) -> bool:
        return self.bwd_perm is not None

    def describe(self) -> list:
        """One printable line per ring step showing the ``ppermute``(s) the
        step issues -- the ``benchmarks/run.py --dry`` plan output."""

        def fmt(perm):
            return " ".join(f"{a}>{b}" for a, b in perm)

        lines = []
        for s in range(self.p):
            fwd = f"fwd:src=rank-{self.fwd_offsets[s]}"
            if s < self.p - 1:
                fwd += f";ppermute={fmt(self.fwd_perm)}"
            else:
                fwd += ";last_step=no_permute"
            if not self.bidirectional:
                lines.append(fwd)
                continue
            hops_back = (self.p - self.bwd_offsets[s]) % self.p
            bwd = f"bwd:src=rank+{hops_back}"
            if s < self.p - 1:
                bwd += f";ppermute={fmt(self.bwd_perm)}"
            else:
                bwd += ";last_step=no_permute"
            lines.append(f"{fwd}|{bwd}")
        return lines


@lru_cache(maxsize=64)
def plan_ring(p: int, mode: str = "ring") -> RingPlan:
    """Build the plan-time schedule for a ``p``-way ring axis (DESIGN.md §5).

    The streaming order comes from the paper's scheduler
    (``core.schedule.ring_stream_order``): "ring" uses the CC order (one
    ICI direction), "serpentine" the SRRC order (both directions
    concurrently, each carrying half of every chunk).  Permutation lists
    are materialized once here, at plan time, and closed over by the
    kernels -- never rebuilt inside a ring step.
    """
    from repro.core.schedule import ring_stream_order

    if mode not in MODES:
        raise ValueError(f"unknown collectives mode {mode!r}; one of {MODES}")
    order = ring_stream_order(p, "cc" if mode == "ring" else "srrc")
    fwd = tuple(step[0] for step in order)
    fwd_perm = tuple((i, (i + 1) % p) for i in range(p))
    if mode == "ring":
        return RingPlan(p=p, mode=mode, fwd_offsets=fwd, fwd_perm=fwd_perm)
    bwd = tuple(step[1] for step in order)
    bwd_perm = tuple((i, (i - 1) % p) for i in range(p))
    # A physical ring shifts chunks one hop per step; verify the scheduler's
    # order is realizable before the kernels trust it.
    assert all((fwd[s + 1] - fwd[s]) % p == 1 for s in range(p - 1)), fwd
    assert all((bwd[s + 1] - bwd[s]) % p == p - 1 for s in range(p - 1)), bwd
    return RingPlan(p=p, mode=mode, fwd_offsets=fwd, fwd_perm=fwd_perm,
                    bwd_offsets=bwd, bwd_perm=bwd_perm)


# ---------------------------------------------------------------------------
# Per-step block compute (chip-level decomposer nested in the mesh step)
# ---------------------------------------------------------------------------


def _block_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """One ring step's block product, decomposer-tiled on TPU.

    The tile plan is the VMEM leaf of the hierarchical planner
    (``repro.plan.leaf_matmul_plan``, memoized per local-shard shape): a
    chip-level cache-conscious sub-plan inside every mesh-level ring step
    -- the paper's hierarchy recursion (DESIGN.md §5/§6).
    """
    if jax.default_backend() == "tpu":
        from repro.core.plan import leaf_matmul_plan
        from repro.kernels.matmul_cc import matmul_cc

        plan = leaf_matmul_plan(a.shape[0], a.shape[1], b.shape[1],
                                dtype_bytes=a.dtype.itemsize)
        return matmul_cc(a, b, plan=plan)
    return jnp.dot(a, b)


def _check_div(name: str, dim: int, n: int, over: str = "ring axis") -> None:
    if dim % n != 0:
        raise ValueError(
            f"{name}={dim} must divide evenly over the {n}-way {over}")


def _lead_spec(batch_axes: Tuple[str, ...]):
    if not batch_axes:
        return None
    return batch_axes[0] if len(batch_axes) == 1 else tuple(batch_axes)


def _batch_extent(mesh: Mesh, batch_axes: Tuple[str, ...]) -> int:
    sizes = dict(mesh.shape)
    return math.prod(sizes.get(a, 1) for a in batch_axes) if batch_axes else 1


# ---------------------------------------------------------------------------
# All-gather matmul
# ---------------------------------------------------------------------------


def make_ag_matmul(mesh: Mesh, axis: str = "model", mode: str = "ring",
                   batch_axes: Tuple[str, ...] = ()):
    """All-gather matmul ``y = x @ w`` with x sharded on k and w on n
    (DESIGN.md §5).

    Ring mode: at step s each chip holds the k-chunk originally owned by
    chip ``(i - s) mod p``, multiplies it against the matching row band of
    its w shard, and forwards it -- the permute of step s overlaps the dot
    of step s (the all-gather never materializes the full x).

    Serpentine mode: each chip's k-chunk is split in half; the low half
    streams forward, the high half backward, and each step computes two
    half-chunk dots against the matching w row bands.  Both ICI directions
    carry traffic every step, so the per-link bytes halve (requires an even
    per-chip chunk, ``k % 2p == 0``).

    ``batch_axes`` names the mesh axes the leading (m) dim of x stays
    sharded over across the ring -- the batch/data axes of the active rules
    -- so routing a model projection through here never gathers the batch.
    """
    p = dict(mesh.shape)[axis]
    plan = plan_ring(p, mode)
    d = _batch_extent(mesh, batch_axes)
    lead = _lead_spec(batch_axes)

    def ag_local(x_blk: jax.Array, w_blk: jax.Array) -> jax.Array:
        # x_blk: (m_local, k/p) -- my k-chunk; w_blk: (k, n/p) -- my n cols.
        m, kb = x_blk.shape
        nb = w_blk.shape[1]
        idx = jax.lax.axis_index(axis)
        acc0 = jnp.zeros((m, nb), jnp.promote_types(x_blk.dtype, w_blk.dtype))

        def rows_for(src, col0, width):
            # Row band of w matching columns [col0, col0+width) of the chunk
            # owned by chip ``src``.
            return jax.lax.dynamic_slice(
                w_blk, (src * kb + col0, 0), (width, nb))

        if not plan.bidirectional:
            offs = jnp.asarray(plan.fwd_offsets, jnp.int32)

            def step(carry, off):
                chunk, acc = carry
                src = (idx - off) % p
                acc = acc + _block_matmul(chunk, rows_for(src, 0, kb))
                chunk = jax.lax.ppermute(chunk, axis, plan.fwd_perm)
                return (chunk, acc), None

            (chunk, acc), _ = jax.lax.scan(step, (x_blk, acc0), offs[:-1])
            src = (idx - offs[-1]) % p
            return acc + _block_matmul(chunk, rows_for(src, 0, kb))

        half = kb // 2
        f_offs = jnp.asarray(plan.fwd_offsets, jnp.int32)
        b_offs = jnp.asarray(plan.bwd_offsets, jnp.int32)

        def compute(lo, hi, acc, off_f, off_b):
            src_f = (idx - off_f) % p
            src_b = (idx - off_b) % p
            acc = acc + _block_matmul(lo, rows_for(src_f, 0, half))
            return acc + _block_matmul(hi, rows_for(src_b, half, kb - half))

        def step(carry, offs_s):
            lo, hi, acc = carry
            off_f, off_b = offs_s
            acc = compute(lo, hi, acc, off_f, off_b)
            lo = jax.lax.ppermute(lo, axis, plan.fwd_perm)
            hi = jax.lax.ppermute(hi, axis, plan.bwd_perm)
            return (lo, hi, acc), None

        (lo, hi, acc), _ = jax.lax.scan(
            step, (x_blk[:, :half], x_blk[:, half:], acc0),
            (f_offs[:-1], b_offs[:-1]))
        return compute(lo, hi, acc, f_offs[-1], b_offs[-1])

    sharded = shard_map(
        ag_local, mesh=mesh,
        in_specs=(P(lead, axis), P(None, axis)),
        out_specs=P(lead, axis),
        check_rep=False,
    )

    @jax.jit
    def ag_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
        if x.shape[1] != w.shape[0]:
            # The ring slices w by dynamic_slice, which would clamp a
            # mismatched contraction dim into silent garbage.
            raise ValueError(f"contraction mismatch: x {x.shape} @ w {w.shape}")
        _check_div("k", x.shape[1], p)
        _check_div("n", w.shape[1], p)
        if d > 1:
            _check_div("m", x.shape[0], d,
                       f"batch axes {batch_axes!r}")
        if plan.bidirectional and (x.shape[1] // p) % 2 != 0:
            raise ValueError(
                f"serpentine all-gather needs an even per-chip k chunk to "
                f"split across both ICI directions: k={x.shape[1]} over the "
                f"{p}-way ring leaves kb={x.shape[1] // p} (odd); pad k to "
                f"a multiple of {2 * p} or use mode='ring'")
        return sharded(x, w)

    return ag_matmul


def make_ag_matmul_fused(mesh: Mesh, axis: str = "model", mode: str = "ring",
                         n_out: int = 2, batch_axes: Tuple[str, ...] = ()):
    """Fused all-gather matmuls: several column-parallel projections of the
    SAME input share one ring (first bullet of the ROADMAP overlap item).

    q/k/v (and the SwiGLU wg/wi) each used to issue an independent
    all-gather ring over the same ``x``: p-1 hops of the identical k-chunk
    per projection.  Here the chunk hops ONCE per ring step and every step
    multiplies it against the matching row band of *each* weight shard --
    ``n_out`` dots per hop, one stream of ``x`` per block.  Outputs are
    each n-sharded over ``axis``, exactly as the unfused kernels produce,
    and each ``y_i == x @ w_i`` globally (same per-column accumulation
    order, so the fusion is bitwise-identical to the unfused rings).

    Serpentine mode streams the two chunk halves in both ICI directions as
    in ``make_ag_matmul`` (``2 * n_out`` half-chunk dots per step).
    """
    p = dict(mesh.shape)[axis]
    plan = plan_ring(p, mode)
    d = _batch_extent(mesh, batch_axes)
    lead = _lead_spec(batch_axes)

    def ag_local(x_blk: jax.Array, *w_blks: jax.Array):
        m, kb = x_blk.shape
        idx = jax.lax.axis_index(axis)
        accs = tuple(
            jnp.zeros((m, w.shape[1]),
                      jnp.promote_types(x_blk.dtype, w.dtype))
            for w in w_blks)

        def rows_for(w_blk, src, col0, width):
            return jax.lax.dynamic_slice(
                w_blk, (src * kb + col0, 0), (width, w_blk.shape[1]))

        if not plan.bidirectional:
            offs = jnp.asarray(plan.fwd_offsets, jnp.int32)

            def compute(chunk, accs, off):
                src = (idx - off) % p
                return tuple(
                    acc + _block_matmul(chunk, rows_for(w, src, 0, kb))
                    for acc, w in zip(accs, w_blks))

            def step(carry, off):
                chunk, accs = carry
                accs = compute(chunk, accs, off)
                chunk = jax.lax.ppermute(chunk, axis, plan.fwd_perm)
                return (chunk, accs), None

            (chunk, accs), _ = jax.lax.scan(step, (x_blk, accs), offs[:-1])
            return compute(chunk, accs, offs[-1])

        half = kb // 2
        f_offs = jnp.asarray(plan.fwd_offsets, jnp.int32)
        b_offs = jnp.asarray(plan.bwd_offsets, jnp.int32)

        def compute(lo, hi, accs, off_f, off_b):
            src_f = (idx - off_f) % p
            src_b = (idx - off_b) % p
            return tuple(
                acc + _block_matmul(lo, rows_for(w, src_f, 0, half))
                + _block_matmul(hi, rows_for(w, src_b, half, kb - half))
                for acc, w in zip(accs, w_blks))

        def step(carry, offs_s):
            lo, hi, accs = carry
            off_f, off_b = offs_s
            accs = compute(lo, hi, accs, off_f, off_b)
            lo = jax.lax.ppermute(lo, axis, plan.fwd_perm)
            hi = jax.lax.ppermute(hi, axis, plan.bwd_perm)
            return (lo, hi, accs), None

        (lo, hi, accs), _ = jax.lax.scan(
            step, (x_blk[:, :half], x_blk[:, half:], accs),
            (f_offs[:-1], b_offs[:-1]))
        return compute(lo, hi, accs, f_offs[-1], b_offs[-1])

    sharded = shard_map(
        ag_local, mesh=mesh,
        in_specs=(P(lead, axis),) + (P(None, axis),) * n_out,
        out_specs=tuple(P(lead, axis) for _ in range(n_out)),
        check_rep=False,
    )

    @jax.jit
    def ag_matmul_fused(x: jax.Array, *ws: jax.Array):
        if len(ws) != n_out:
            raise ValueError(f"expected {n_out} weights, got {len(ws)}")
        for w in ws:
            if x.shape[1] != w.shape[0]:
                raise ValueError(
                    f"contraction mismatch: x {x.shape} @ w {w.shape}")
            _check_div("n", w.shape[1], p)
        _check_div("k", x.shape[1], p)
        if d > 1:
            _check_div("m", x.shape[0], d, f"batch axes {batch_axes!r}")
        if plan.bidirectional and (x.shape[1] // p) % 2 != 0:
            raise ValueError(
                f"serpentine all-gather needs an even per-chip k chunk: "
                f"k={x.shape[1]} over the {p}-way ring leaves "
                f"kb={x.shape[1] // p} (odd); pad k to a multiple of "
                f"{2 * p} or use mode='ring'")
        return sharded(x, *ws)

    return ag_matmul_fused


# ---------------------------------------------------------------------------
# Reduce-scatter matmul
# ---------------------------------------------------------------------------


def make_rs_matmul(mesh: Mesh, axis: str = "model", mode: str = "ring",
                   batch_axes: Tuple[str, ...] = ()):
    """Reduce-scatter matmul ``y = x @ w`` with x and w sharded on k
    (DESIGN.md §5).

    Ring mode: each output row block's partial-sum accumulator travels the
    ring once, visiting every chip; chip i computes row block
    ``(i + p-1 - s) mod p`` of its local partial product at step s, so the
    accumulator it forwards is always the one its successor must extend
    (the reduce-scatter is the ring itself -- no (m, n) intermediate is
    ever materialized).

    Serpentine mode: the output columns are split in half; the low-column
    accumulators ride the forward ring, the high-column ones the backward
    ring, so both ICI directions carry half-width accumulators every step
    (requires an even n).

    ``batch_axes`` keeps the leading (m) dim sharded over the batch/data
    axes across the ring, as in ``make_ag_matmul``.
    """
    p = dict(mesh.shape)[axis]
    plan = plan_ring(p, mode)
    d = _batch_extent(mesh, batch_axes)
    lead = _lead_spec(batch_axes)
    out_axes = tuple(batch_axes) + (axis,)
    out_lead = out_axes[0] if len(out_axes) == 1 else out_axes

    def rs_local(x_blk: jax.Array, w_blk: jax.Array) -> jax.Array:
        # x_blk: (m_local, k/p) -- my k columns; w_blk: (k/p, n) -- my rows.
        m, kb = x_blk.shape
        n = w_blk.shape[1]
        mb = m // p
        idx = jax.lax.axis_index(axis)
        out_dtype = jnp.promote_types(x_blk.dtype, w_blk.dtype)

        def rows(r):
            return jax.lax.dynamic_slice(x_blk, (r * mb, 0), (mb, kb))

        if not plan.bidirectional:
            offs = jnp.asarray(plan.fwd_offsets, jnp.int32)

            def partial(off):
                r = (idx + (p - 1) - off) % p
                return _block_matmul(rows(r), w_blk).astype(out_dtype)

            def step(acc, off):
                return jax.lax.ppermute(acc + partial(off), axis,
                                        plan.fwd_perm), None

            acc, _ = jax.lax.scan(step, jnp.zeros((mb, n), out_dtype),
                                  offs[:-1])
            return acc + partial(offs[-1])

        half = n // 2
        w_lo, w_hi = w_blk[:, :half], w_blk[:, half:]
        f_offs = jnp.asarray(plan.fwd_offsets, jnp.int32)
        b_offs = jnp.asarray(plan.bwd_offsets, jnp.int32)

        def partials(off_f, off_b):
            r_f = (idx + (p - 1) - off_f) % p
            s_b = (p - off_b) % p        # steps the backward stream has taken
            r_b = (idx - (p - 1) + s_b) % p
            return (_block_matmul(rows(r_f), w_lo).astype(out_dtype),
                    _block_matmul(rows(r_b), w_hi).astype(out_dtype))

        def step(carry, offs_s):
            acc_f, acc_b = carry
            off_f, off_b = offs_s
            pf, pb = partials(off_f, off_b)
            acc_f = jax.lax.ppermute(acc_f + pf, axis, plan.fwd_perm)
            acc_b = jax.lax.ppermute(acc_b + pb, axis, plan.bwd_perm)
            return (acc_f, acc_b), None

        (acc_f, acc_b), _ = jax.lax.scan(
            step,
            (jnp.zeros((mb, half), out_dtype),
             jnp.zeros((mb, n - half), out_dtype)),
            (f_offs[:-1], b_offs[:-1]))
        pf, pb = partials(f_offs[-1], b_offs[-1])
        return jnp.concatenate([acc_f + pf, acc_b + pb], axis=1)

    sharded = shard_map(
        rs_local, mesh=mesh,
        in_specs=(P(lead, axis), P(axis, None)),
        out_specs=P(out_lead, None),
        check_rep=False,
    )

    @jax.jit
    def rs_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
        if x.shape[1] != w.shape[0]:
            raise ValueError(f"contraction mismatch: x {x.shape} @ w {w.shape}")
        _check_div("k", x.shape[1], p)
        _check_div("m", x.shape[0], d * p,
                   f"ring axis x batch axes {batch_axes!r}" if d > 1
                   else "ring axis")
        if plan.bidirectional and w.shape[1] % 2 != 0:
            raise ValueError(
                f"serpentine reduce-scatter needs an even n to split the "
                f"output columns across both ICI directions: n={w.shape[1]} "
                f"(odd); pad n or use mode='ring'")
        return sharded(x, w)

    return rs_matmul


# ---------------------------------------------------------------------------
# Dispatch (models/layers.py -> overlap layer)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=64)
def ring_kernel(mesh: Mesh, axis: str, kind: str, mode: str,
                batch_axes: Tuple[str, ...] = ()) -> Callable:
    """Memoized kernel factory (DESIGN.md §5): one shard_map/jit build per
    (mesh, axis, kind, mode, batch_axes) -- the model forward asks for a
    kernel once per projection per trace, so the factory must not rebuild
    (and the LRU bound evicts kernels of meshes long gone, e.g. across
    elastic restarts).  ``kind`` is "ag" (all-gather), "rs"
    (reduce-scatter), or "agf<N>" (N-output fused all-gather)."""
    if kind.startswith("agf"):
        return make_ag_matmul_fused(mesh, axis=axis, mode=mode,
                                    n_out=int(kind[3:]),
                                    batch_axes=batch_axes)
    make = make_ag_matmul if kind == "ag" else make_rs_matmul
    return make(mesh, axis=axis, mode=mode, batch_axes=batch_axes)


def overlap_matmul(x: jax.Array, w: jax.Array,
                   parallel: str) -> Optional[jax.Array]:
    """Route a ``(..., k) @ (k, n)`` projection through the ring kernels
    when the active sharding rules request ring/serpentine collectives
    (DESIGN.md §5).

    ``parallel`` is the weight's tensor-parallel orientation under the
    rules: "column" (n sharded over the TP axis -> all-gather ring) or
    "row" (k sharded over the TP axis -> reduce-scatter ring).  Returns
    None when the caller should fall back to a plain einsum: no active
    overlap context (``dist.sharding.active_overlap``), TP axis of size 1,
    or shapes that do not divide the ring -- mirroring the per-tensor
    divisibility guards GSPMD rules apply in ``dist.sharding``.
    """
    from repro.dist.sharding import active_overlap

    ctx = active_overlap()
    if ctx is None:
        return None
    mesh, axis, mode, batch_axes = ctx
    p = dict(mesh.shape).get(axis, 1)
    if p <= 1:
        return None
    lead, k = x.shape[:-1], x.shape[-1]
    n = w.shape[-1]
    m = math.prod(lead) if lead else 1
    d = _batch_extent(mesh, batch_axes)
    if k != w.shape[0] or k % p or m % d:
        return None
    serp = mode == "serpentine"
    if parallel == "column":
        if n % p or (serp and (k // p) % 2):
            return None
        kind = "ag"
    elif parallel == "row":
        if m % (d * p) or (serp and n % 2):
            return None
        kind = "rs"
    else:
        raise ValueError(f"parallel must be 'column' or 'row', got {parallel!r}")
    y = ring_kernel(mesh, axis, kind, mode, batch_axes)(x.reshape(m, k), w)
    return y.reshape(*lead, n)


def overlap_matmul_fused(x: jax.Array,
                         ws: Sequence[jax.Array]) -> Optional[list]:
    """Route several column-parallel projections of the same ``x`` through
    ONE all-gather ring (``make_ag_matmul_fused``): the q/k/v and SwiGLU
    fusion ``models/layers.py`` asks for.  Returns the list of outputs, or
    None when the caller should fall back to per-weight ``tp_matmul`` --
    no active overlap context, a degenerate ring, or any shape that does
    not divide it (the same guards as ``overlap_matmul``, applied to every
    weight)."""
    from repro.dist.sharding import active_overlap

    ctx = active_overlap()
    if ctx is None or len(ws) < 2:
        return None
    mesh, axis, mode, batch_axes = ctx
    p = dict(mesh.shape).get(axis, 1)
    if p <= 1:
        return None
    lead, k = x.shape[:-1], x.shape[-1]
    m = math.prod(lead) if lead else 1
    d = _batch_extent(mesh, batch_axes)
    if k % p or m % d or (mode == "serpentine" and (k // p) % 2):
        return None
    if any(w.shape[0] != k or w.shape[-1] % p for w in ws):
        return None
    fn = ring_kernel(mesh, axis, f"agf{len(ws)}", mode, batch_axes)
    ys = fn(x.reshape(m, k), *ws)
    return [y.reshape(*lead, w.shape[-1]) for y, w in zip(ys, ws)]
