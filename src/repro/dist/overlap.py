"""Ring collective matmuls: stream mesh-level partitions while computing.

The paper's CC/SRRC schedules keep one partition resident while the next is
being fetched from the level above.  At the mesh level the "level above" is
the interconnect: these kernels decompose the contraction into one partition
per chip and overlap the ``lax.ppermute`` transfer of the next partition
with the MXU work on the current one (XLA turns the independent permute
into an async collective-permute-start/done pair around the dot).

  * ``make_ag_matmul`` -- all-gather matmul: x is k-sharded (the layout a
    preceding row-parallel layer leaves it in), w is n-sharded; each ring
    step multiplies the resident k-chunk of x against the matching rows of
    the local w shard.  Output is n-sharded; globally ``y == x @ w``.
  * ``make_rs_matmul`` -- reduce-scatter matmul: x is k-sharded, w is
    k-sharded (row-parallel); the partial-sum accumulator for each output
    row block rides the ring, each chip adding its local contribution.
    Output is m-sharded; globally ``y == x @ w``.

The per-step block compute reuses the chip-level decomposer: on TPU the
local dot runs the Pallas ``matmul_cc`` kernel under a memoized
``plan_matmul_cached`` plan (the same shard shape re-plans once, not per
trace); elsewhere it lowers to ``jnp.dot``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _block_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """One ring step's block product, decomposer-tiled on TPU."""
    if jax.default_backend() == "tpu":
        from repro.core.autotile import plan_matmul_cached
        from repro.kernels.matmul_cc import matmul_cc

        plan = plan_matmul_cached(a.shape[0], a.shape[1], b.shape[1],
                                  dtype_bytes=a.dtype.itemsize)
        return matmul_cc(a, b, plan=plan)
    return jnp.dot(a, b)


def _check_div(name: str, dim: int, n: int) -> None:
    if dim % n != 0:
        raise ValueError(
            f"{name}={dim} must divide evenly over the {n}-way ring axis")


def make_ag_matmul(mesh: Mesh, axis: str = "model"):
    """All-gather matmul ``y = x @ w`` with x sharded on k and w on n.

    Ring schedule: at step s each chip holds the k-chunk originally owned by
    chip ``(i - s) mod p``, multiplies it against the matching row band of
    its w shard, and forwards it -- the permute of step s overlaps the dot
    of step s (the all-gather never materializes the full x).
    """
    p = dict(mesh.shape)[axis]

    def ag_local(x_blk: jax.Array, w_blk: jax.Array) -> jax.Array:
        # x_blk: (m, k/p) -- my k-chunk; w_blk: (k, n/p) -- my n columns.
        m, kb = x_blk.shape
        nb = w_blk.shape[1]
        idx = jax.lax.axis_index(axis)
        acc0 = jnp.zeros((m, nb), jnp.promote_types(x_blk.dtype, w_blk.dtype))

        def rows_for(step):
            src = (idx - step) % p     # owner of the resident chunk
            return jax.lax.dynamic_slice(w_blk, (src * kb, 0), (kb, nb))

        def body(s, carry):
            chunk, acc = carry
            acc = acc + _block_matmul(chunk, rows_for(s))
            chunk = jax.lax.ppermute(chunk, axis, _ring_perm(p))
            return chunk, acc

        chunk, acc = jax.lax.fori_loop(0, p - 1, body, (x_blk, acc0))
        return acc + _block_matmul(chunk, rows_for(p - 1))

    sharded = shard_map(
        ag_local, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis)),
        out_specs=P(None, axis),
        check_rep=False,
    )

    @jax.jit
    def ag_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
        if x.shape[1] != w.shape[0]:
            # The ring slices w by dynamic_slice, which would clamp a
            # mismatched contraction dim into silent garbage.
            raise ValueError(f"contraction mismatch: x {x.shape} @ w {w.shape}")
        _check_div("k", x.shape[1], p)
        _check_div("n", w.shape[1], p)
        return sharded(x, w)

    return ag_matmul


def make_rs_matmul(mesh: Mesh, axis: str = "model"):
    """Reduce-scatter matmul ``y = x @ w`` with x and w sharded on k.

    Each output row block's partial-sum accumulator travels the ring once,
    visiting every chip; chip i computes row block ``(i + p-1 - s) mod p``
    of its local partial product at step s, so the accumulator it forwards
    is always the one its successor must extend (the reduce-scatter is the
    ring itself -- no (m, n) intermediate is ever materialized).
    """
    p = dict(mesh.shape)[axis]

    def rs_local(x_blk: jax.Array, w_blk: jax.Array) -> jax.Array:
        # x_blk: (m, k/p) -- my k columns; w_blk: (k/p, n) -- my k rows.
        m, kb = x_blk.shape
        n = w_blk.shape[1]
        mb = m // p
        idx = jax.lax.axis_index(axis)
        out_dtype = jnp.promote_types(x_blk.dtype, w_blk.dtype)

        def partial_for(step):
            r = (idx + (p - 1 - step)) % p
            rows = jax.lax.dynamic_slice(x_blk, (r * mb, 0), (mb, kb))
            return _block_matmul(rows, w_blk).astype(out_dtype)

        def body(s, acc):
            acc = acc + partial_for(s)
            return jax.lax.ppermute(acc, axis, _ring_perm(p))

        acc = jax.lax.fori_loop(0, p - 1, body,
                                jnp.zeros((mb, n), out_dtype))
        return acc + partial_for(p - 1)

    sharded = shard_map(
        rs_local, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None),
        check_rep=False,
    )

    @jax.jit
    def rs_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
        if x.shape[1] != w.shape[0]:
            raise ValueError(f"contraction mismatch: x {x.shape} @ w {w.shape}")
        _check_div("k", x.shape[1], p)
        _check_div("m", x.shape[0], p)
        return sharded(x, w)

    return rs_matmul
