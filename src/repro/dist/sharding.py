"""Logical-axis sharding rules, chosen by the paper's decomposer.

Model code names *logical* axes ("embed", "heads", "batch", ...); a
``ShardingRules`` table maps each logical axis to zero or more mesh axes.
The table itself is not hand-written per architecture: the mesh is treated
as the outermost level of the memory hierarchy (DESIGN.md §2) and the
FSDP / replicated choice for parameters is made by the paper's Algorithm 1
(``find_optimal_np`` with ``phi_mesh``) against the per-chip HBM budget of
``tpu_hierarchy(..., mesh_devices=N)``:

  * ``np* == 1``  -- one partition: the parameter+optimizer state fits each
    chip's HBM after tensor parallelism, so params replicate over the data
    axes (cheapest collectives -- the mesh analogue of "the whole domain
    fits the TCL").
  * ``np* > 1``   -- the state must be decomposed harder: params shard over
    the data axes (FSDP), exactly like the binary search relaxing np until
    the partition fits.

Tensor-parallel ("model" axis) rules are structural -- they follow from the
architecture's divisibilities (heads, experts, vocab) -- while the
memory-driven FSDP degree is the decomposer's call.  ``mesh_decomposition``
exposes the raw search result for tests and diagnostics.
"""

from __future__ import annotations

import threading
from math import prod
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.decompose import (
    NoValidDecomposition,
    find_optimal_np,
    make_phi_mesh,
)
from repro.core.distribution import Array1DDistribution, ReplicatedDistribution
from repro.core.hierarchy import MemoryLevel
from repro.core.plan import HierarchicalPlan, PlanPolicy, Workload, plan_run

AxisRule = Union[None, str, Tuple[str, ...]]
PyTree = Any

#: Resident bytes per parameter of the training state: fp32 master copy,
#: AdamW mu + nu (fp32 default), and the bf16 compute cast made each step.
TRAIN_STATE_BYTES_PER_PARAM = 4 + 4 + 4 + 2

#: Collective-matmul schedules the rules may request (DESIGN.md §5):
#: "gspmd" leaves collectives to XLA; "ring"/"serpentine" route the
#: tensor-parallel projections through ``dist.overlap``'s streaming matmuls.
COLLECTIVES = ("gspmd", "ring", "serpentine")


# ---------------------------------------------------------------------------
# Rules table
# ---------------------------------------------------------------------------


def _rule_axes(rule: AxisRule) -> Tuple[str, ...]:
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def _build_spec(table: Dict[str, AxisRule],
                axes: Sequence[Optional[str]]) -> P:
    """PartitionSpec from logical axes via the table; a mesh axis is used at
    most once (first logical axis wins, matching GSPMD's constraint)."""
    used: set = set()
    entries = []
    for ax in axes:
        names = [n for n in _rule_axes(table.get(ax) if ax else None)
                 if n not in used]
        used.update(names)
        if not names:
            entries.append(None)
        elif len(names) == 1:
            entries.append(names[0])
        else:
            entries.append(tuple(names))
    return P(*entries)


@dataclass
class ShardingRules:
    """Logical-axis -> mesh-axis tables for parameters and activations.

    ``meta`` carries the decomposer's provenance (mesh np*, budget, fit);
    it is advisory and deliberately optional so callers may rebuild rules
    positionally (``ShardingRules(param_rules, act_rules)``).
    """

    param_rules: Dict[str, AxisRule]
    act_rules: Dict[str, AxisRule]
    meta: Dict[str, Any] = field(default_factory=dict)

    def param_spec(self, axes: Sequence[Optional[str]]) -> P:
        return _build_spec(self.param_rules, axes)

    def act_spec(self, axes: Sequence[Optional[str]]) -> P:
        return _build_spec(self.act_rules, axes)


# ---------------------------------------------------------------------------
# Mesh-level decomposition (Algorithm 1 at the outermost level)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshDecomposition:
    """Result of the mesh-level Algorithm 1 run."""

    np: int                    # smallest partition count that fits per-chip HBM
    budget_bytes: int          # TCL_PER_CORE: one chip's HBM
    granule_bytes: int         # sharding granule (the mesh "cache line")
    sharded_bytes: int         # state the search may partition
    replicated_bytes: int      # state pinned to every chip
    fits: bool                 # False: even the max realizable np overflows

    @property
    def replicated(self) -> bool:
        return self.np <= 1


def mesh_hierarchy(mesh, spec=None) -> MemoryLevel:
    """The mesh in the paper's schema: [DCN ->] ICI -> per-chip HBM -> VMEM
    -> VREG.  A mesh with a "pod" axis gets a DCN level above the ICI (one
    ICI domain per pod -- the hierarchical planner runs Algorithm 1 at both
    interconnect levels, DESIGN.md §6)."""
    from repro.hw.tpu import chip_spec

    hosts = dict(mesh.shape).get("pod", 1)
    return (spec or chip_spec()).hierarchy(
        mesh_devices=mesh.size // max(1, hosts), hosts=hosts)


def mesh_plan(
    mesh,
    *,
    state_bytes: int = 0,
    act_bytes: int = 0,
    hierarchy: Optional[MemoryLevel] = None,
    max_np: Optional[int] = None,
    overhead: float = 1.0,
    matmul: Optional[Tuple[int, int, int]] = None,
    dtype_bytes: int = 2,
    spec=None,
) -> HierarchicalPlan:
    """``plan_run`` over this mesh's memory hierarchy.

    The one planning call the distribution layer makes: the returned
    ``HierarchicalPlan`` carries the DCN sub-plan (``dist.pipeline`` stage
    count), the ICI sub-plan (FSDP degree, raw and divisor-quantized), and
    -- when ``matmul`` local shapes are given -- the VMEM tile leaf.
    ``max_np`` caps the ICI partition count (the FSDP capacity of the data
    axes); ``overhead`` is the per-arch ``phi_mesh`` transient-copy factor.
    """
    hierarchy = hierarchy or mesh_hierarchy(mesh, spec)
    caps = {"ICI": max_np} if max_np else {}
    return plan_run(
        hierarchy,
        Workload(state_bytes=state_bytes, replicated_bytes=act_bytes,
                 matmul=matmul, dtype_bytes=dtype_bytes, overhead=overhead),
        PlanPolicy(max_np=caps, spec=spec),
    )


def mesh_decomposition(
    hierarchy: MemoryLevel,
    sharded_bytes: int,
    replicated_bytes: int = 0,
    max_np: int = 1 << 16,
) -> MeshDecomposition:
    """Run Algorithm 1 with the per-chip HBM as the TCL.

    A thin wrapper over a single-level ``plan_run`` (``repro.plan``): the
    planner's ICI node runs exactly this search -- the shardable training
    state (a 1-D byte range) plus a replicated remainder, the smallest
    partition count whose per-chip footprint (``phi_mesh``) fits one HBM
    copy.  Returns the *raw* np (legacy contract; the planner's quantized
    degree lives in the sub-plan).  The search is bounded by the smaller of
    ``max_np`` and the hierarchy's chip count -- a shard count above the
    number of chips is not realizable, so when nothing fits the
    decomposition saturates at that bound with ``fits=False`` (shard as
    hard as the mesh allows).
    """
    hp = plan_run(
        hierarchy,
        Workload(state_bytes=sharded_bytes, replicated_bytes=replicated_bytes),
        PlanPolicy(max_np={"ICI": max_np, "DCN": max_np}, quantize=False),
    )
    lp = hp.level("ICI")
    if lp is None:
        # Hierarchy without an interconnect level: search it directly.
        hbm = hierarchy.find("HBM") or hierarchy
        budget = hbm.per_core_size()
        granule = hbm.cache_line_size or 8 * 128 * 4
        dists = [Array1DDistribution(length=max(1, sharded_bytes),
                                     element_size=1)]
        if replicated_bytes:
            dists.append(ReplicatedDistribution(replicated_bytes))
        try:
            np_ = find_optimal_np(budget, granule, dists, 1, make_phi_mesh(),
                                  max_np=max_np)
            fits = True
        except NoValidDecomposition:
            np_, fits = max_np, False
        return MeshDecomposition(
            np=np_, budget_bytes=budget, granule_bytes=granule,
            sharded_bytes=sharded_bytes, replicated_bytes=replicated_bytes,
            fits=fits,
        )
    return MeshDecomposition(
        np=lp.np_raw, budget_bytes=lp.budget_bytes,
        granule_bytes=lp.granule_bytes,
        sharded_bytes=sharded_bytes, replicated_bytes=replicated_bytes,
        fits=lp.fits,
    )


# ---------------------------------------------------------------------------
# Rule construction
# ---------------------------------------------------------------------------


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def default_rules(
    mesh,
    *,
    state_bytes: int = 0,
    act_bytes: int = 0,
    hierarchy: Optional[MemoryLevel] = None,
    seq_sharded: bool = False,
    overhead: float = 1.0,
    plan: Optional[HierarchicalPlan] = None,
) -> ShardingRules:
    """Architecture-independent rules: TP over "model" for the structural
    axes, batch over the data axes, and the FSDP / replicated choice made by
    the hierarchical planner (``repro.plan``) over ``state_bytes`` (0 bytes
    -> trivially fits -> replicated).  Pass ``plan`` to consume an existing
    ``HierarchicalPlan`` instead of re-planning; the plan (and its
    raw/quantized FSDP degrees) rides in ``meta`` either way."""
    sizes = _axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    data = _data_axes(mesh)
    fsdp_capacity = max(1, prod(sizes[a] for a in data))
    if plan is None:
        plan = mesh_plan(
            mesh,
            state_bytes=state_bytes // max(1, model_n),
            act_bytes=act_bytes,
            hierarchy=hierarchy,
            max_np=fsdp_capacity,
            overhead=overhead,
        )
    dec = plan.level("ICI") or plan.leaf()
    dcn = plan.level("DCN")
    embed_rule: AxisRule = None
    if not dec.replicated and data:
        embed_rule = data[0] if len(data) == 1 else data
    param_rules: Dict[str, AxisRule] = {
        "embed": embed_rule,
        "heads": "model",
        "mlp": "model",
        "mlp_expert": "model",
        "vocab": "model",
        "experts": None,
        "layers": None,
    }
    act_rules: Dict[str, AxisRule] = {
        "batch": data[0] if len(data) == 1 else (data or None),
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "kv_seq": "model" if seq_sharded else None,
        "mlp": "model",
        "experts": None,
        "state_heads": "model",
        "state_inner": None,
        "vocab": "model",
        "layers": None,
    }
    return ShardingRules(param_rules, act_rules, meta={
        "mesh_np": dec.np_raw,
        "fsdp_degree": dec.np,           # divisor-quantized (ROADMAP item)
        "mesh_budget_bytes": dec.budget_bytes,
        "mesh_fits": dec.fits,
        "fsdp": not dec.replicated,
        "fsdp_capacity": fsdp_capacity,
        "dcn_np": dcn.np if dcn is not None else 1,
        "plan": plan,
    })


def arch_rules(
    cfg: ModelConfig,
    mesh,
    seq_sharded: bool = False,
    hierarchy: Optional[MemoryLevel] = None,
    act_bytes: int = 0,
    state_bytes_per_param: int = TRAIN_STATE_BYTES_PER_PARAM,
    plan: Optional[HierarchicalPlan] = None,
) -> ShardingRules:
    """Rules for one architecture on one mesh.

    Structural (divisibility-driven) TP choices come from ``cfg``; the
    memory-driven FSDP degree comes from the hierarchical planner
    (``repro.plan``) run on this architecture's resident-state footprint
    with its ``cfg.overhead`` phi_mesh factor.  Pass ``hierarchy`` to
    decompose against a different machine (tests shrink the HBM budget to
    force the replicated -> FSDP flip); pass ``act_bytes`` to reserve
    per-chip HBM for activations (they do not shrink with the param np);
    pass ``state_bytes_per_param`` for non-training memory models (serving
    holds only the bf16 weights, no master copy or optimizer moments);
    pass ``plan`` to consume an existing ``HierarchicalPlan`` instead of
    re-planning.
    """
    sizes = _axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    state_bytes = cfg.param_count() * state_bytes_per_param
    rules = default_rules(
        mesh,
        state_bytes=state_bytes,
        act_bytes=act_bytes,
        hierarchy=hierarchy,
        seq_sharded=seq_sharded,
        overhead=cfg.overhead,
        plan=plan,
    )
    pr, ar = dict(rules.param_rules), dict(rules.act_rules)

    # Structural TP refinements: drop mesh axes the architecture cannot fill.
    if cfg.n_heads % model_n != 0:
        ar["heads"] = None
    if cfg.n_kv_heads % model_n != 0:
        ar["kv_heads"] = None
    if cfg.vocab_size % model_n != 0:
        pr["vocab"] = None
        ar["vocab"] = None
    if cfg.ssm is not None:
        n_state_heads = (cfg.ssm.expand * cfg.d_model) // max(1, cfg.ssm.head_dim)
        if n_state_heads % model_n != 0:
            ar["state_heads"] = None
    if cfg.xlstm is not None and cfg.n_heads % model_n != 0:
        # xLSTM state heads == n_heads (e.g. 4) -- far short of a 16-wide
        # model axis.  Sub-axis sharding: drop the head axis and shard the
        # per-head state inner dim instead (mLSTM's dh, sLSTM's d/H), iff
        # BOTH divide the axis -- one rule covers every state leaf, and
        # the matrix state C:(..., H, dh, dh) then splits on dim 3 where
        # it used to fail pjit's divisibility check on dim 2.
        from repro.models.xlstm import _round128

        ar["state_heads"] = None
        dh = _round128(cfg.xlstm.mlstm_proj_factor * cfg.d_model) \
            // max(1, cfg.n_heads)
        dhs = cfg.d_model // max(1, cfg.n_heads)
        if dh % model_n == 0 and dhs % model_n == 0:
            ar["state_inner"] = "model"
    if cfg.moe is not None:
        # Expert parallelism when the expert count fills the model axis
        # (dispatch stays shard-local per expert group); tensor-parallel
        # experts otherwise -- see models/moe.py for the measured rationale.
        if cfg.moe.n_experts % model_n == 0 and model_n > 1:
            pr["experts"] = "model"
            pr["mlp_expert"] = None
            ar["experts"] = "model"
        else:
            pr["experts"] = None
            pr["mlp_expert"] = "model"
            ar["experts"] = None
    return ShardingRules(pr, ar, meta=rules.meta)


def with_collectives(rules: ShardingRules, mode: str,
                     axis: str = "model") -> ShardingRules:
    """Request ring/serpentine overlap collectives for the TP projections
    (DESIGN.md §5).

    The choice rides in ``rules.meta`` so it scopes exactly like the rules
    themselves: model code traced under ``use_mesh_rules(mesh, rules)``
    sees it through ``active_overlap`` and routes its matmuls through
    ``dist.overlap``; the same code under plain rules stays on GSPMD's
    default collectives.  ``axis`` names the mesh axis the ring runs over.
    """
    if mode not in COLLECTIVES:
        raise ValueError(f"unknown collectives {mode!r}; one of {COLLECTIVES}")
    meta = dict(rules.meta)
    meta["collectives"] = mode
    meta["overlap_axis"] = axis
    return ShardingRules(dict(rules.param_rules), dict(rules.act_rules),
                         meta=meta)


def resolve_collectives(rules: ShardingRules, mode: str) -> ShardingRules:
    """Resolve a collectives request ("auto" included) against the mesh
    decomposition -- the one policy shared by the train and serve step
    factories.

    "auto" enables the serpentine overlap exactly when the mesh-level
    decomposer chose FSDP (``rules.meta["fsdp"]``): that is the regime
    where every step re-gathers parameter shards over the wire, so hiding
    the transfers behind the ring matmuls pays (DESIGN.md §5).  Explicit
    "ring"/"serpentine" always apply; "gspmd" leaves XLA's defaults.
    """
    if mode == "auto":
        mode = "serpentine" if rules.meta.get("fsdp") else "gspmd"
    if mode != "gspmd":
        rules = with_collectives(rules, mode)
    return rules


def with_kv_sharding(rules: ShardingRules, kv_shard: int,
                     axis: str = "model") -> ShardingRules:
    """KV-cache sharding from the decode plan's mesh level (``repro.serve``).

    The hierarchical planner's decode workload records the KV head shard
    degree it chose at the innermost mesh level
    (``HierarchicalPlan.kv_shard()``: the full ``axis`` extent when the
    memory search demanded sharding and the head count divides it, else
    1).  This rewrites the activation rules so the lowered cache layout
    realizes exactly that choice: heads sharded over ``axis`` when
    ``kv_shard > 1``, fully replicated KV otherwise -- and never the
    legacy auto-policy's sequence fallback, which the plan does not model.

    The same choice covers the POOLED layout (``repro.serve.pages``): the
    page pool's head dim carries the same "kv_heads" logical axis, and its
    page dim ("kv_pages") is pinned unsharded -- a page is the VMEM
    streaming granule of one chip, so splitting a page across chips would
    break the plan's block-size = page-size identity.  The per-slot page
    table and position vector replicate (scalar bookkeeping).
    """
    ar = dict(rules.act_rules)
    ar["kv_heads"] = axis if kv_shard > 1 else None
    ar["kv_seq"] = None
    ar["kv_pages"] = None
    meta = dict(rules.meta)
    meta["kv_shard"] = int(kv_shard)
    return ShardingRules(dict(rules.param_rules), ar, meta=meta)


def with_batch_guard(rules: ShardingRules, mesh, global_batch: int) -> ShardingRules:
    """Trim the batch rule to the mesh axes whose product divides the global
    batch (a batch that cannot split evenly replicates instead of erroring)."""
    sizes = _axis_sizes(mesh)
    kept: list = []
    prod = 1
    for a in _rule_axes(rules.act_rules.get("batch")):
        size = sizes.get(a, 1)
        if size and global_batch % (prod * size) == 0:
            kept.append(a)
            prod *= size
    ar = dict(rules.act_rules)
    ar["batch"] = None if not kept else (kept[0] if len(kept) == 1 else tuple(kept))
    return ShardingRules(dict(rules.param_rules), ar, meta=dict(rules.meta))


# ---------------------------------------------------------------------------
# Shardings from rules
# ---------------------------------------------------------------------------


def _divisible_spec(spec: P, shape: Sequence[int], sizes: Dict[str, int]) -> P:
    """Drop mesh axes from dims they do not divide evenly (per-tensor guard:
    a 2-head KV projection on a 4-way model axis stays unsharded rather than
    forcing GSPMD's padded uneven layout)."""
    entries = []
    for i, entry in enumerate(spec):
        names = list(_rule_axes(entry))
        while names and shape[i] % prod(sizes.get(n, 1) for n in names) != 0:
            names.pop()
        entries.append(None if not names else
                       (names[0] if len(names) == 1 else tuple(names)))
    return P(*entries)


def logical_sharding(
    mesh: Mesh,
    rules: ShardingRules,
    axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    kind: str = "param",
) -> NamedSharding:
    """NamedSharding for one tensor from its logical axes (with the
    per-tensor divisibility guard when ``shape`` is known)."""
    spec = rules.param_spec(axes) if kind == "param" else rules.act_spec(axes)
    if shape is not None:
        spec = _divisible_spec(spec, shape, _axis_sizes(mesh))
    return NamedSharding(mesh, spec)


def param_shardings(mesh: Mesh, rules: ShardingRules, specs: PyTree) -> PyTree:
    """NamedSharding pytree matching a ``ParamSpec`` tree."""
    from repro.models.params import spec_tree_map

    return spec_tree_map(
        lambda _, s: logical_sharding(mesh, rules, s.axes, s.shape, "param"),
        specs,
    )


# ---------------------------------------------------------------------------
# Active-rules context (constrain / active_rule inside model code)
# ---------------------------------------------------------------------------


_CTX = threading.local()


def _active() -> Optional[Tuple[Mesh, ShardingRules]]:
    stack = getattr(_CTX, "stack", None)
    return stack[-1] if stack else None


@contextmanager
def use_mesh_rules(mesh: Mesh, rules: ShardingRules):
    """Activate (mesh, rules) for ``constrain``/``active_rule`` in model code
    traced under this context (trace-time scoping, like the paper's runtime
    carrying the hierarchy through the decomposition)."""
    stack = getattr(_CTX, "stack", None)
    if stack is None:
        stack = _CTX.stack = []
    stack.append((mesh, rules))
    try:
        yield
    finally:
        stack.pop()


def active_rule(logical_axis: str) -> AxisRule:
    """The mesh axes the active rules map ``logical_axis`` to (None outside
    any ``use_mesh_rules`` context or for unmapped axes)."""
    ctx = _active()
    if ctx is None:
        return None
    return ctx[1].act_rules.get(logical_axis)


def active_overlap() -> Optional[Tuple[Mesh, str, str, Tuple[str, ...]]]:
    """The overlap-collectives request of the active rules (DESIGN.md §5).

    Returns ``(mesh, axis, mode, batch_axes)`` when the rules traced under
    ``use_mesh_rules`` carry a ``with_collectives`` request and the ring
    axis actually exists with size > 1; None under GSPMD rules, outside any
    context, or on a degenerate axis.  ``batch_axes`` are the mesh axes the
    activations' batch dim shards over -- ``dist.overlap`` keeps the
    leading matmul dim sharded over them so routing never gathers the
    batch.
    """
    ctx = _active()
    if ctx is None:
        return None
    mesh, rules = ctx
    mode = rules.meta.get("collectives", "gspmd")
    if mode == "gspmd":
        return None
    axis = rules.meta.get("overlap_axis", "model")
    sizes = _axis_sizes(mesh)
    if sizes.get(axis, 1) <= 1:
        return None
    batch = tuple(a for a in _rule_axes(rules.act_rules.get("batch"))
                  if a != axis and sizes.get(a, 1) > 1)
    return mesh, axis, mode, batch


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Pin ``x`` to the sharding its logical axes imply under the active
    rules; the identity outside a ``use_mesh_rules`` context (single-host
    smoke tests run the same model code unsharded)."""
    ctx = _active()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = _divisible_spec(rules.act_spec(axes), x.shape, _axis_sizes(mesh))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
