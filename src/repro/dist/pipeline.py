"""GPipe-style microbatch pipeline over one mesh axis.

The mesh axis is treated as a ring of pipeline stages: stage parameters are
sharded over their leading (stage) dimension, microbatches enter at stage 0
and activations hop one stage per step via ``lax.ppermute``.  With ``M``
microbatches and ``P`` stages the schedule runs ``M + P - 1`` steps -- the
classic GPipe trapezoid -- and every chip computes its stage for a
different microbatch at every interior step, so the per-step permute (one
microbatch of activations over the interconnect) overlaps the stage
compute, the same partition-streaming idea the chip level applies to
HBM->VMEM block copies.  DESIGN.md §5 places this schedule next to the
ring/serpentine collective matmuls of ``dist.overlap``.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def dcn_stages(plan) -> int:
    """Stage count the hierarchical plan's DCN sub-plan prescribes.

    The DCN level's partition count is the host-level decomposition the
    planner chose (``repro.plan``); pipeline stages over the "pod" axis
    realize exactly those partitions.  Returns 1 when the plan is None or
    has no DCN level (single-host meshes).
    """
    lp = plan.level("DCN") if plan is not None else None
    return lp.np if lp is not None else 1


def make_pipeline(mesh: Mesh, stage_fn: Callable[[PyTree, jax.Array], jax.Array],
                  axis: str = "pod", plan: Optional[Any] = None):
    """Build ``fn(stage_params, microbatches) -> outputs`` (DESIGN.md §5).

    ``stage_params`` is a pytree whose leaves carry a leading stage
    dimension equal to the ``axis`` size; ``microbatches`` is an
    ``(n_microbatches, ...)`` stack.  The result equals applying the stages
    sequentially to every microbatch (stage order = position along the mesh
    axis); shapes must be stage-invariant (GPipe homogeneity).  Like the
    ring matmuls, the per-step ``ppermute`` hop is independent of the
    stage compute, so XLA overlaps transfer with work -- the GPipe
    trapezoid is the CC partition stream with stages as partitions.

    ``plan`` (a ``repro.plan.HierarchicalPlan``) maps the stages onto the
    planner's DCN sub-plan: when the plan partitioned the DCN level, the
    mesh axis carrying the stages must realize exactly that partition count
    (a mismatch is a coherence bug -- the state shards the planner sized
    for one host would straddle stage boundaries).
    """
    n_stages = dict(mesh.shape)[axis]
    if plan is not None:
        want = dcn_stages(plan)
        if want > 1 and want != n_stages:
            raise ValueError(
                f"plan's DCN sub-plan prescribes {want} stages but mesh "
                f"axis {axis!r} has {n_stages}")
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def pipe_local(stage_params: PyTree, mbs: jax.Array) -> jax.Array:
        params = jax.tree.map(lambda a: a[0], stage_params)  # my stage's slice
        n_mb = mbs.shape[0]
        idx = jax.lax.axis_index(axis)
        out_struct = jax.eval_shape(stage_fn, params, mbs[0])
        if out_struct.shape != mbs.shape[1:] or out_struct.dtype != mbs.dtype:
            raise ValueError(
                f"stage output {out_struct.shape}/{out_struct.dtype} must "
                f"match microbatch {mbs.shape[1:]}/{mbs.dtype} "
                f"(GPipe homogeneity)")
        outputs0 = jnp.zeros((n_mb,) + out_struct.shape, out_struct.dtype)
        carry0 = jnp.zeros(out_struct.shape, out_struct.dtype)

        def body(t, state):
            carry, outputs = state
            # Stage 0 injects microbatch t; later stages consume the carry
            # their predecessor forwarded last step.
            feed = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(t, 0, n_mb - 1), 0, keepdims=False)
            y = stage_fn(params, jnp.where(idx == 0, feed, carry))
            # The last stage retires microbatch t - (P-1) once it is valid.
            t_out = t - (n_stages - 1)
            is_tail = jnp.logical_and(idx == n_stages - 1,
                                      jnp.logical_and(t_out >= 0, t_out < n_mb))
            slot = jnp.where(is_tail, t_out, n_mb)    # n_mb is OOB -> dropped
            outputs = outputs.at[slot].set(y, mode="drop")
            carry = jax.lax.ppermute(y, axis, perm)
            return carry, outputs

        _, outputs = jax.lax.fori_loop(0, n_mb + n_stages - 1, body,
                                       (carry0, outputs0))
        # Only the tail stage wrote real values; share them with the ring.
        return jax.lax.psum(outputs, axis)

    sharded = shard_map(
        pipe_local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )

    @jax.jit
    def pipeline(stage_params: PyTree, microbatches: jax.Array) -> jax.Array:
        leaves = jax.tree.leaves(stage_params)
        for leaf in leaves:
            if leaf.shape[0] != n_stages:
                raise ValueError(
                    f"leading stage dim {leaf.shape[0]} != mesh axis "
                    f"{axis!r} size {n_stages}")
        return sharded(stage_params, microbatches)

    return pipeline
