"""Distribution layer: the device mesh as the outermost memory level.

The paper decomposes a data-parallel domain against a *hierarchy* of
memories, sizing each partition for the target cache level (TCL).  This
package applies the same machinery one level further out (DESIGN.md §2):

  * ``sharding``  -- logical-axis sharding rules where the FSDP / TP /
    replicated choice is made by ``Decomposer``/``find_optimal_np`` with
    ``phi_mesh`` against the per-chip HBM budget, not by a hard-coded table.
  * ``overlap``   -- ring / serpentine all-gather and reduce-scatter
    matmuls that stream mesh-level partitions over the interconnect while
    the previous one is on the MXU (the CC/SRRC "compute the resident
    partition while fetching the next" idea lifted to the ICI; the
    serpentine mode drives both ICI directions at once -- DESIGN.md §5).
  * ``pipeline``  -- GPipe-style microbatch schedule over a mesh axis.
"""

from repro.dist.overlap import (  # noqa: F401
    RingPlan,
    make_ag_matmul,
    make_rs_matmul,
    overlap_matmul,
    plan_ring,
)
from repro.dist.pipeline import dcn_stages, make_pipeline  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    COLLECTIVES,
    ShardingRules,
    active_overlap,
    active_rule,
    arch_rules,
    constrain,
    default_rules,
    logical_sharding,
    mesh_decomposition,
    mesh_plan,
    param_shardings,
    use_mesh_rules,
    with_batch_guard,
    with_collectives,
)

__all__ = [
    "COLLECTIVES",
    "RingPlan",
    "ShardingRules",
    "active_overlap",
    "active_rule",
    "arch_rules",
    "constrain",
    "dcn_stages",
    "default_rules",
    "logical_sharding",
    "make_ag_matmul",
    "make_pipeline",
    "make_rs_matmul",
    "mesh_decomposition",
    "mesh_plan",
    "overlap_matmul",
    "param_shardings",
    "plan_ring",
    "use_mesh_rules",
    "with_batch_guard",
    "with_collectives",
]
