"""Distribution layer: the device mesh as the outermost memory level.

The paper decomposes a data-parallel domain against a *hierarchy* of
memories, sizing each partition for the target cache level (TCL).  This
package applies the same machinery one level further out (DESIGN.md §2):

  * ``sharding``  -- logical-axis sharding rules where the FSDP / TP /
    replicated choice is made by ``Decomposer``/``find_optimal_np`` with
    ``phi_mesh`` against the per-chip HBM budget, not by a hard-coded table.
  * ``overlap``   -- ring all-gather / reduce-scatter matmuls that stream
    mesh-level partitions over the interconnect while the previous one is on
    the MXU (the CC/SRRC "compute the resident partition while fetching the
    next" idea lifted to the ICI).
  * ``pipeline``  -- GPipe-style microbatch schedule over a mesh axis.
"""

from repro.dist.sharding import (  # noqa: F401
    ShardingRules,
    active_rule,
    arch_rules,
    constrain,
    default_rules,
    logical_sharding,
    mesh_decomposition,
    param_shardings,
    use_mesh_rules,
    with_batch_guard,
)

__all__ = [
    "ShardingRules",
    "active_rule",
    "arch_rules",
    "constrain",
    "default_rules",
    "logical_sharding",
    "mesh_decomposition",
    "param_shardings",
    "use_mesh_rules",
    "with_batch_guard",
]
