"""``repro-obs`` -- the observability layer's command line (DESIGN.md §13).

One subcommand for now:

  repro-obs report [--arch NAME] [--band-lo F] [--band-hi F]
                   [--prompts N] [--new N] [--trace out.json]

Builds a reduced paged engine on the host mesh, runs a small recorded
workload through it, and prints the plan-vs-actual residual table: one
row per level of the decode ``HierarchicalPlan``, pairing the level's
predicted budget (page-table geometry, VMEM working set, HBM prefix
leftover) with the peak the metrics registry actually observed.  A
ratio outside ``[band-lo, band-hi]`` earns a calibration warning
pointing at ``repro.launch.dryrun --calibrate``.

``--trace out.json`` additionally exports the workload's Chrome/Perfetto
trace so a residual can be chased down to the spans that produced it.
"""

from __future__ import annotations

import argparse
import sys

from repro.obs.planview import DEFAULT_BAND, format_report, plan_vs_actual


def _run_workload(arch: str, prompts: int, new: int):
    """A small deterministic paged+prefix workload; returns the engine
    with its registry populated (observed peaks) for the report."""
    import numpy as np

    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config(arch).reduced()
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=new, max_slots=4, max_len=128,
                           batching="paged", prefix_cache="radix"))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, 256, 12, dtype=np.int32)
    reqs = [np.concatenate([shared,
                            rng.integers(0, 256, 4 + i, dtype=np.int32)])
            for i in range(prompts)]
    engine.generate(reqs)
    return engine


def cmd_report(args) -> int:
    band = (args.band_lo, args.band_hi)
    engine = _run_workload(args.arch, args.prompts, args.new)
    rows = plan_vs_actual(engine.plan, engine.obs, band=band)
    print(f"plan-vs-actual: {args.arch} (reduced), "
          f"{args.prompts} prompts x {args.new} new tokens")
    print("\n".join(format_report(rows, band=band)))
    if args.trace:
        engine.tracer.export_chrome(args.trace)
        print(f"trace: {len(engine.tracer.export_events())} events "
              f"-> {args.trace}")
    # Exit nonzero when the acceptance bound itself is violated (pool
    # peak above the plan's page_table budget) -- scriptable in CI.
    for r in rows:
        if r["metric"] == "pool_pages" and r["observed"] is not None \
                and r["predicted"] and r["observed"] > r["predicted"]:
            print("ERROR: observed pool peak exceeds the plan's "
                  "page_table budget", file=sys.stderr)
            return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-obs", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser(
        "report", help="plan-vs-actual residual table for one arch")
    rep.add_argument("--arch", default="llama3.2-1b")
    rep.add_argument("--prompts", type=int, default=3)
    rep.add_argument("--new", type=int, default=6)
    rep.add_argument("--band-lo", type=float, default=DEFAULT_BAND[0])
    rep.add_argument("--band-hi", type=float, default=DEFAULT_BAND[1])
    rep.add_argument("--trace", default="",
                     help="also export the workload's Chrome trace here")
    rep.set_defaults(fn=cmd_report)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
