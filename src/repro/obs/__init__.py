"""repro.obs -- zero-dependency observability spine (DESIGN.md §13).

``metrics``: typed Counter/Gauge/Histogram in a Registry; MetricsView
keeps the legacy ``engine.metrics`` dict API alive over it.
``trace``: span Tracer with Chrome/Perfetto trace_event export and the
RingLog bounded-list policy.
``planview``: plan-vs-actual residual report over a HierarchicalPlan.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsView,
                               Registry, prometheus_lines)
from repro.obs.planview import (DEFAULT_BAND, format_report,
                                plan_vs_actual)
from repro.obs.trace import (RingLog, Tracer, merge_events,
                             validate_events, write_chrome)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsView", "Registry",
    "prometheus_lines",
    "RingLog", "Tracer", "merge_events", "validate_events", "write_chrome",
    "DEFAULT_BAND", "format_report", "plan_vs_actual",
]
