"""Span tracer with Chrome/Perfetto ``trace_event`` JSON export.

DESIGN.md §13.  One ``Tracer`` per engine (``pid`` = replica id) plus
one for the router, all sharing a module-level monotonic epoch -- so a
cluster trace merged with ``merge_events`` shows the whole fleet on a
single timeline.  Request lifecycle rides on ``tid = rid + 1``
(admission -> queue-wait -> prefill-chunk[i] -> first-token -> finish);
engine-wide decode ticks ride on ``tid = 0``; pool alloc/free, prefix
hit/evict, CoW copies and router placements are instant events.

Events live in a bounded ring (old events drop, ``dropped`` counts
them) -- the same policy ``RingLog`` applies to the engine's legacy
``interleave``/``token_times`` metrics, which previously grew without
limit over an engine's lifetime.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterable, Iterator, List, Optional

# Single timeline zero for every tracer in this process: thread-transport
# replicas and the router all subtract the same epoch, so their ``ts``
# values interleave correctly in one exported trace.
_EPOCH = time.monotonic()


class RingLog:
    """A bounded append-only log that quacks like the list it replaced.

    ``maxlen`` caps residency; overflow evicts the oldest entry and
    bumps ``dropped``.  Supports the exact read patterns the benchmark
    harness uses on ``metrics["interleave"]`` / ``metrics["token_times"]``:
    iteration, ``len``, indexing, and list concatenation on either side
    (``[t0] + ring``)."""

    def __init__(self, maxlen: int = 65536,
                 init: Optional[Iterable[Any]] = None) -> None:
        self.maxlen = int(maxlen)
        self._d: deque = deque(maxlen=self.maxlen)
        self.dropped = 0
        for x in init or ():
            self.append(x)

    def append(self, x: Any) -> None:
        if len(self._d) == self.maxlen:
            self.dropped += 1
        self._d.append(x)

    def clear(self) -> None:
        """Drop contents but keep the ``dropped`` count -- recompute
        preemption resets a request's token times without hiding that
        earlier entries were shed."""
        self._d.clear()

    def __iter__(self) -> Iterator[Any]:
        return iter(list(self._d))

    def __len__(self) -> int:
        return len(self._d)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._d)[i]
        return self._d[i]

    def __add__(self, other):
        return list(self._d) + list(other)

    def __radd__(self, other):
        return list(other) + list(self._d)

    def __eq__(self, other):
        return list(self._d) == list(other)

    def __bool__(self) -> bool:
        return bool(self._d)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"RingLog({list(self._d)!r}, dropped={self.dropped})"


class Tracer:
    """Nestable spans + instant events in a bounded ring buffer.

    Emits Chrome ``trace_event`` dicts: ``B``/``E`` pairs from
    ``span()``/``begin()``/``end()``, retroactive ``X`` complete events
    from ``complete()`` (for durations measured across scheduler ticks,
    e.g. queue wait), and ``i`` instants.  Timestamps are microseconds
    relative to the process-wide monotonic epoch."""

    def __init__(self, capacity: int = 65536, pid: int = 0,
                 process_name: Optional[str] = None,
                 enabled: bool = True) -> None:
        self.capacity = int(capacity)
        self.pid = int(pid)
        self.process_name = process_name or f"replica-{self.pid}"
        self.enabled = enabled
        self.dropped = 0
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()

    # -- time ----------------------------------------------------------
    @staticmethod
    def now() -> float:
        """Monotonic seconds; pass these to ``complete``."""
        return time.monotonic()

    @staticmethod
    def _us(t: float) -> float:
        return (t - _EPOCH) * 1e6

    # -- recording -----------------------------------------------------
    def _push(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(ev)

    def _event(self, ph: str, name: str, tid: int, ts: float,
               args: Optional[Dict[str, Any]] = None,
               **extra: Any) -> Dict[str, Any]:
        ev: Dict[str, Any] = {"name": name, "ph": ph, "ts": self._us(ts),
                              "pid": self.pid, "tid": int(tid)}
        if args:
            ev["args"] = dict(args)
        ev.update(extra)
        return ev

    def begin(self, name: str, tid: int = 0,
              args: Optional[Dict[str, Any]] = None) -> None:
        if self.enabled:
            self._push(self._event("B", name, tid, time.monotonic(), args))

    def end(self, name: str, tid: int = 0) -> None:
        if self.enabled:
            self._push(self._event("E", name, tid, time.monotonic()))

    @contextmanager
    def span(self, name: str, tid: int = 0,
             args: Optional[Dict[str, Any]] = None):
        self.begin(name, tid, args)
        try:
            yield self
        finally:
            self.end(name, tid)

    def complete(self, name: str, t_start: float, t_end: float,
                 tid: int = 0,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Retroactive span from monotonic seconds ``t_start``..``t_end``
        (an ``X`` event) -- for durations that close long after they
        open, like queue wait or a whole request lifetime."""
        if self.enabled:
            self._push(self._event("X", name, tid, t_start, args,
                                   dur=max(0.0, (t_end - t_start) * 1e6)))

    def instant(self, name: str, tid: int = 0,
                args: Optional[Dict[str, Any]] = None) -> None:
        if self.enabled:
            self._push(self._event("i", name, tid, time.monotonic(), args,
                                   s="t"))

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.dropped = 0

    # -- export --------------------------------------------------------
    def export_events(self, last: Optional[int] = None) -> List[Dict[str, Any]]:
        """The recorded events (oldest first), optionally only the last
        ``last`` of them."""
        with self._lock:
            evs = list(self._ring)
        if last is not None and last >= 0:
            evs = evs[-last:]
        return evs

    def metadata_events(self) -> List[Dict[str, Any]]:
        return [{"name": "process_name", "ph": "M", "pid": self.pid,
                 "tid": 0, "args": {"name": self.process_name}}]

    def chrome_events(self, last: Optional[int] = None) -> List[Dict[str, Any]]:
        return self.metadata_events() + self.export_events(last)

    def export_chrome(self, path: str, last: Optional[int] = None) -> str:
        """Write a Chrome/Perfetto-loadable JSON trace; returns path."""
        return write_chrome(path, self.chrome_events(last))


def merge_events(*event_lists: Iterable[Dict[str, Any]]
                 ) -> List[Dict[str, Any]]:
    """Merge per-tracer event lists onto one timeline.  Metadata events
    lead; the rest sort by timestamp (stable, so B/E order within one
    tracer's equal-ts events survives)."""
    meta: List[Dict[str, Any]] = []
    evs: List[Dict[str, Any]] = []
    for lst in event_lists:
        for ev in lst:
            (meta if ev.get("ph") == "M" else evs).append(ev)
    evs.sort(key=lambda e: e.get("ts", 0.0))
    return meta + evs


def write_chrome(path: str, events: List[Dict[str, Any]]) -> str:
    with open(path, "w") as f:
        json.dump({"traceEvents": list(events), "displayTimeUnit": "ms"},
                  f, indent=None, separators=(",", ":"))
    return path


def validate_events(events: Iterable[Dict[str, Any]]) -> List[str]:
    """Schema check for exported events; returns a list of problems
    (empty == loadable).  Used by the ``--only obs --dry`` CI gate and
    the export tests: required keys, known phases, non-negative
    relative timestamps/durations, balanced well-nested B/E per
    (pid, tid) in record order."""
    problems: List[str] = []
    stacks: Dict[tuple, List[str]] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            if "name" not in ev or "args" not in ev:
                problems.append(f"event {i}: metadata missing name/args")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        if ph not in ("B", "E", "X", "i"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X without dur")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            problems.append(f"event {i}: instant without scope")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append(ev.get("name", ""))
        elif ph == "E":
            stack = stacks.setdefault(key, [])
            if not stack:
                problems.append(f"event {i}: E without open B on {key}")
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            problems.append(f"unclosed spans on {key}: {stack}")
    return problems
