"""Typed metrics spine: Counter / Gauge / Histogram in a Registry.

DESIGN.md §13.  The serving layers (``ServeEngine``, ``PagePool``,
``RadixPrefixCache``, ``Router``) all write into one ``Registry`` per
engine/router instead of ad-hoc ``self.metrics`` dicts.  The old dict
API survives as ``MetricsView`` -- a MutableMapping over the registry
plus a side table for the non-scalar entries (``batching``,
``interleave``, ``token_times``, ...) -- so every existing consumer
(``benchmarks/run.py``, launcher printouts, the cluster tests) keeps
reading the keys it always read.

Zero dependencies: histograms are fixed log-spaced buckets (bounds
``lo * growth**i``), so ``percentile(p)`` is a cumulative-count walk
with relative error bounded by ``growth``; the Prometheus text
exposition is hand-rolled (format version 0.0.4).
"""

from __future__ import annotations

import bisect
import math
import re
import threading
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

try:  # pragma: no cover - py<3.9 fallback never hit in-repo
    from collections.abc import MutableMapping
except ImportError:  # pragma: no cover
    from collections import MutableMapping  # type: ignore


class Counter:
    """Monotonic count.  ``inc`` by a negative amount raises -- that is
    the satellite fix for ``metrics["tokens"]`` going transiently
    negative on recompute preemption: preempted work moves into its own
    ``tokens_recomputed`` counter instead of subtracting."""

    kind = "counter"

    def __init__(self, name: str, unit: str = "") -> None:
        self.name = name
        self.unit = unit
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r}: negative inc {n}")
        self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {self.name: self.value}


class Gauge:
    """Point-in-time value; ``set_max`` tracks peaks (pool occupancy,
    resident bytes) without a separate high-watermark variable."""

    kind = "gauge"

    def __init__(self, name: str, unit: str = "") -> None:
        self.name = name
        self.unit = unit
        self.value: Any = 0

    def set(self, v: Any) -> None:
        self.value = v

    def add(self, v: float) -> None:
        self.value = (self.value or 0) + v

    def set_max(self, v: float) -> None:
        cur = self.value
        if not isinstance(cur, (int, float)) or v > cur:
            self.value = v

    def snapshot(self) -> Dict[str, Any]:
        return {self.name: self.value}


class Histogram:
    """Fixed log-bucket latency histogram.

    Bucket ``i`` holds values in ``(lo*g**(i-1), lo*g**i]``; values
    below ``lo`` land in bucket 0, values above ``hi`` in the overflow
    bucket.  ``percentile`` returns the upper bound of the bucket that
    contains the rank-``ceil(q*count)`` observation, so against a
    sorted-list oracle the relative error is at most ``growth`` for
    in-range values (tested in tests/test_obs.py)."""

    kind = "histogram"

    def __init__(self, name: str, unit: str = "s", lo: float = 1e-6,
                 hi: float = 1e3, growth: float = 2.0 ** 0.25) -> None:
        if not (lo > 0 and hi > lo and growth > 1.0):
            raise ValueError("histogram needs lo>0, hi>lo, growth>1")
        self.name = name
        self.unit = unit
        self.lo = lo
        self.growth = growth
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        self.bounds: List[float] = [lo * growth ** i for i in range(n + 1)]
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        # smallest i with bounds[i] >= v; v past the last bound lands in
        # the overflow bucket (index len(bounds)).
        self.counts[bisect.bisect_left(self.bounds, v)] += 1

    def percentile(self, p: float) -> float:
        """p in [0, 100].  Returns 0.0 when empty."""
        if self.count == 0:
            return 0.0
        rank = max(1, int(math.ceil(p / 100.0 * self.count)))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                if i < len(self.bounds):
                    return self.bounds[i]
                return self.max  # overflow bucket: best bound we have
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            f"{self.name}.count": self.count,
            f"{self.name}.mean": self.mean,
            f"{self.name}.p50": self.percentile(50),
            f"{self.name}.p95": self.percentile(95),
            f"{self.name}.p99": self.percentile(99),
        }


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    return "repro_" + _PROM_BAD.sub("_", name)


def _prom_labels(labels: Optional[Mapping[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def prometheus_lines(values: Mapping[str, Any],
                     labels: Optional[Mapping[str, str]] = None) -> List[str]:
    """Text-exposition lines for a flat name->scalar mapping (e.g. a
    remote replica's ``Registry.snapshot()`` forwarded in ReplicaStats).
    Non-numeric values are skipped."""
    out: List[str] = []
    lab = _prom_labels(labels)
    for name in sorted(values):
        v = values[name]
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        if isinstance(v, float) and not math.isfinite(v):
            continue
        out.append(f"{_prom_name(name)}{lab} {v}")
    return out


class Registry:
    """Ordered, lazily-created instruments keyed by name.

    ``counter``/``gauge``/``histogram`` are get-or-create (a name keeps
    its first type; asking for the same name as a different type
    raises).  ``snapshot()`` flattens to a JSON-/pickle-safe dict --
    the exact payload ``ReplicaStats.metrics`` carries over the cluster
    transports."""

    def __init__(self) -> None:
        self._m: "Dict[str, Any]" = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, **kw):
        with self._lock:
            m = self._m.get(name)
            if m is None:
                m = self._m[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is a {m.kind}, "
                                f"not a {cls.__name__.lower()}")
            return m

    def counter(self, name: str, unit: str = "") -> Counter:
        return self._get_or_create(name, Counter, unit=unit)

    def gauge(self, name: str, unit: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, unit=unit)

    def histogram(self, name: str, unit: str = "s", **kw) -> Histogram:
        return self._get_or_create(name, Histogram, unit=unit, **kw)

    # -- convenience write paths (create-on-first-use) -----------------
    def inc(self, name: str, n: int = 1, unit: str = "") -> None:
        self.counter(name, unit=unit).inc(n)

    def set(self, name: str, v: Any, unit: str = "") -> None:
        self.gauge(name, unit=unit).set(v)

    def set_max(self, name: str, v: float, unit: str = "") -> None:
        self.gauge(name, unit=unit).set_max(v)

    def observe(self, name: str, v: float, unit: str = "s") -> None:
        self.histogram(name, unit=unit).observe(v)

    # -- read paths ----------------------------------------------------
    def get(self, name: str):
        return self._m.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._m

    def names(self) -> List[str]:
        return list(self._m)

    def value(self, name: str, default: Any = None) -> Any:
        m = self._m.get(name)
        if m is None:
            return default
        if isinstance(m, Histogram):
            return m.count
        return m.value

    def remove(self, name: str) -> None:
        with self._lock:
            self._m.pop(name, None)

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for m in list(self._m.values()):
            out.update(m.snapshot())
        return out

    def to_prometheus(self,
                      labels: Optional[Mapping[str, str]] = None) -> str:
        """Prometheus text exposition (version 0.0.4) with TYPE hints."""
        lines: List[str] = []
        lab = _prom_labels(labels)
        for name in sorted(self._m):
            m = self._m[name]
            pname = _prom_name(name)
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {pname} summary")
                for k, v in m.snapshot().items():
                    lines.extend(prometheus_lines({k: v}, labels))
                continue
            v = m.value
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            lines.append(f"# TYPE {pname} {m.kind}")
            lines.append(f"{pname}{lab} {v}")
        return "\n".join(lines) + "\n"

    def format_table(self) -> str:
        """Sorted ``name value [unit]`` lines -- what ``repro-serve
        --stats`` prints, identical across cohort/paged/cluster modes."""
        snap = self.snapshot()
        units = {}
        for name, m in self._m.items():
            if isinstance(m, Histogram):
                for k in m.snapshot():
                    units[k] = m.unit if k.endswith(("mean", "p50", "p95",
                                                    "p99")) else ""
            else:
                units[name] = m.unit
        width = max((len(k) for k in snap), default=0)
        lines = []
        for k in sorted(snap):
            v = snap[k]
            if isinstance(v, float):
                v = f"{v:.6g}"
            u = units.get(k, "")
            lines.append(f"{k:<{width}}  {v}" + (f" {u}" if u else ""))
        return "\n".join(lines)


_SCALAR = (bool, int, float)


class MetricsView(MutableMapping):
    """The legacy ``engine.metrics`` dict API over a ``Registry``.

    Scalar keys live in the registry (counters keep monotonic
    semantics: ``m["evictions"] += 1`` becomes an ``inc`` by the
    delta); everything else -- batching strings, the plan_page_table
    dict, the interleave/token_times ring logs -- lives in a side
    ``objects`` table.  ``dict(engine.metrics)`` and every ``.get``
    site in benchmarks/ and launch/ behave exactly as before."""

    def __init__(self, registry: Registry,
                 objects: Optional[Dict[str, Any]] = None) -> None:
        self.registry = registry
        self.objects: Dict[str, Any] = dict(objects or {})

    def __getitem__(self, key: str) -> Any:
        if key in self.registry:
            return self.registry.value(key)
        if key in self.objects:
            return self.objects[key]
        raise KeyError(key)

    def __setitem__(self, key: str, value: Any) -> None:
        m = self.registry.get(key)
        if m is not None:
            if isinstance(m, Counter):
                if not isinstance(value, _SCALAR):
                    raise TypeError(f"counter {key!r} takes numbers")
                m.inc(int(value) - m.value)  # += path; negative raises
            elif isinstance(m, Gauge):
                m.set(value)
            else:
                raise TypeError(f"cannot assign histogram {key!r}")
            return
        if isinstance(value, _SCALAR) and not isinstance(value, bool):
            self.registry.set(key, value)
        else:
            self.objects[key] = value

    def __delitem__(self, key: str) -> None:
        if key in self.registry:
            self.registry.remove(key)
        else:
            del self.objects[key]

    def __iter__(self) -> Iterator[str]:
        seen = set()
        for k in self.registry.names():
            seen.add(k)
            yield k
        for k in self.objects:
            if k not in seen:
                yield k

    def __len__(self) -> int:
        return len(set(self.registry.names()) | set(self.objects))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"MetricsView({dict(self)!r})"
