"""Plan-vs-actual accounting: did the decomposition the planner
predicted match what the runtime observed?

DESIGN.md §13.  ``plan_vs_actual(plan, registry)`` walks the
``HierarchicalPlan`` levels top-down and pairs each level's predicted
budget with the observed peak from the engine's metrics registry:

  DCN  [mesh]  fleet width        plan ``np``  vs  replicas stood up
  ICI  [mesh]  HBM prefix leftover ``plan.prefix_budget()`` vs the
               radix cache's peak resident bytes
  VMEM [page]  two rows: the page_table's ``pages_total`` vs the pool's
               peak live pages (the acceptance bound: observed peak
               must land inside the planned pool), and the VMEM budget
               vs the double-buffered page working set
  leaf [VREG]  realized per-worker partition vs the register budget
               (plan-side -- the leaf has no runtime counter)

Each row carries ``ratio = observed / predicted``; a ratio outside the
configurable band prints a calibration warning pointing at
``launch/dryrun.py --calibrate`` (the planner's overhead terms are
fitted artifacts -- a systematic residual means the fit is stale).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

#: Default acceptance band for observed/predicted.  The lower edge is 0
#: because under-use is normal at reduced scale (a 3-request demo never
#: fills a 16 GiB HBM budget); the upper edge flags the planner
#: UNDER-predicting, which is the dangerous direction.
DEFAULT_BAND = (0.0, 1.0)

CALIBRATE_HINT = ("plan-vs-actual residual outside band -- the planner's "
                  "fitted overhead terms may be stale; re-run "
                  "`python -m repro.launch.dryrun --calibrate`")


def _row(level: str, kind: str, metric: str, predicted, observed,
         unit: str, src: str, band) -> Dict[str, Any]:
    ratio: Optional[float] = None
    if predicted is not None and observed is not None:
        p = float(predicted)
        o = float(observed)
        ratio = (o / p) if p else (0.0 if o == 0 else math.inf)
    within = (ratio is not None and math.isfinite(ratio)
              and band[0] <= ratio <= band[1])
    return {"level": level, "kind": kind, "metric": metric,
            "predicted": predicted, "observed": observed, "unit": unit,
            "ratio": ratio, "within_band": within, "src": src}


def plan_vs_actual(plan, registry, band=DEFAULT_BAND,
                   fleet: Optional[int] = None) -> List[Dict[str, Any]]:
    """One (or two, for the page level) residual rows per plan level.

    ``registry`` is the engine's ``Registry`` (or any object with a
    compatible ``.value(name, default)``); ``fleet`` is the observed
    replica count for cluster runs (single-engine plans have no DCN
    level, so it usually stays None)."""
    rows: List[Dict[str, Any]] = []
    val = registry.value
    pt = dict(plan.page_table() or {})
    for lp in plan.levels():
        if lp.kind == "mesh" and lp.level == "DCN":
            observed = fleet if fleet is not None \
                else val("fleet_replicas", None)
            rows.append(_row(lp.level, lp.kind, "fleet_replicas",
                             lp.np, observed, "replicas", "runtime", band))
        elif lp.kind == "mesh":
            # Mesh-level HBM leftover: what the planner set aside for
            # cached prefixes after weights + live KV (DESIGN.md §11).
            predicted = plan.prefix_budget() or lp.budget_bytes
            observed = val("prefix_peak_resident_bytes",
                           val("prefix_resident_bytes", 0))
            rows.append(_row(lp.level, lp.kind, "hbm_prefix_leftover",
                             predicted, observed, "B", "runtime", band))
        elif lp.kind == "page":
            # The acceptance bound: peak live pages inside the planned
            # pool.  pages_total is the physical pool the plan sized.
            predicted = pt.get("pages_total")
            observed = val("pool_peak_pages", val("peak_pages", 0))
            rows.append(_row(lp.level, lp.kind, "pool_pages",
                             predicted, observed, "pages", "runtime", band))
            # And the working set the page was sized for: the planner
            # guarantees PAGE_BUFFERING * page_bytes <= VMEM budget.
            try:
                from repro.core.plan import PAGE_BUFFERING
            except ImportError:  # pragma: no cover
                PAGE_BUFFERING = 2
            page_bytes = val("page_bytes", None)
            observed_ws = (PAGE_BUFFERING * page_bytes
                           if page_bytes else None)
            rows.append(_row(lp.level, lp.kind, "vmem_working_set",
                             lp.budget_bytes, observed_ws, "B",
                             "runtime", band))
        elif lp.kind == "leaf":
            # No runtime counter at register granularity; the residual
            # is the planner's own realized per-worker partition against
            # the register budget (<= budget whenever the level fits).
            rows.append(_row(lp.level, lp.kind, "leaf_partition",
                             lp.budget_bytes, lp.partition_bytes or 0.0,
                             "B", "plan", band))
        else:
            rows.append(_row(lp.level, lp.kind, "budget",
                             lp.budget_bytes, lp.partition_bytes or None,
                             "B", "plan", band))
    return rows


def format_report(rows: List[Dict[str, Any]],
                  band=DEFAULT_BAND) -> List[str]:
    """Printable report; appends the calibration hint when any row's
    ratio leaves the band."""
    lines = [f"{'level':<6} {'kind':<5} {'metric':<20} "
             f"{'predicted':>14} {'observed':>14} {'ratio':>8}  unit"]
    warn = False
    for r in rows:
        pred = _fmt(r["predicted"])
        obs = _fmt(r["observed"])
        ratio = "n/a" if r["ratio"] is None else f"{r['ratio']:.4f}"
        mark = ""
        if r["ratio"] is not None and not r["within_band"]:
            mark = "  <-- outside band"
            warn = True
        lines.append(f"{r['level']:<6} {r['kind']:<5} {r['metric']:<20} "
                     f"{pred:>14} {obs:>14} {ratio:>8}  {r['unit']}{mark}")
    if warn:
        lines.append(f"WARNING: {CALIBRATE_HINT} "
                     f"(band {band[0]:g}..{band[1]:g})")
    return lines


def _fmt(v) -> str:
    if v is None:
        return "n/a"
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.3g}"
    return str(int(v))
