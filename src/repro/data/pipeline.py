"""Deterministic, resumable, sharded data pipeline.

Design constraints at 1000+ nodes:

  * **Stateless resumability**: batch ``i`` is a pure function of
    ``(seed, i)`` -- a restarted (or elastically resized) job resumes from
    the checkpointed step with zero pipeline state to restore, and a
    straggling host can be replaced mid-run without coordination.
  * **Per-host sharding**: each host materializes only its slice of the
    global batch (``host_slice``); the global batch is assembled by the
    runtime's sharding, never on one host.
  * **Prefetch**: a background thread keeps ``prefetch`` batches ready so
    host-side generation overlaps device compute.

The dataset here is synthetic (seeded token streams with a repeating-ngram
structure so the LM loss actually decreases); swapping in a real tokenized
corpus only requires another ``__getitem__``.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class SyntheticLMDataset:
    """Deterministic synthetic LM tokens: batch i == f(seed, i)."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    ngram: int = 8

    def batch(self, index: int, batch_size: int) -> Dict[str, np.ndarray]:
        # A FIXED n-gram pool (function of seed only) gives the model stable
        # statistics to learn; batch composition varies with the index.
        pool_rng = np.random.default_rng(np.random.SeedSequence([self.seed]))
        pool = pool_rng.integers(1, self.vocab_size,
                                 size=(64, self.ngram), dtype=np.int32)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index]))
        picks = rng.integers(0, 64, size=(batch_size,
                                          self.seq_len // self.ngram + 2))
        toks = pool[picks].reshape(batch_size, -1)[:, : self.seq_len + 1]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class DataPipeline:
    """Per-host sharded, prefetching iterator over a dataset."""

    def __init__(
        self,
        dataset: SyntheticLMDataset,
        global_batch: int,
        host_index: int = 0,
        host_count: int = 1,
        start_step: int = 0,
        prefetch: int = 2,
        extra_builder=None,          # fn(host_batch) -> dict (vlm/enc_dec stubs)
    ) -> None:
        assert global_batch % host_count == 0, (global_batch, host_count)
        self.dataset = dataset
        self.global_batch = global_batch
        self.host_batch = global_batch // host_count
        self.host_index = host_index
        self.host_count = host_count
        self.step = start_step
        self.extra_builder = extra_builder
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        full = self.dataset.batch(step, self.global_batch)
        lo = self.host_index * self.host_batch
        hi = lo + self.host_batch
        host = {k: v[lo:hi] for k, v in full.items()}
        if self.extra_builder is not None:
            host = self.extra_builder(host)
        return host

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        return self

    def __next__(self) -> Tuple[int, Dict[str, np.ndarray]]:
        return self._q.get()

    def peek_step(self, step: int) -> Dict[str, np.ndarray]:
        """Random access (used by tests + straggler replacement)."""
        return self._make(step)

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
