from repro.data.pipeline import DataPipeline, SyntheticLMDataset

__all__ = ["DataPipeline", "SyntheticLMDataset"]
