"""``repro.plan`` -- the recursive planner API (see ``repro.core.plan``).

Import surface for consumers::

    from repro.plan import HierarchicalPlan, PlanPolicy, Workload, plan_run

The implementation lives in ``repro.core.plan`` next to the rest of the
paper machinery; this module is the stable, documented entry point.
"""

from repro.core.plan import (  # noqa: F401
    MESH_LEVEL_NAMES,
    HierarchicalPlan,
    LevelPlan,
    PlanPolicy,
    Workload,
    leaf_matmul_plan,
    plan_run,
    quantize_divisor,
)

__all__ = [
    "MESH_LEVEL_NAMES",
    "HierarchicalPlan",
    "LevelPlan",
    "PlanPolicy",
    "Workload",
    "leaf_matmul_plan",
    "plan_run",
    "quantize_divisor",
]
