"""Paper benchmark suite (Tables 3-5, Figs. 9-10) on this container's REAL
cache hierarchy, detected with the paper's own sysfs tool (§3.1).

Each benchmark applies the same per-partition computation under both
decompositions:

  * ``horizontal``       -- np == nWorkers (the paper's baseline)
  * ``cache_conscious``  -- np from Algorithm 1 + binary search vs the TCL

Inner kernels are deliberately cache-naive where the paper's were
(``np.einsum(..., optimize=False)`` is a plain C triple loop, like the
Java loops of the original): the paper's claim is precisely that run-time
decomposition rescues cache-neglectful execution. Container caveat recorded
in EXPERIMENTS.md: 1 hardware core, so the *shared-cache contention* part of
the paper's gains (SRRC's raison d'etre) cannot manifest; the
capacity-miss/temporal-locality part does.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    Array1DDistribution,
    Array2DBlockDistribution,
    Decomposer,
    Engine,
    StencilDistribution,
    matmul_domain,
    matmul_task_grid,
    read_linux_hierarchy,
)
from repro.core.decompose import phi_simple
from repro.core.engine import StageTimes


def _hierarchy():
    try:
        return read_linux_hierarchy()
    except Exception:
        from repro.core import paper_system_a
        return paper_system_a()


HIER = _hierarchy()


def _time(fn: Callable[[], None], repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


@dataclass
class BenchResult:
    name: str
    cc_s: float
    hz_s: float
    np_cc: int
    n_tasks: int
    times: Optional[StageTimes] = None

    @property
    def speedup(self) -> float:
        return self.hz_s / self.cc_s if self.cc_s else 0.0

    def csv(self) -> str:
        return (f"{self.name},{self.cc_s * 1e6:.0f},"
                f"speedup_vs_horizontal={self.speedup:.2f};np={self.np_cc};"
                f"tasks={self.n_tasks}")


# ---------------------------------------------------------------------------
# MatMult (naive einsum inner kernel)
# ---------------------------------------------------------------------------

def _matmul_run(n: int, tcl, schedule: str, strategy: str,
                repeats: int = 2) -> Tuple[float, int, int, StageTimes]:
    rng = np.random.default_rng(0)
    A = rng.standard_normal((n, n)).astype(np.float32)
    B = rng.standard_normal((n, n)).astype(np.float32)
    eng = Engine(HIER, n_workers=1, tcl=tcl, schedule=schedule,
                 strategy=strategy, parallel=False)
    domain = matmul_domain(n, n, n, 4)

    best, np_, ntasks, times = float("inf"), 0, 0, None
    for _ in range(repeats):
        C = np.zeros((n, n), np.float32)

        def make_tasks(plan):
            a_regions, b_regions, c_regions = plan.regions
            side = round(math.sqrt(plan.np))
            return [
                (a_regions[i * side + kk], b_regions[kk * side + j],
                 c_regions[i * side + j])
                for (i, j, kk) in matmul_task_grid(plan.np)
            ]

        def compute(task):
            a_reg, b_reg, c_reg = task
            C[c_reg] += np.einsum("ik,kj->ij", A[a_reg], B[b_reg],
                                  optimize=False)

        res = eng.run(domain, compute, make_tasks=make_tasks)
        dt = res.times.total
        if dt < best:
            best, np_, ntasks, times = dt, res.np, res.n_tasks, res.times
    return best, np_, ntasks, times


def bench_matmult(n: int = 512, tcl="L1", schedule: str = "cc") -> BenchResult:
    cc, np_cc, ntasks, times = _matmul_run(n, tcl, schedule, "cache_conscious")
    hz, _, _, _ = _matmul_run(n, tcl, schedule, "horizontal")
    return BenchResult(f"matmult_{n}", cc, hz, np_cc, ntasks, times)


# ---------------------------------------------------------------------------
# MatTrans
# ---------------------------------------------------------------------------

def bench_mattrans(n: int = 4096, tcl="L1") -> BenchResult:
    rng = np.random.default_rng(1)
    A = rng.standard_normal((n, n)).astype(np.float32)
    out = np.zeros((n, n), np.float32)
    domain = [Array2DBlockDistribution(n, n, 4)]

    def run(strategy):
        eng = Engine(HIER, n_workers=1, tcl=tcl, strategy=strategy,
                     parallel=False)

        def compute(task):
            ((rs, cs),) = task
            out[cs.start:cs.stop, rs.start:rs.stop] = A[rs, cs].T

        return eng.run(domain, compute)

    r_cc = run("cache_conscious")
    cc = _time(lambda: run("cache_conscious"), 2)
    hz = _time(lambda: run("horizontal"), 2)
    return BenchResult(f"mattrans_{n}", cc, hz, r_cc.np, r_cc.n_tasks)


# ---------------------------------------------------------------------------
# GaussianBlur (box-weighted separable-free 2D accumulation, halo reads)
# ---------------------------------------------------------------------------

def bench_gaussianblur(n: int = 2048, radius: int = 5, tcl="L1") -> BenchResult:
    rng = np.random.default_rng(2)
    img = rng.standard_normal((n, n)).astype(np.float32)
    pad = np.pad(img, radius, mode="edge")
    out = np.zeros((n, n), np.float32)
    r = radius
    offs = [(dr, dc) for dr in range(-r, r + 1) for dc in range(-r, r + 1)]
    w = np.array([math.exp(-(dr * dr + dc * dc) / (2.0 * (r / 2) ** 2))
                  for dr, dc in offs], np.float32)
    w /= w.sum()
    d = StencilDistribution(n, n, 4, halo=r)

    def run(strategy):
        eng = Engine(HIER, n_workers=1, tcl=tcl, strategy=strategy,
                     parallel=False)

        def compute(task):
            ((rs, cs),) = task
            h, wd = rs.stop - rs.start, cs.stop - cs.start
            acc = np.zeros((h, wd), np.float32)
            for wi, (dr, dc) in enumerate(offs):
                acc += w[wi] * pad[rs.start + r + dr: rs.stop + r + dr,
                                   cs.start + r + dc: cs.stop + r + dc]
            out[rs, cs] = acc

        return eng.run([d], compute)

    r_cc = run("cache_conscious")
    cc = _time(lambda: run("cache_conscious"), 2)
    hz = _time(lambda: run("horizontal"), 2)
    return BenchResult(f"gaussianblur_{n}-{radius}", cc, hz, r_cc.np,
                       r_cc.n_tasks)


# ---------------------------------------------------------------------------
# SOR (5-point Jacobi sweeps)
# ---------------------------------------------------------------------------

def bench_sor(n: int = 2048, sweeps: int = 4, tcl="L1") -> BenchResult:
    rng = np.random.default_rng(3)
    grid = rng.standard_normal((n, n)).astype(np.float32)
    d = StencilDistribution(n, n, 4, halo=1)
    omega = np.float32(1.25)

    def run(strategy):
        eng = Engine(HIER, n_workers=1, tcl=tcl, strategy=strategy,
                     parallel=False)
        cur = grid.copy()

        def one_sweep(_):
            pad = np.pad(cur, 1, mode="edge")

            def compute(task):
                ((rs, cs),) = task
                blk = 0.25 * (
                    pad[rs.start: rs.stop, cs.start + 1: cs.stop + 1]
                    + pad[rs.start + 2: rs.stop + 2, cs.start + 1: cs.stop + 1]
                    + pad[rs.start + 1: rs.stop + 1, cs.start: cs.stop]
                    + pad[rs.start + 1: rs.stop + 1, cs.start + 2: cs.stop + 2])
                cur[rs, cs] = (1 - omega) * cur[rs, cs] + omega * blk

            return eng.run([d], compute)

        res = None
        for s in range(sweeps):
            res = one_sweep(s)
        return res

    r_cc = run("cache_conscious")
    cc = _time(lambda: run("cache_conscious"), 2)
    hz = _time(lambda: run("horizontal"), 2)
    return BenchResult(f"sor_{n}", cc, hz, r_cc.np, r_cc.n_tasks)


# ---------------------------------------------------------------------------
# Table 4 group: Crypt / Series / WordCount (no temporal locality)
# ---------------------------------------------------------------------------

def bench_crypt(mb: int = 16, tcl="L1") -> BenchResult:
    rng = np.random.default_rng(4)
    data = rng.integers(0, 256, mb << 20, dtype=np.uint8)
    key = rng.integers(0, 256, 64, dtype=np.uint8)
    out = np.zeros_like(data)
    d = Array1DDistribution(len(data), 1, indivisible=64)

    def run(strategy):
        eng = Engine(HIER, n_workers=1, tcl=tcl, strategy=strategy,
                     parallel=False)

        def compute(task):
            ((sl,),) = task
            seg = data[sl]
            out[sl] = seg ^ np.resize(key, len(seg))

        return eng.run([d], compute)

    r_cc = run("cache_conscious")
    cc = _time(lambda: run("cache_conscious"), 2)
    hz = _time(lambda: run("horizontal"), 2)
    return BenchResult(f"crypt_{mb}MB", cc, hz, r_cc.np, r_cc.n_tasks)


def bench_series(n: int = 20000, tcl="L1") -> BenchResult:
    # First n Fourier coefficients of f(x) = (x+1)^x on [0, 2].
    xs = np.linspace(1e-6, 2.0, 512)
    fx = np.power(xs + 1.0, xs)
    d = Array1DDistribution(n, 8)
    coeffs = np.zeros(n)

    def run(strategy):
        eng = Engine(HIER, n_workers=1, tcl=tcl, strategy=strategy,
                     parallel=False)

        def compute(task):
            ((sl,),) = task
            ks = np.arange(sl.start + 1, sl.stop + 1)[:, None]
            coeffs[sl] = np.trapezoid(fx * np.cos(math.pi * ks * xs), xs,
                                      axis=1)

        return eng.run([d], compute)

    r_cc = run("cache_conscious")
    cc = _time(lambda: run("cache_conscious"), 2)
    hz = _time(lambda: run("horizontal"), 2)
    return BenchResult(f"series_{n}", cc, hz, r_cc.np, r_cc.n_tasks)


def bench_wordcount(mb: int = 8, vocab: int = 50000, tcl="L1") -> BenchResult:
    # As in the paper (§4.4.1): a SHARED count map updated by the workers;
    # its random access pattern defeats cache-conscious placement, so the
    # expected result is parity (Table 4).
    rng = np.random.default_rng(5)
    tokens = rng.integers(0, vocab, (mb << 20) // 4, dtype=np.int32)
    d = Array1DDistribution(len(tokens), 4)

    def run(strategy):
        eng = Engine(HIER, n_workers=1, tcl=tcl, strategy=strategy,
                     parallel=False)
        counts = np.zeros(vocab, np.int64)

        def compute(task):
            ((sl,),) = task
            np.add.at(counts, tokens[sl], 1)

        return eng.run([d], compute)

    r_cc = run("cache_conscious")
    cc = _time(lambda: run("cache_conscious"), 2)
    hz = _time(lambda: run("horizontal"), 2)
    return BenchResult(f"wordcount_{mb}MB", cc, hz, r_cc.np, r_cc.n_tasks)


# ---------------------------------------------------------------------------
# Table 5 / Fig. 9: TCL sensitivity sweep
# ---------------------------------------------------------------------------

def tcl_sweep_matmult(n: int = 512,
                      tcls: Optional[List[int]] = None) -> Dict[int, float]:
    l1 = HIER.find("L1").size if HIER.find("L1") else 49152
    l2 = HIER.find("L2").size if HIER.find("L2") else 2 << 20
    tcls = tcls or [l1 // 2, l1, 2 * l1, 4 * l1, l2 // 4, l2, 4 * l2]
    out = {}
    for tcl in tcls:
        t, np_, _, _ = _matmul_run(n, int(tcl), "cc", "cache_conscious",
                                   repeats=2)
        out[int(tcl)] = t
    return out
