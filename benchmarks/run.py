"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  table3   -- temporal-locality benchmarks, CC vs horizontal (Table 3)
  table4   -- no-temporal-locality group: overhead parity check (Table 4)
  table5   -- TCL-size sensitivity sweep (Table 5 / Fig. 9)
  fig10    -- per-stage breakdown of MatMult (Fig. 10)
  fig11    -- cluster-level scaling model (Fig. 11)
  roofline -- §Roofline summary of every dry-run cell (single-pod)
  plans    -- decomposer tile plans for the TPU kernels (DESIGN.md §2)
  collectives -- A/B per-step timings of the overlap layer's matmuls
             (gspmd vs ring vs serpentine, DESIGN.md §5; needs >= 2
             devices -- force them with
             XLA_FLAGS=--xla_force_host_platform_device_count=4)
  serve    -- tok/s of the plan-driven serving engine (repro.serve) and
             planned-vs-naive KV page sizes; with --dry, the decode plan
             tree + the DCN-free / VMEM-fit assertions CI greps
             (DESIGN.md §7)
  paged    -- tok/s + slot-utilization A/B of the paged page-pool engine
             vs the cohort baseline on a mixed-length trace; with --dry,
             the pool-geometry-matches-page_plan assertion CI greps
             (DESIGN.md §8)
  prefill  -- TTFT + decode-stall A/B of chunked vs monolithic prefill
             (a long prompt backfilling while a resident slot decodes);
             with --dry, the chunk-equals-planned-page assertion CI
             greps (DESIGN.md §10)

Usage: ``python -m benchmarks.run [--quick] [--only table3,roofline]
                                  [--collectives gspmd|ring|serpentine]``

``--collectives`` with ``--dry`` prints the plan-time ring schedule (one
line per step showing the ppermute(s) it issues -- both directions under
serpentine) and, when devices allow, the collective-permute count of the
lowered HLO.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def table3(quick: bool) -> list:
    from benchmarks.paper_cpu import (
        bench_gaussianblur,
        bench_matmult,
        bench_mattrans,
        bench_sor,
    )

    out = []
    out.append(bench_matmult(n=512 if quick else 768, tcl="L2").csv())
    out.append(bench_mattrans(n=2048 if quick else 4096).csv())
    out.append(bench_gaussianblur(n=1024 if quick else 2048,
                                  radius=5).csv())
    out.append(bench_sor(n=1024 if quick else 2048,
                         sweeps=2 if quick else 4).csv())
    return out


def table4(quick: bool) -> list:
    from benchmarks.paper_cpu import bench_crypt, bench_series, bench_wordcount

    out = []
    out.append(bench_crypt(mb=8 if quick else 16).csv())
    out.append(bench_series(n=4000 if quick else 8000).csv())
    out.append(bench_wordcount(mb=4 if quick else 8).csv())
    return out


def table5(quick: bool) -> list:
    from benchmarks.paper_cpu import HIER, tcl_sweep_matmult

    res = tcl_sweep_matmult(n=384 if quick else 768)
    best_tcl = min(res, key=res.get)
    l1 = HIER.find("L1").size if HIER.find("L1") else 0
    l2 = HIER.find("L2").size if HIER.find("L2") else 0
    lines = []
    for tcl, t in sorted(res.items()):
        tag = "L1" if tcl == l1 else ("L2" if tcl == l2 else "")
        lines.append(f"tcl_sweep_matmult_tcl{tcl}{tag},{t * 1e6:.0f},"
                     f"best={tcl == best_tcl}")
    lines.append(
        f"tcl_sweep_summary,0,best_tcl={best_tcl};L1={l1};L2={l2};"
        f"best_between_L1_and_L2={l1 <= best_tcl <= l2}")
    return lines


def fig10(quick: bool) -> list:
    from benchmarks.paper_cpu import bench_matmult

    r = bench_matmult(n=512 if quick else 768, tcl="L2")
    t = r.times
    tot = max(t.total, 1e-12)
    return [
        f"fig10_breakdown_decomposition,{t.decomposition * 1e6:.0f},"
        f"pct={100 * t.decomposition / tot:.2f}",
        f"fig10_breakdown_scheduling,{t.scheduling * 1e6:.0f},"
        f"pct={100 * t.scheduling / tot:.2f}",
        f"fig10_breakdown_execution,{t.execution * 1e6:.0f},"
        f"pct={100 * t.execution / tot:.2f}",
        f"fig10_breakdown_reduction,{t.reduction * 1e6:.0f},"
        f"pct={100 * t.reduction / tot:.2f}",
    ]


def fig11(quick: bool) -> list:
    """Cluster-level scaling (Fig. 11), reproduced as a model over the
    dry-run roofline terms: per-node work shrinks with node count while the
    cache-conscious decomposition keeps per-worker partitions TCL-sized
    regardless of scale -- the paper's observation that horizontal gains
    from scale-out are ephemeral."""
    from repro.core import matmul_domain, paper_system_a, find_optimal_np
    from repro.core.decompose import phi_simple, validate_np

    lines = []
    n = 8192
    for nodes in (1, 2, 4, 8):
        workers = 8 * nodes
        rows_per_node = n // nodes
        # Horizontal: partition size shrinks with scale (ephemeral locality).
        hz_bytes = 3 * (rows_per_node // 8) * n * 4
        # Cache-conscious: partition size pinned to the TCL at any scale.
        domain = matmul_domain(rows_per_node, n, n, 4)
        np_ = find_optimal_np(64 << 10, 64, domain, 8, phi_simple)
        cc_bytes = sum(phi_simple(64, d, np_) for d in domain)
        lines.append(
            f"fig11_nodes{nodes},0,horizontal_partition_bytes={hz_bytes};"
            f"cc_partition_bytes={cc_bytes:.0f};cc_fits_64k={cc_bytes <= 64 << 10}")
    return lines


def roofline(quick: bool) -> list:
    from benchmarks.roofline_table import load_cells, nominate_hillclimb, summary_csv

    cells = load_cells("16x16")
    if not cells:
        return ["roofline_missing,0,run launch/dryrun.py first"]
    out = summary_csv(cells)
    noms = nominate_hillclimb(cells)
    for k, v in noms.items():
        out.append(f"roofline_nominee_{k},0,{v['arch']}x{v['shape']}")
    return out


def plans(quick: bool) -> list:
    from repro.core.autotile import plan_attention, plan_matmul
    from repro.models.mamba2 import choose_chunk

    out = []
    t0 = time.perf_counter()
    p = plan_matmul(8192, 8192, 8192, dtype_bytes=2)
    dt = time.perf_counter() - t0
    out.append(f"plan_matmul_8k,{dt * 1e6:.0f},"
               f"bm={p.bm};bk={p.bk};bn={p.bn};np={p.np};"
               f"vmem={p.est_vmem_bytes}")
    t0 = time.perf_counter()
    a = plan_attention(32768, 32768, 128, dtype_bytes=2)
    dt = time.perf_counter() - t0
    out.append(f"plan_attention_32k,{dt * 1e6:.0f},"
               f"bq={a.block_q};bkv={a.block_kv};vmem={a.est_vmem_bytes}")
    t0 = time.perf_counter()
    c = choose_chunk(4096, 64, 64, 64)
    dt = time.perf_counter() - t0
    out.append(f"plan_ssd_chunk,{dt * 1e6:.0f},chunk={c}")
    return out


#: Set from --hosts / --chips in main(): the forced hierarchy the "plan"
#: section walks (CI runs a 2-host x 4-chip dry plan on every run).
_PLAN_HOSTS = 1
_PLAN_CHIPS = 8


def plan_tree(quick: bool) -> list:
    """--only plan: the full hierarchical plan tree (``repro.plan``).

    Walks ``plan_run`` over a ``--hosts`` x ``--chips`` TPU hierarchy --
    DCN -> ICI/HBM -> VMEM -> VREG -- for a real architecture's training
    state (with its per-arch phi_mesh ``overhead``) and for a synthetic
    65 GiB state whose np* (5 on 16 GiB chips) is not a mesh-axis divisor,
    so the printed tree demonstrates the FSDP degree quantization
    (``np_raw=5 quantized=8``).  Pure planning: no jax, no timed loops.
    """
    from repro.configs import get_model_config
    from repro.core.plan import Workload, plan_run
    from repro.dist.sharding import TRAIN_STATE_BYTES_PER_PARAM
    from repro.hw.tpu import chip_spec

    del quick
    spec = chip_spec()
    hier = spec.hierarchy(mesh_devices=_PLAN_CHIPS, hosts=_PLAN_HOSTS)
    out = []
    cfg = get_model_config("llama3.2-1b")
    hp = plan_run(hier, Workload(
        state_bytes=cfg.param_count() * TRAIN_STATE_BYTES_PER_PARAM,
        overhead=cfg.overhead,
        matmul=(4096, cfg.d_model, cfg.d_ff),
        dtype_bytes=2,
    ))
    for i, line in enumerate(hp.describe()):
        out.append(f"plan_tree_{cfg.arch}_{i},0,{line}")
    hp = plan_run(hier, Workload(state_bytes=65 << 30))
    for i, line in enumerate(hp.describe()):
        out.append(f"plan_tree_65GiB_state_{i},0,{line}")
    return out


def collectives_plan(mode: str) -> list:
    """--collectives=ring|serpentine under --dry: the plan-time ring
    schedule, one line per step showing the ppermute(s) it issues (forward
    AND backward under serpentine), plus -- when the host exposes >= 2
    devices (CI forces 4) -- the collective-permute count of the lowered
    kernels (DESIGN.md §5)."""
    import jax
    import jax.numpy as jnp
    from repro.dist.overlap import make_ag_matmul, make_rs_matmul, plan_ring

    n_dev = jax.device_count()
    p = n_dev if n_dev >= 2 else 4
    plan = plan_ring(p, mode)
    out = []
    for s, desc in enumerate(plan.describe()):
        out.append(f"ring_plan_{mode}_step{s},0,{desc}")
    if n_dev < 2:
        out.append(f"ring_hlo_{mode},0,skipped=1 device "
                   "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        return out
    mesh = jax.make_mesh((p,), ("model",))
    x = jax.ShapeDtypeStruct((4 * p, 2 * p), jnp.float32)
    w = jax.ShapeDtypeStruct((2 * p, 2 * p), jnp.float32)
    for kind, make in (("ag", make_ag_matmul), ("rs", make_rs_matmul)):
        fn = make(mesh, axis="model", mode=mode)
        mlir = fn.lower(x, w).as_text()
        # One collective_permute per ICI direction in the ring-step body:
        # 1 under ring, 2 under serpentine (the both-direction evidence).
        out.append(f"ring_hlo_{kind}_{mode},0,devices={p};"
                   f"collective_permutes={mlir.count('collective_permute')};"
                   f"directions={2 if mode == 'serpentine' else 1}")
    return out


#: Set from --collectives in main(): "gspmd" benches all three schedules,
#: "ring"/"serpentine" restrict the A/B to gspmd vs that schedule.
_AB_MODE = "gspmd"


def collectives_bench(quick: bool) -> list:
    """§Perf A/B: per-step timings of one TP projection under gspmd (XLA's
    own collectives), the ring, and the serpentine overlap matmuls
    (DESIGN.md §5), next to the estimated per-link wire bytes
    (``launch.specs.overlap_wire_bytes``).  Needs >= 2 devices;
    ``--collectives`` narrows the comparison to gspmd vs one schedule."""
    import statistics

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.overlap import make_ag_matmul, make_rs_matmul
    from repro.launch.specs import overlap_wire_bytes

    n_dev = jax.device_count()
    if n_dev < 2:
        return ["collectives_ab_skip,0,needs >=2 devices "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)"]
    p = n_dev
    mesh = jax.make_mesh((p,), ("model",))
    m = 256 if quick else 1024
    k = n = 16 * p
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    iters = 10 if quick else 30
    fns = {
        "ag_gspmd": jax.jit(
            lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, P(None, "model")),
                          NamedSharding(mesh, P(None, "model"))),
            out_shardings=NamedSharding(mesh, P(None, "model"))),
        "ag_ring": make_ag_matmul(mesh, "model", mode="ring"),
        "ag_serpentine": make_ag_matmul(mesh, "model", mode="serpentine"),
        "rs_gspmd": jax.jit(
            lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, P(None, "model")),
                          NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P("model", None))),
        "rs_ring": make_rs_matmul(mesh, "model", mode="ring"),
        "rs_serpentine": make_rs_matmul(mesh, "model", mode="serpentine"),
    }
    if _AB_MODE != "gspmd":
        fns = {name: fn for name, fn in fns.items()
               if name.endswith("_gspmd") or name.endswith(f"_{_AB_MODE}")}
    out = []
    for name, fn in fns.items():
        fn(x, w).block_until_ready()        # compile + warm
        steps = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn(x, w).block_until_ready()
            steps.append(time.perf_counter() - t0)
        kind, _, mode = name.partition("_")
        per_link = overlap_wire_bytes(
            m, k, n, p, kind=kind,
            mode=mode if mode in ("ring", "serpentine") else "ring",
            dtype_bytes=4)
        out.append(
            f"collectives_ab_{name},{statistics.median(steps) * 1e6:.0f},"
            f"p={p};min_us={min(steps) * 1e6:.0f};iters={iters};"
            f"est_wire_bytes_per_link={per_link}")
    return out


def serve_dry() -> list:
    """--only serve --dry: the decode plan tree end to end, no model math.

    Walks ``repro.serve.plan_decode`` for a forced single-host 4-way
    tensor-parallel mesh (DCN-free by construction: one host, so the
    hierarchy tops out at the ICI) and asserts the page level picked a
    page that fits the VMEM leaf double-buffered -- the CI serve smoke
    gate (``ci/run_tests.sh`` greps ``dcn_free=True`` and
    ``page_fits_vmem=True``).
    """
    from jax.sharding import AbstractMesh
    from repro.configs import get_model_config
    from repro.core.plan import PAGE_BUFFERING
    from repro.serve import page_spec_from_plan, plan_decode

    mesh = AbstractMesh((("data", 1), ("model", 4)))
    cfg = get_model_config("llama3.2-1b")
    hp = plan_decode(cfg, mesh, max_len=32768, batch=8)
    out = []
    for i, line in enumerate(hp.describe()):
        out.append(f"serve_plan_{cfg.arch}_{i},0,{line}")
    levels = [lp.level for lp in hp.levels()]
    page = hp.page_plan()
    vmem = hp.level("VMEM")
    fits = (page is not None and vmem is not None
            and PAGE_BUFFERING * page["page_bytes"] <= vmem.budget_bytes)
    spec = page_spec_from_plan(hp, cfg)
    out.append(
        f"serve_dry_summary,0,levels={'>'.join(levels)};"
        f"dcn_free={'DCN' not in levels};"
        f"page_tokens={page['page_tokens'] if page else 0};"
        f"kv_shard={hp.kv_shard()};"
        f"page_fits_vmem={fits};"
        f"global_page_bytes={spec.page_bytes}")
    return out


def paged_dry() -> list:
    """--only paged --dry: pool geometry end to end, no model math.

    Builds a paged engine on the host mesh and asserts its pool geometry
    is taken VERBATIM from ``plan_run``'s page level: the pool's page size
    equals ``page_plan()["page_tokens"]``, the per-slot table width covers
    the plan's ``page_table()["pages_per_slot"]`` bound, and the physical
    pool never exceeds the plan's ``pages_total`` budget bound (the engine
    applies ``kv_fraction < 1`` on top).  CI greps
    ``pool_matches_plan=True`` (``ci/run_tests.sh``).
    """
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=4, max_slots=2, max_len=64,
                           batching="paged"))
    rng = np.random.default_rng(0)
    engine.generate([rng.integers(0, 256, 9, dtype=np.int32)])
    m = engine.metrics
    page = engine.plan.page_plan()
    ptab = engine.plan.page_table() or {}
    pool_ok = (
        m["batching"] == "paged"
        and page is not None
        and m["page_tokens"] == page["page_tokens"]
        and m["pages_per_slot"] >= int(ptab.get("pages_per_slot", 1))
        and (not ptab.get("pages_total")
             or m["pages_total"] <= ptab["pages_total"])
        and m["pages_total"] >= 1
        and m["pages_allocated"] == m["pages_released"]  # drained pool
    )
    return [
        f"paged_dry_geometry,0,page_tokens={m['page_tokens']};"
        f"pages_total={m['pages_total']};pages_per_slot={m['pages_per_slot']};"
        f"plan_pages_per_slot={ptab.get('pages_per_slot')};"
        f"plan_pages_total={ptab.get('pages_total')};"
        f"pool_matches_plan={pool_ok}",
    ]


def paged_bench(quick: bool) -> list:
    """--only paged: tok/s + slot-utilization of the paged engine vs the
    PR 4 cohort engine on a mixed-length trace (mixed prompt lengths AND
    mixed max_new, so cohorts drag finished slots while the page pool
    backfills them)."""
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config("llama3.2-1b").reduced()
    rng = np.random.default_rng(0)
    lens = (16, 16, 32, 16, 32, 16) if not quick else (16, 16, 32)
    news = (24, 6, 24, 6, 24, 6) if not quick else (12, 3, 12)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in lens]
    out = []
    results = {}
    for mode in ("cohort", "paged"):
        engine = ServeEngine(
            cfg, make_host_mesh(),
            policy=ServePolicy(max_slots=2, max_len=128, batching=mode))
        t0 = time.perf_counter()
        outs = engine.generate(prompts, max_new_tokens=list(news))
        dt = time.perf_counter() - t0
        n_tok = sum(len(o) for o in outs)
        m = engine.metrics
        results[mode] = (outs, m)
        out.append(
            f"paged_ab_{mode},{dt / max(1, n_tok) * 1e6:.0f},"
            f"tok_s={n_tok / max(dt, 1e-9):.1f};tokens={n_tok};"
            f"slot_utilization={m['slot_utilization']:.3f};"
            f"backfills={m.get('backfills', 0)};"
            f"decode_steps={m['decode_steps']}")
    same = results["cohort"][0] == results["paged"][0]
    cu = results["cohort"][1]["slot_utilization"]
    pu = results["paged"][1]["slot_utilization"]
    out.append(
        f"paged_ab_summary,0,token_identical={same};"
        f"util_cohort={cu:.3f};util_paged={pu:.3f};"
        f"paged_util_higher={pu > cu}")
    return out


def prefill_dry() -> list:
    """--only prefill --dry: chunk geometry, no timing.

    Runs one chunked-prefill request end to end and asserts every full
    prefill chunk in the engine's interleave trace is EXACTLY the
    planner's page (``plan.chunk_tokens()`` == ``page_plan()``'s
    ``page_tokens`` -- the VMEM-fitting double-buffered KV slice, reused
    as the prefill quantum, DESIGN.md §10).  CI greps
    ``chunk_matches_page=True`` (``ci/run_tests.sh``).
    """
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=2, max_slots=2, max_len=128,
                           batching="paged", prefill="chunked"))
    t = engine.plan.chunk_tokens()
    page = engine.plan.page_plan()
    rng = np.random.default_rng(0)
    plen = 2 * (t or 16) + 3                 # multi-chunk, partial final
    engine.generate([rng.integers(0, 256, plen, dtype=np.int32)])
    chunks = [ev for ev in engine.metrics["interleave"]
              if ev[0] == "chunk"]
    full = [c for _, _, _, c in chunks if c == t]
    ok = (
        t is not None
        and page is not None
        and t == page["page_tokens"]
        and len(chunks) == -(-plen // t)
        and len(full) == plen // t
        and sum(c for _, _, _, c in chunks) == plen
    )
    return [
        f"prefill_dry_chunks,0,chunk_tokens={t};"
        f"page_tokens={page['page_tokens'] if page else None};"
        f"chunks={len(chunks)};prompt_tokens={plen};"
        f"chunk_matches_page={ok}",
    ]


def prefill_bench(quick: bool) -> list:
    """--only prefill: TTFT + decode-stall A/B, chunked vs monolithic.

    One long prompt arrives while a short request is already decoding.
    Monolithic prefill runs the whole prompt between two of the resident
    slot's decode ticks -- its max inter-token gap absorbs the entire
    prefill.  Chunked prefill pays one page-sized chunk per tick, so the
    resident slot's worst stall is bounded by a chunk.  Reports the long
    request's time-to-first-token and the short request's max inter-token
    gap for both modes, from the engine's per-token timestamps.
    """
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config("llama3.2-1b").reduced()
    rng = np.random.default_rng(0)
    out = []
    results = {}
    for mode in ("monolithic", "chunked"):
        engine = ServeEngine(
            cfg, make_host_mesh(),
            policy=ServePolicy(max_slots=2, max_len=256, batching="paged",
                               prefill=mode))
        t = engine.plan.chunk_tokens() or engine.page.page_tokens
        long_plen = (4 if quick else 6) * t
        # Two short requests fill both slots; the long one backfills the
        # early finisher's slot and prefills WHILE request 0 still decodes
        # -- its inter-token gaps are where a monolithic prefill shows up.
        prompts = [rng.integers(0, cfg.vocab_size, t - 2, dtype=np.int32),
                   rng.integers(0, cfg.vocab_size, t - 2, dtype=np.int32),
                   rng.integers(0, cfg.vocab_size, long_plen,
                                dtype=np.int32)]
        outs = engine.generate(
            prompts, max_new_tokens=[12 if quick else 24, 2, 2])
        m = engine.metrics
        times = m["token_times"]
        ttft_long = times[2][0] - m["start_time"]
        gaps = np.diff(np.asarray([m["start_time"]] + times[0]))
        results[mode] = (outs, ttft_long, float(gaps.max()))
        n_tok = sum(len(o) for o in outs)
        out.append(
            f"prefill_ab_{mode},{ttft_long * 1e6:.0f},"
            f"ttft_long_ms={ttft_long * 1e3:.1f};"
            f"max_stall_short_ms={float(gaps.max()) * 1e3:.1f};"
            f"tokens={n_tok};prefill_chunks={m['prefill_chunks']};"
            f"chunk_tokens={t};long_prompt={long_plen}")
    # Token identity chunked-vs-monolithic is the test suite's job
    # (tests/test_serve_prefill.py, at controlled context lengths --
    # random-init logits go argmax-unstable at this prompt scale).
    out.append(
        f"prefill_ab_summary,0,"
        f"stall_mono_ms={results['monolithic'][2] * 1e3:.1f};"
        f"stall_chunked_ms={results['chunked'][2] * 1e3:.1f};"
        f"chunked_stall_lower="
        f"{results['chunked'][2] < results['monolithic'][2]}")
    return out


def prefix_dry() -> list:
    """--only prefix --dry: radix-cache capacity vs the plan, no timing.

    Builds the paged engine with ``prefix_cache="radix"``, runs two
    prompts sharing a page-aligned prefix through one slot, and asserts
    the cache's byte budget is EXACTLY the mesh-level HBM leftover the
    planner recorded (``plan.prefix_budget()``, from
    ``detail["page_table"]["prefix_budget_bytes"]`` -- DESIGN.md §11),
    with the second request hitting the first's published pages.  CI
    greps ``prefix_budget_matches_plan=True`` (``ci/run_tests.sh``).
    """
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=2, max_slots=1, max_len=160,
                           batching="paged", prefix_cache="radix"))
    t = engine.page.page_tokens
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 3 * t, dtype=np.int32)
    tails = [rng.integers(0, cfg.vocab_size, t - 2, dtype=np.int32)
             for _ in range(2)]
    tails[1][0] = (tails[0][0] + 1) % cfg.vocab_size
    engine.generate([np.concatenate([shared, tl]) for tl in tails])
    m = engine.metrics
    plan_budget = engine.plan.prefix_budget()
    cache_budget = engine._paged_session.prefix.budget_bytes
    ok = (
        plan_budget is not None
        and plan_budget > 0
        and m["prefix_budget_bytes"] == plan_budget
        and cache_budget == plan_budget
        and m["prefix_hits"] == 1
        and m["prefix_hit_tokens"] == 3 * t
        and m["pages_saved"] > 0
    )
    return [
        f"prefix_dry_budget,0,plan_budget={plan_budget};"
        f"cache_budget={cache_budget};"
        f"metric_budget={m['prefix_budget_bytes']};"
        f"hits={m['prefix_hits']};hit_tokens={m['prefix_hit_tokens']};"
        f"pages_saved={m['pages_saved']};"
        f"resident_pages={m['prefix_resident_pages']};"
        f"prefix_budget_matches_plan={ok}",
    ]


def prefix_bench(quick: bool) -> list:
    """--only prefix: shared-system-prompt A/B, cached vs cold TTFT.

    The workload millions of deployments run: every request opens with
    the same system prompt.  Three single-request ``generate`` calls
    through one radix engine: X compiles every chunk bucket (its timings
    are discarded), Y measures a COLD prompt (disjoint tokens -- a
    radix miss, full prefill), Z measures a CACHED prompt sharing Y's
    page-aligned system prefix -- admission starts chunked prefill at
    the first unshared token, so Z prefills only the tail.  The tail is
    sized to the final chunk bucket X already compiled (``t - 2``), so
    the A/B is pure prefill work, not compile skew.  Reports TTFT and
    prefill tokens for both, from the engine's per-token timestamps.
    """
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config("llama3.2-1b").reduced()
    n_new = 4 if quick else 8
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=n_new, max_slots=1, max_len=256,
                           batching="paged", prefix_cache="radix"))
    t = engine.page.page_tokens
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab_size, 3 * t, dtype=np.int32)

    def prompt(prefix, seed):
        r = np.random.default_rng(seed)
        return np.concatenate(
            [prefix, r.integers(0, cfg.vocab_size, t - 2, dtype=np.int32)])

    def run(p):
        before = engine.metrics["prefill_tokens"]   # counters accumulate
        engine.generate([p], max_new_tokens=n_new)
        m = engine.metrics
        # token_times is keyed by rid, which counts across calls.
        (times,) = m["token_times"].values()
        return (times[0] - m["start_time"],
                m["prefill_tokens"] - before, m)

    warmup = rng.integers(0, cfg.vocab_size, 4 * t - 2, dtype=np.int32)
    run(warmup)                             # X: compile, discard timings
    cold_ttft, cold_tokens, _ = run(prompt(system, 1))      # Y: radix miss
    hot_ttft, hot_tokens, m = run(prompt(system, 2))        # Z: radix hit
    return [
        f"prefix_ab_cold,{cold_ttft * 1e6:.0f},"
        f"ttft_ms={cold_ttft * 1e3:.2f};prefill_tokens={cold_tokens};"
        f"prompt_tokens={4 * t - 2}",
        f"prefix_ab_cached,{hot_ttft * 1e6:.0f},"
        f"ttft_ms={hot_ttft * 1e3:.2f};prefill_tokens={hot_tokens};"
        f"hit_tokens={m['prefix_hit_tokens']};"
        f"pages_saved={m['pages_saved']};cow_copies={m['cow_copies']}",
        f"prefix_ab_summary,0,shared_tokens={3 * t};"
        f"ttft_cold_ms={cold_ttft * 1e3:.2f};"
        f"ttft_cached_ms={hot_ttft * 1e3:.2f};"
        f"prefill_saved_tokens={cold_tokens - hot_tokens};"
        f"cached_ttft_lower={hot_ttft < cold_ttft}",
    ]


def serve_bench(quick: bool) -> list:
    """--only serve: tok/s of the plan-driven engine on this host, next to
    the planned-vs-naive page sizes (naive = the legacy loop's allocation
    granule: one full ``max_len`` buffer per request up front)."""
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy, kv_token_bytes

    cfg = get_model_config("llama3.2-1b").reduced()
    n_new = 8 if quick else 24
    max_len = 128 if quick else 256
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=n_new, max_slots=4,
                           max_len=max_len))
    rng = np.random.default_rng(0)
    lens = (16, 16, 32, 32, 16, 48) if not quick else (16, 16, 32)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in lens]
    t0 = time.perf_counter()
    outs = engine.generate(prompts)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    m = engine.metrics
    tok_bytes, _, _ = kv_token_bytes(cfg, 4)
    naive_tokens = max_len                  # legacy: full buffer up front
    naive_resident = naive_tokens * tok_bytes * len(prompts)
    return [
        f"serve_toks,{dt / max(1, n_tok) * 1e6:.0f},"
        f"tok_s={n_tok / max(dt, 1e-9):.1f};tokens={n_tok};"
        f"requests={len(prompts)};cohorts={m['cohorts']}",
        f"serve_pages,0,planned_page_tokens={m['page_tokens']};"
        f"naive_page_tokens={naive_tokens};"
        f"planned_peak_resident={m.get('peak_resident_bytes', 0)};"
        f"naive_resident={naive_resident};"
        f"kv_shard={m['kv_shard']};evictions={m['evictions']}",
    ]


def tune_bench(quick: bool) -> list:
    """--only tune: tuned-vs-analytic kernel times (DESIGN.md §9).

    Runs the neighborhood sweep (``repro.tune.sweep``) around each kernel's
    analytic block and reports the winner next to the analytic center --
    the measured evidence behind every ``src=tuned`` line in the plan tree.
    Winners are NOT persisted from a benchmark run (that is ``repro-tune``'s
    job); this section only measures.
    """
    from repro.tune.sweep import run_sweeps

    results = run_sweeps(quick=quick, warmup=1, iters=3 if quick else 5,
                         write=False)
    out = []
    for r in results:
        e = r.entry
        if e is None:
            out.append(f"tune_{r.kernel},0,no_timed_candidates=1")
            continue
        win = "/".join(f"{k}={v}" for k, v in sorted(e.block.items()))
        ana = "/".join(f"{k}={v}"
                       for k, v in sorted(e.analytic_block.items()))
        out.append(
            f"tune_{r.kernel},{e.median_us:.0f},"
            f"analytic_us={e.analytic_us:.0f};speedup={e.speedup};"
            f"winner={win};analytic={ana};bucket={r.bucket};"
            f"candidates={len(r.candidates)};rejected={r.rejected};"
            f"tuned_beats_analytic={e.speedup > 1.0}")
    return out


def tune_dry() -> list:
    """--only tune --dry: enumerate + VMEM-filter the sweep neighborhoods
    without timing anything -- the CI tune smoke gate (``ci/run_tests.sh``
    greps ``all_candidates_fit_vmem=True``)."""
    from repro.tune.sweep import run_sweeps

    results = run_sweeps(quick=True, dry=True)
    out = []
    all_fit = True
    for r in results:
        fit = all(c.est_vmem_bytes <= r.budget_bytes for c in r.candidates)
        all_fit &= fit and bool(r.candidates)
        center = "/".join(f"{k}={v}" for k, v in sorted(r.center.items()))
        out.append(
            f"tune_dry_{r.kernel},0,bucket={r.bucket};center={center};"
            f"candidates={len(r.candidates)};rejected={r.rejected};"
            f"budget={r.budget_bytes};fit={fit}")
    out.append(f"tune_dry_summary,0,kernels={len(results)};"
               f"all_candidates_fit_vmem={all_fit}")
    return out


def _nocsv(d) -> str:
    """A dict rendered without commas (the row format's field separator)."""
    return "/".join(f"{k}:{v}" for k, v in dict(d or {}).items())


def _cluster_ab(policy: str, quick: bool) -> dict:
    """One routed A/B arm: a 2-replica thread-transport cluster under a
    memory-SKEWED workload -- one long request pins most of replica 0's
    page pool, then a burst of short requests arrives while it decodes.
    ``free_pages`` routes the burst around the page-poor replica;
    ``round_robin`` alternates it into the queue behind the long request.
    Prefix cache and affinity are OFF so the A/B isolates placement."""
    import numpy as np
    from repro.cluster import EngineSpec, ServeCluster
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve.engine import plan_decode

    cfg = get_model_config("llama3.2-1b").reduced()
    max_len = 192
    long_new = 32 if quick else 64
    spec = EngineSpec(arch="llama3.2-1b", max_new_tokens=long_new,
                      max_slots=1, max_len=max_len, prefix_cache="off")
    plan = plan_decode(cfg, make_host_mesh(), max_len=max_len, cluster=2)
    cluster = ServeCluster.from_plan(plan, spec, transport="thread",
                                     policy=policy, affinity=False)
    rng = np.random.default_rng(0)

    def prompt(n, seed):
        return np.random.default_rng(seed).integers(
            0, cfg.vocab_size, n, dtype=np.int32).tolist()

    try:
        # Build + compile both replicas' chunk buckets outside the clock.
        for rep in cluster.replicas:
            rep.generate([prompt(96, 10 + rep.replica)], 1).wait(600)
            rep.generate([prompt(24, 20 + rep.replica)], 1).wait(600)
        long_cr = cluster.submit(prompt(96, 1), long_new)
        t0 = time.perf_counter()
        while long_cr.ttft() is None:       # decoding: its pages are held
            if long_cr.done() or time.perf_counter() - t0 > 300:
                break
            time.sleep(0.005)
        burst = [cluster.submit(prompt(24, 100 + i), 2) for i in range(4)]
        for cr in burst:
            cr.result(timeout=600)
        long_cr.result(timeout=600)
        ttfts = [cr.ttft() for cr in burst]
        return {
            "policy": policy,
            "burst_replicas": [cr.replica for cr in burst],
            "long_replica": long_cr.replica,
            "mean_ttft": sum(ttfts) / len(ttfts),
            "max_ttft": max(ttfts),
        }
    finally:
        cluster.close()


def cluster_bench(quick: bool) -> list:
    """--only cluster: free_pages-vs-round_robin TTFT A/B under the
    memory-skewed workload (DESIGN.md §12) -- the Silva et al. claim,
    measured: placing by available pool memory instead of work count
    keeps the short burst's TTFT off the long request's decode tail."""
    arms = {p: _cluster_ab(p, quick) for p in ("round_robin", "free_pages")}
    rr, fp = arms["round_robin"], arms["free_pages"]
    out = []
    for a in (rr, fp):
        out.append(
            f"cluster_ab_{a['policy']},{a['mean_ttft'] * 1e6:.0f},"
            f"mean_burst_ttft_ms={a['mean_ttft'] * 1e3:.2f};"
            f"max_burst_ttft_ms={a['max_ttft'] * 1e3:.2f};"
            f"long_replica={a['long_replica']};"
            f"burst_replicas={'/'.join(str(r) for r in a['burst_replicas'])}")
    out.append(
        f"cluster_ab_summary,0,replicas=2;"
        f"ttft_rr_ms={rr['mean_ttft'] * 1e3:.2f};"
        f"ttft_free_pages_ms={fp['mean_ttft'] * 1e3:.2f};"
        f"speedup={rr['mean_ttft'] / max(fp['mean_ttft'], 1e-9):.2f};"
        f"free_pages_ttft_lower={fp['mean_ttft'] < rr['mean_ttft']}")
    return out


def cluster_dry() -> list:
    """--only cluster --dry: the fleet-vs-plan assertions CI gates
    (``ci/run_tests.sh`` greps ``replicas_match_plan=True`` and
    ``pool_matches_plan=True``): the cluster stands up exactly the DCN
    level's np replicas, each replica's pool geometry is the single-host
    plan's page_table (the DCN level chooses WIDTH, never reshapes the
    per-replica subtree), and a DCN-bearing plan without ``cluster=``
    raises the structured ``PlanError``."""
    from repro.cluster import ServeCluster, StubSpec
    from repro.configs import get_model_config
    from repro.hw.tpu import chip_spec
    from repro.launch.mesh import make_host_mesh
    from repro.serve.engine import PlanError, plan_decode

    cfg = get_model_config("llama3.2-1b").reduced()
    mesh = make_host_mesh()
    spec = chip_spec()
    plan = plan_decode(cfg, mesh, max_len=256, spec=spec, cluster=2)
    single = plan_decode(cfg, mesh, max_len=256, spec=spec)
    dcn = plan.level("DCN")
    cluster = ServeCluster.from_plan(plan, StubSpec(), transport="thread")
    try:
        n = len(cluster.replicas)
    finally:
        cluster.close()
    replicas_match = dcn is not None and n == dcn.np == plan.replicas()
    pool_match = (dict(plan.page_table() or {})
                  == dict(single.page_table() or {}))
    try:
        plan_decode(cfg, mesh, max_len=256, spec=spec,
                    hierarchy=spec.hierarchy(mesh_devices=1, hosts=2))
        guard = False
    except PlanError:
        guard = True
    return [
        f"cluster_dry_plan,0,dcn_np={dcn.np if dcn else 0};"
        f"replicas={plan.replicas()};fleet={n};"
        f"placement={dcn.detail.get('placement') if dcn else None}",
        f"cluster_dry_pool,0,"
        f"cluster_page_table={_nocsv(plan.page_table())};"
        f"single_page_table={_nocsv(single.page_table())}",
        f"cluster_dry_summary,0,replicas_match_plan={replicas_match};"
        f"pool_matches_plan={pool_match};dcn_guard_raises={guard}",
    ]


def _obs_workload(tracing: bool = True):
    """The obs section's shared recorded workload: a reduced paged+radix
    engine over three prompts sharing a 12-token prefix.  Returns the
    engine (registry populated, tracer holding the request spans)."""
    import numpy as np
    from repro.configs import get_model_config
    from repro.launch.mesh import make_host_mesh
    from repro.serve import ServeEngine, ServePolicy

    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=6, max_slots=4, max_len=128,
                           batching="paged", prefix_cache="radix"))
    engine.tracer.enabled = tracing
    rng = np.random.default_rng(0)
    shared = rng.integers(0, 256, 12, dtype=np.int32)
    engine.generate(
        [np.concatenate([shared,
                         rng.integers(0, 256, 4 + i, dtype=np.int32)])
         for i in range(3)])
    return engine


def obs_dry() -> list:
    """--only obs --dry: the observability spine end to end, no timing.

    Runs the shared workload, exports the tracer's Chrome/Perfetto JSON
    and validates it against the ``trace_event`` schema
    (``repro.obs.validate_events``), then walks plan-vs-actual
    (DESIGN.md §13) asserting every residual is finite and the pool's
    observed peak landed inside the plan's ``page_table`` budget.  CI
    greps ``trace_schema_ok=True``, ``plan_vs_actual_ok=True`` and
    ``pool_peak_within_plan=True`` (``ci/run_tests.sh``).
    """
    import json
    import math
    import tempfile

    from repro.obs import plan_vs_actual, validate_events

    engine = _obs_workload()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as tf:
        engine.tracer.export_chrome(tf.name)
        doc = json.load(open(tf.name))
    events = doc.get("traceEvents", [])
    problems = validate_events(events)
    names = sorted({e.get("name") for e in events if e.get("ph") != "M"})
    schema_ok = (not problems and doc.get("displayTimeUnit") == "ms"
                 and {"request", "prefill_chunk", "decode_tick",
                      "queue_wait"} <= set(names))
    rows = plan_vs_actual(engine.plan, engine.obs)
    out = []
    finite = bool(rows)
    pool_ok = False
    for r in rows:
        ratio = r["ratio"]
        finite = finite and ratio is not None and math.isfinite(ratio)
        if r["metric"] == "pool_pages":
            pool_ok = bool(r["observed"] is not None and r["predicted"]
                           and r["observed"] <= r["predicted"])
        out.append(
            f"obs_dry_planvsactual_{r['level']}_{r['metric']},0,"
            f"predicted={r['predicted']};observed={r['observed']};"
            f"ratio={ratio};unit={r['unit']};"
            f"within_band={r['within_band']}")
    out.append(
        f"obs_dry_summary,0,trace_events={len(events)};"
        f"trace_problems={len(problems)};"
        f"trace_schema_ok={schema_ok};plan_vs_actual_ok={finite};"
        f"pool_peak_within_plan={pool_ok}")
    return out


def obs_bench(quick: bool) -> list:
    """--only obs: overhead A/B of the observability spine + the latency
    percentile surface it produces.

    The same workload runs through two identical paged engines, tracer
    on vs off (the registry stays on both sides -- it IS the metrics
    spine, there is no without-registry engine anymore), reporting the
    per-token cost of tracing, the TTFT / inter-token percentiles the
    log-bucket histograms yield, and the plan-vs-actual residual rows --
    the committable calibration trajectory (BENCH_10.json)."""
    from repro.obs import plan_vs_actual

    out = []
    reps = 1 if quick else 2
    results = {}
    _obs_workload()                 # compile warmup outside both arms
    for tracing in (False, True):
        best = None
        for _ in range(reps):
            t0 = time.perf_counter()
            engine = _obs_workload(tracing=tracing)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
            results[tracing] = engine
        n_tok = int(engine.obs.value("tokens", 0))
        tag = "on" if tracing else "off"
        out.append(
            f"obs_ab_trace_{tag},{best / max(1, n_tok) * 1e6:.0f},"
            f"tokens={n_tok};tok_s={n_tok / max(best, 1e-9):.1f};"
            f"trace_events={len(engine.tracer.export_events())}")
    eng = results[True]
    for hname in ("ttft_s", "inter_token_s", "queue_wait_s"):
        h = eng.obs.get(hname)
        out.append(
            f"obs_latency_{hname},{h.mean * 1e6:.1f},"
            f"count={h.count};p50_us={h.percentile(50) * 1e6:.1f};"
            f"p95_us={h.percentile(95) * 1e6:.1f};"
            f"p99_us={h.percentile(99) * 1e6:.1f}")
    for r in plan_vs_actual(eng.plan, eng.obs):
        out.append(
            f"obs_planvsactual_{r['level']}_{r['metric']},0,"
            f"predicted={r['predicted']};observed={r['observed']};"
            f"ratio={r['ratio']};unit={r['unit']};"
            f"within_band={r['within_band']}")
    return out


SECTIONS = {
    "table3": table3,
    "table4": table4,
    "table5": table5,
    "fig10": fig10,
    "fig11": fig11,
    "roofline": roofline,
    "plans": plans,
    "plan": plan_tree,
    "collectives": collectives_bench,
    "serve": serve_bench,
    "paged": paged_bench,
    "prefill": prefill_bench,
    "prefix": prefix_bench,
    "tune": tune_bench,
    "cluster": cluster_bench,
    "obs": obs_bench,
}


def dry(_quick: bool, collectives: str = "gspmd") -> list:
    """CI smoke: exercise the decomposer planning paths (chip and mesh
    level) without running any timed benchmark loops.  With
    ``--collectives`` also print the overlap layer's ring schedule."""
    from repro.configs import get_model_config
    from repro.dist.sharding import arch_rules, mesh_decomposition, mesh_hierarchy
    from jax.sharding import AbstractMesh

    out = plans(True)
    mesh = AbstractMesh((("data", 16), ("model", 16)))
    for arch in ("llama3.2-1b", "deepseek-v2-236b"):
        cfg = get_model_config(arch)
        rules = arch_rules(cfg, mesh)
        out.append(
            f"dry_mesh_rules_{arch},0,"
            f"embed={rules.param_rules['embed']};np={rules.meta['mesh_np']};"
            f"fits={rules.meta['mesh_fits']}")
    dec = mesh_decomposition(mesh_hierarchy(mesh), sharded_bytes=1 << 40,
                             max_np=16)
    out.append(f"dry_mesh_decomposition_1TiB,0,np={dec.np};fits={dec.fits}")
    if collectives != "gspmd":
        out.extend(collectives_plan(collectives))
    return out


def _parse_derived(derived: str) -> dict:
    """``k=v;k=v`` -> dict with numbers parsed (best-effort; a token
    without '=' keeps the raw string under ``_raw``)."""
    out = {}
    raw = []
    for tok in derived.split(";"):
        if "=" not in tok:
            if tok:
                raw.append(tok)
            continue
        k, _, v = tok.partition("=")
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    if raw:
        out["_raw"] = ";".join(raw)
    return out


def _write_json(path: str, rows: list, argv: list) -> None:
    """The committable ``BENCH_<n>.json`` artifact: every CSV row of the
    run, parsed, plus enough provenance (backend, device, argv) to read a
    number a year later.  Schema checked by the CI smoke."""
    import json

    backend = device = "unknown"
    if "jax" in sys.modules:
        try:
            import jax

            backend = jax.default_backend()
            device = jax.devices()[0].device_kind
        except Exception:
            pass
    doc = {
        "schema": "repro-bench-v1",
        "created_unix": int(time.time()),
        "argv": argv,
        "backend": backend,
        "device": device,
        "rows": [
            {"section": sec, "name": name, "us_per_call": us,
             "derived": _parse_derived(derived)}
            for sec, name, us, derived in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)


def _collect(rows: list, section: str, line: str) -> None:
    print(line)
    name, _, rest = line.partition(",")
    us, _, derived = rest.partition(",")
    try:
        us_f = float(us)
    except ValueError:
        us_f = 0.0
    rows.append((section, name, us_f, derived))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--dry", action="store_true",
                    help="plan-only smoke run (CI): no timed benchmarks")
    ap.add_argument("--json", default="",
                    help="also write every row to a BENCH_<n>.json artifact "
                         "(schema repro-bench-v1; the committable perf "
                         "trajectory)")
    ap.add_argument("--collectives", default="gspmd",
                    choices=("gspmd", "ring", "serpentine"),
                    help="overlap-layer collective schedule (DESIGN.md §5): "
                         "with --dry, print its ring plan + lowered-HLO "
                         "permute count; with --only collectives, restrict "
                         "the A/B to gspmd vs this schedule")
    ap.add_argument("--hosts", type=int, default=1,
                    help="--only plan: hosts (DCN copies) of the forced "
                         "hierarchy the plan tree is walked over")
    ap.add_argument("--chips", type=int, default=8,
                    help="--only plan: chips per host of the forced "
                         "hierarchy")
    args = ap.parse_args()
    global _AB_MODE, _PLAN_HOSTS, _PLAN_CHIPS
    _AB_MODE = args.collectives
    _PLAN_HOSTS, _PLAN_CHIPS = args.hosts, args.chips
    if args.collectives != "gspmd":
        # The ring needs >1 device to mean anything; force a 4-way host
        # platform unless the caller already chose (must precede jax import,
        # which only the section bodies perform).
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    rows: list = []
    if args.dry:
        # CI gate: unlike the benchmark sections below, failures here must
        # propagate to a nonzero exit, not become an _ERROR CSV row.
        print("name,us_per_call,derived")
        # Dedicated dry smokes (serve: decode plan tree + page/DCN
        # assertions; paged: pool geometry vs the plan's page level; tune:
        # sweep enumeration + VMEM filter) -- any --only list made up
        # entirely of these runs them in order.
        dry_sections = {"serve": serve_dry, "paged": paged_dry,
                        "prefill": prefill_dry, "prefix": prefix_dry,
                        "tune": tune_dry, "cluster": cluster_dry,
                        "obs": obs_dry}
        only = [s.strip() for s in args.only.split(",") if s.strip()]
        if only and all(s in dry_sections for s in only):
            for s in only:
                for line in dry_sections[s]():
                    _collect(rows, s, line)
        else:
            for line in dry(args.quick, args.collectives):
                _collect(rows, "dry", line)
        if args.json:
            _write_json(args.json, rows, sys.argv[1:])
        return
    names = args.only.split(",") if args.only else list(SECTIONS)
    print("name,us_per_call,derived")
    for name in names:
        fn = SECTIONS[name.strip()]
        t0 = time.perf_counter()
        try:
            for line in fn(args.quick):
                _collect(rows, name.strip(), line)
        except Exception as e:  # keep the harness running
            _collect(rows, name.strip(), f"{name}_ERROR,0,{e!r}")
        sys.stdout.flush()
    if args.json:
        _write_json(args.json, rows, sys.argv[1:])


if __name__ == "__main__":
    main()
