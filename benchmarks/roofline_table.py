"""§Roofline table generation from the dry-run artifacts.

Reads ``experiments/dryrun/*__16x16.json`` (the single-pod baseline of every
(arch x shape) cell), renders the roofline table, and nominates the three
hillclimb cells: worst MFU bound, most collective-bound, and the cell most
representative of the paper's technique.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str = "16x16", dir_: str = DRYRUN_DIR) -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    cells.sort(key=lambda c: (c["arch"], SHAPE_ORDER.index(c["shape"])
                              if c["shape"] in SHAPE_ORDER else 9))
    return cells


def render_table(cells: List[dict]) -> str:
    lines = [
        "| arch | shape | step | compute ms | memory ms | collective ms "
        "| bottleneck | useful (6ND/HLO) | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") == "skipped":
            lines.append(
                f"| {c['arch']} | {c['shape']} | -- | -- | -- | -- | "
                f"skipped: {c['reason'][:46]}... | -- | -- |")
            continue
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | FAILED | | | | | | |")
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['step']} "
            f"| {r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} "
            f"| {r['collective_s'] * 1e3:.2f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound'] * 100:.1f}% |")
    return "\n".join(lines)


def nominate_hillclimb(cells: List[dict]) -> Dict[str, dict]:
    ok = [c for c in cells if c.get("status") == "ok"]
    worst_mfu = min(ok, key=lambda c: c["roofline"]["mfu_bound"])
    coll = max(ok, key=lambda c: (c["roofline"]["collective_s"]
                                  / max(1e-12, max(
                                      c["roofline"]["compute_s"],
                                      c["roofline"]["memory_s"]))))
    # Most representative of the paper: the big dense training cell whose
    # bottleneck is the cache-neglectful attention materialization.
    rep = next((c for c in ok if c["arch"] == "deepseek-coder-33b"
                and c["shape"] == "train_4k"), ok[0])
    return {"worst_mfu": worst_mfu, "most_collective": coll,
            "paper_representative": rep}


def summary_csv(cells: List[dict]) -> List[str]:
    out = []
    for c in cells:
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        bound_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        out.append(
            f"roofline_{c['arch']}_{c['shape']},{bound_us:.0f},"
            f"bottleneck={r['bottleneck']};mfu_bound={r['mfu_bound']:.4f};"
            f"useful={r['useful_ratio']:.3f}")
    return out


if __name__ == "__main__":
    cells = load_cells()
    print(render_table(cells))
    print()
    for k, v in nominate_hillclimb(cells).items():
        print(f"{k}: {v['arch']} x {v['shape']} "
              f"(mfu_bound={v['roofline']['mfu_bound'] * 100:.2f}%)")
