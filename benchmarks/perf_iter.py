"""§Perf hillclimb driver: re-lower one cell under a named optimization
variant, re-analyze the HLO, and report the roofline-term deltas vs the
stored baseline.

MUST set the device count before any jax import (same rule as dryrun.py).

Variants (composable, comma-separated):
  blockwise_attn   -- cache-conscious attention for train/prefill: stream
                      decomposer-sized KV blocks instead of materializing
                      (B, H, S, S) f32 logits (threshold 8192 -> 2048)
  remat_dots       -- checkpoint policy: save matmul outputs (recompute
                      element-wise only) instead of full-layer remat
  serve_tp_weights -- serving keeps weights TP-sharded only (no per-step
                      FSDP all-gather); costs HBM capacity, removes the
                      dominant decode collective
  cache_head_shard -- long-context cache sharded over KV heads instead of
                      sequence: attention stays shard-local (no
                      distributed softmax / gather of the cache)
  cache_seq_shard  -- decode cache sharded over the sequence dim (for archs
                      whose kv_heads don't divide the model axis: keeps the
                      cache sharded, collectives move tiny logits instead
                      of the cache)
  opt_bf16         -- optimizer moments in bf16 (halves optimizer traffic)
  ring / serpentine -- route the TP matmuls through dist/overlap's ring
                      (one ICI direction) or serpentine (both directions,
                      half the per-link bytes) collective matmuls instead
                      of GSPMD's default collectives (DESIGN.md §5)

Usage:
  python -m benchmarks.perf_iter --arch deepseek-coder-33b --shape train_4k \
      --variants blockwise_attn,remat_dots
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import TrainConfig, get_model_config, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import decode_batch_specs, train_batch_specs  # noqa: E402
from repro.launch.trainer import make_serve_steps, make_train_step  # noqa: E402
from repro.optim import adamw_init  # noqa: E402
from repro.roofline import analyze_hlo, roofline_terms  # noqa: E402

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(HERE, "experiments", "dryrun")
PERF = os.path.join(HERE, "experiments", "perf")


def run_variant(arch: str, shape_name: str, variants: list,
                mesh_name: str = "16x16") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name != "16x16"))
    cfg = get_model_config(arch)
    shape = get_shape(shape_name)

    if "blockwise_attn" in variants:
        cfg = dataclasses.replace(cfg, attn_blockwise_threshold=2048)
    collectives = ("serpentine" if "serpentine" in variants
                   else "ring" if "ring" in variants else "gspmd")

    t0 = time.time()
    if shape.kind == "train":
        train = TrainConfig(
            remat="dots" if "remat_dots" in variants else "full",
            optimizer_dtype="bfloat16" if "opt_bf16" in variants
            else "float32",
            collectives=collectives,
        )
        ts = make_train_step(cfg, shape, mesh, train, jit=True)
        p_abs = ts.model.abstract_params(jnp.float32)
        opt_dtype = (jnp.bfloat16 if "opt_bf16" in variants else jnp.float32)
        opt_abs = jax.eval_shape(
            lambda p: adamw_init(p, state_dtype=opt_dtype), p_abs)
        b_abs = train_batch_specs(cfg, shape)
        lowered = ts.fn.lower(p_abs, opt_abs, b_abs)
        step_kind = "train_step"
    else:
        ss = make_serve_steps(
            cfg, shape, mesh, jit=True,
            weights_tp_only="serve_tp_weights" in variants,
            cache_head_sharded="cache_head_shard" in variants,
            cache_seq_sharded="cache_seq_shard" in variants,
            cache_policy="auto" if "auto_cache" in variants else "baseline",
            collectives=collectives,
        )
        p_abs = ss.model.abstract_params(jnp.float32)
        if shape.kind == "prefill":
            b_abs = train_batch_specs(cfg, shape)
            b_abs.pop("labels", None)
            lowered = ss.prefill.lower(p_abs, b_abs)
            step_kind = "prefill_step"
        else:
            cache_abs = jax.eval_shape(
                lambda: ss.model.init_cache(shape.global_batch,
                                            shape.seq_len, jnp.bfloat16,
                                            enc_len=shape.seq_len))
            b_abs = decode_batch_specs(cfg, shape)
            lowered = ss.decode.lower(p_abs, cache_abs, b_abs)
            step_kind = "serve_step"
    compiled = lowered.compile()
    compile_s = time.time() - t0

    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text)
    n_chips = 256 if mesh_name == "16x16" else 512
    terms = roofline_terms(get_model_config(arch), shape, mesh_name,
                           step_kind, hlo, n_chips=n_chips)
    mem = compiled.memory_analysis()

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variants": variants, "compile_s": round(compile_s, 1),
        "flops": hlo.flops, "hbm_bytes": hlo.hbm_bytes,
        "collective_bytes": hlo.collective_bytes,
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "bottleneck": terms.bottleneck,
        "bound_s": terms.step_time_bound_s,
        "mfu_bound": terms.mfu_bound,
        "roofline_fraction": terms.roofline_fraction,
        "useful_ratio": terms.useful_ratio,
        "arg_bytes_per_dev": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", None),
    }

    os.makedirs(PERF, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_name}__{'+'.join(variants) or 'base'}"
    with open(os.path.join(PERF, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    with gzip.open(os.path.join(PERF, tag + ".hlo.gz"), "wt") as f:
        f.write(hlo_text)
    return result


def compare(arch: str, shape_name: str, result: dict,
            mesh_name: str = "16x16") -> None:
    base_path = os.path.join(DRYRUN, f"{arch}__{shape_name}__{mesh_name}.json")
    if not os.path.exists(base_path):
        print("no baseline found")
        return
    with open(base_path) as f:
        base = json.load(f)
    br = base["roofline"]
    bb = max(br["compute_s"], br["memory_s"], br["collective_s"])
    print(f"\n{arch} x {shape_name} [{'+'.join(result['variants'])}]")
    print(f"{'term':12s} {'baseline':>12s} {'variant':>12s} {'delta':>8s}")
    for term, bval in (("compute_s", br["compute_s"]),
                       ("memory_s", br["memory_s"]),
                       ("collective_s", br["collective_s"])):
        v = result[term]
        d = (v - bval) / bval * 100 if bval else 0.0
        print(f"{term:12s} {bval * 1e3:10.2f}ms {v * 1e3:10.2f}ms {d:+7.1f}%")
    print(f"{'bound':12s} {bb * 1e3:10.2f}ms {result['bound_s'] * 1e3:10.2f}ms "
          f"{(result['bound_s'] - bb) / bb * 100:+7.1f}%")
    print(f"roofline_fraction: {br.get('roofline_fraction', 0):.4f} -> "
          f"{result['roofline_fraction']:.4f}   "
          f"mfu_bound: {br['mfu_bound'] * 100:.2f}% -> "
          f"{result['mfu_bound'] * 100:.2f}%")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    variants = [v for v in args.variants.split(",") if v]
    res = run_variant(args.arch, args.shape, variants, args.mesh)
    compare(args.arch, args.shape, res, args.mesh)


if __name__ == "__main__":
    main()
