#!/usr/bin/env bash
# CI entry point: the tier-1 suite on CPU plus the benchmark smoke step.
#
# The suite already includes the multi-device distributed tests --
# tests/test_dist.py and tests/test_serve_policy.py spawn subprocesses with
# --xla_force_host_platform_device_count so the main pytest process keeps
# the single-device view (see the module docstrings there).
#
# PYTHONPATH=src is exported for parity with ROADMAP's tier-1 command, but
# either `pip install -e .` or tests/conftest.py makes it optional.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== smoke: benchmark harness (--dry) =="
python -m benchmarks.run --dry

echo "== smoke: overlap collectives (--dry, 4 host devices) =="
# Exercise the serpentine ring path end to end on every run: the forced
# 4-device host mesh lets the plan printout AND the lowered HLO (both
# ppermute directions) come from a real mesh, not a degenerate one.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m benchmarks.run --dry --collectives=serpentine

echo "== smoke: hierarchical planner (forced 2-host x 4-chip dry plan) =="
# The recursive planner (repro.plan) end to end on every run: the forced
# DCN level must appear in the printed tree, and the synthetic 65 GiB
# state (np*=5 on 16 GiB chips) must show the divisor-quantized FSDP
# degree (5 -> 8 on the 8-chip extent).
plan_out="$(python -m benchmarks.run --only plan --hosts 2 --chips 4)"
printf '%s\n' "$plan_out"
printf '%s\n' "$plan_out" | grep -q 'DCN\[mesh\]' \
    || { echo "FAIL: plan tree is missing the DCN level"; exit 1; }
printf '%s\n' "$plan_out" | grep -q 'np_raw=5 quantized=8' \
    || { echo "FAIL: plan tree is missing the quantized FSDP degree"; exit 1; }

echo "== smoke: plan-driven serving (forced 4-device dry) =="
# The serving engine's decode plan end to end on every run: a single-host
# 4-way TP mesh must produce a DCN-free plan whose KV page fits the VMEM
# leaf double-buffered (DESIGN.md §7).
serve_out="$(XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m benchmarks.run --only serve --dry)"
printf '%s\n' "$serve_out"
printf '%s\n' "$serve_out" | grep -q 'dcn_free=True' \
    || { echo "FAIL: serve plan is not DCN-free"; exit 1; }
printf '%s\n' "$serve_out" | grep -q 'page_fits_vmem=True' \
    || { echo "FAIL: serve plan page does not fit VMEM"; exit 1; }

echo "== smoke: paged KV pool (geometry vs page_plan) =="
# The paged engine end to end on every run: the pool's page size, table
# width and physical page count must come verbatim from plan_run's page
# level (DESIGN.md §8), and the drained pool must reconcile.
paged_out="$(python -m benchmarks.run --only paged --dry)"
printf '%s\n' "$paged_out"
printf '%s\n' "$paged_out" | grep -q 'pool_matches_plan=True' \
    || { echo "FAIL: paged pool geometry does not match page_plan"; exit 1; }

echo "== smoke: chunked prefill (chunk == planned page) =="
# Chunked prefill end to end on every run: every full prefill chunk the
# engine cuts must be exactly the planner's page -- the VMEM-fitting KV
# slice doubles as the prefill quantum (DESIGN.md §10).
prefill_out="$(python -m benchmarks.run --only prefill --dry)"
printf '%s\n' "$prefill_out"
printf '%s\n' "$prefill_out" | grep -q 'chunk_matches_page=True' \
    || { echo "FAIL: prefill chunk does not match the planned page"; exit 1; }

echo "== smoke: radix prefix cache (capacity vs plan budget) =="
# The cross-request prefix cache end to end on every run: the radix
# cache's byte capacity must be exactly the mesh-level HBM leftover the
# planner recorded (plan.prefix_budget(), DESIGN.md §11), and a request
# sharing a published prefix must hit it.
prefix_out="$(python -m benchmarks.run --only prefix --dry)"
printf '%s\n' "$prefix_out"
printf '%s\n' "$prefix_out" | grep -q 'prefix_budget_matches_plan=True' \
    || { echo "FAIL: radix cache capacity does not match the plan"; exit 1; }

echo "== smoke: tuning sweep (--dry: enumerate + VMEM filter) =="
# The autotuning harness end to end on every run, without timing anything:
# every swept candidate -- the analytic center and all its power-of-two
# neighbors -- must pass the planner's own VMEM working-set filter
# (DESIGN.md §9).
tune_out="$(python -m benchmarks.run --only tune --dry)"
printf '%s\n' "$tune_out"
printf '%s\n' "$tune_out" | grep -q 'all_candidates_fit_vmem=True' \
    || { echo "FAIL: a swept candidate exceeds the level budget"; exit 1; }

echo "== smoke: cluster fleet (replicas == DCN np, pool == plan) =="
# Multi-replica serving end to end on every run (DESIGN.md §12): the
# cluster must stand up exactly the DCN level's np replicas, each
# replica's pool geometry must be the single-host plan's page_table
# (the DCN level chooses width, never reshapes the per-replica
# subtree), and a DCN-bearing plan without cluster= must raise the
# structured PlanError.
cluster_out="$(python -m benchmarks.run --only cluster --dry)"
printf '%s\n' "$cluster_out"
printf '%s\n' "$cluster_out" | grep -q 'replicas_match_plan=True' \
    || { echo "FAIL: fleet width does not match the DCN level"; exit 1; }
printf '%s\n' "$cluster_out" | grep -q 'pool_matches_plan=True' \
    || { echo "FAIL: per-replica pool differs from the plan page_table"; exit 1; }
printf '%s\n' "$cluster_out" | grep -q 'dcn_guard_raises=True' \
    || { echo "FAIL: single-replica DCN guard did not raise PlanError"; exit 1; }

echo "== smoke: observability (trace schema + plan-vs-actual) =="
# The obs spine end to end on every run (DESIGN.md §13): the tracer's
# Chrome export must validate against the trace_event schema, every
# plan-vs-actual residual must be finite, and the pool's observed peak
# must land inside the plan's page_table budget.
obs_out="$(python -m benchmarks.run --only obs --dry)"
printf '%s\n' "$obs_out"
printf '%s\n' "$obs_out" | grep -q 'trace_schema_ok=True' \
    || { echo "FAIL: Chrome trace export does not validate"; exit 1; }
printf '%s\n' "$obs_out" | grep -q 'plan_vs_actual_ok=True' \
    || { echo "FAIL: a plan-vs-actual residual is not finite"; exit 1; }
printf '%s\n' "$obs_out" | grep -q 'pool_peak_within_plan=True' \
    || { echo "FAIL: observed pool peak exceeds the planned page_table"; exit 1; }

echo "== smoke: BENCH json emitter (schema repro-bench-v1) =="
# Every benchmark run must be able to write a committable perf artifact:
# run the cheap dry sections through --json and check the schema keys.
bench_json="$(mktemp /tmp/bench_ci_XXXX.json)"
python -m benchmarks.run --dry --only serve,paged,prefill,prefix,tune,cluster,obs \
    --json "$bench_json" > /dev/null
python - "$bench_json" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == "repro-bench-v1", doc.get("schema")
assert isinstance(doc["rows"], list) and doc["rows"], "no rows"
for row in doc["rows"]:
    assert set(row) == {"section", "name", "us_per_call", "derived"}, row
    assert isinstance(row["derived"], dict), row
assert {"created_unix", "argv", "backend", "device"} <= set(doc)
print(f"BENCH json OK: {len(doc['rows'])} rows")
EOF
rm -f "$bench_json"

echo "== smoke: committed BENCH_10.json (obs trajectory) =="
# The committed observability benchmark artifact must stay parseable
# against the same schema so the perf trajectory remains readable.
python - BENCH_10.json <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == "repro-bench-v1", doc.get("schema")
assert {"created_unix", "argv", "backend", "device"} <= set(doc)
rows = doc["rows"]
assert rows, "no rows"
for row in rows:
    assert set(row) == {"section", "name", "us_per_call", "derived"}, row
assert any(r["name"].startswith("obs_planvsactual_") for r in rows), \
    "missing plan-vs-actual rows"
assert any(r["name"].startswith("obs_ab_trace_") for r in rows), \
    "missing tracing A/B rows"
print(f"BENCH_10 OK: {len(rows)} rows")
EOF

echo "CI OK"
