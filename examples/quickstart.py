"""Quickstart: the paper's cache-conscious run-time decomposition in ~70
lines -- one recursive planner (``repro.plan``) from the host caches to the
device mesh, plus the execution engine and the TPU tile-plan view.

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""

import numpy as np

from repro.core import (
    Engine,
    matmul_domain,
    matmul_task_grid,
    paper_system_a,
    read_linux_hierarchy,
)
from repro.hw import chip_spec
from repro.plan import PlanPolicy, Workload, plan_run

# ---------------------------------------------------------------- 1. detect
# Platform-independent memory hierarchy (paper §3.1), straight from sysfs
# (containers often hide the cache indexes; fall back to the paper's
# System A so the walk below always has cache levels to plan against).
hier = read_linux_hierarchy()
if hier.find("L2") is None:
    hier = paper_system_a()
print("memory hierarchy:")
for lvl in hier.levels():
    line = f"  {lvl.name:5s} {lvl.size / 1024:10.0f} KiB"
    if lvl.cache_line_size:
        line += f"  line={lvl.cache_line_size}B"
    print(line)

# ------------------------------------------------------------- 2. decompose
# MatMult 1024x1024 against the L2 TCL: one plan_run call walks the
# hierarchy and runs Algorithm 1 + the §2.1.1 binary search at the L2 level.
n = 1024
domain = matmul_domain(n, n, n, 4)
hp = plan_run(hier, Workload(domain=tuple(domain)),
              PlanPolicy(tcl="L2", n_workers=4))
l2 = hp.level("L2")
print(f"\ncache-conscious decomposition: np={l2.np} partitions, "
      f"{l2.partition_bytes / 1024:.1f} KiB each "
      f"(TCL={l2.budget_bytes / 1024:.0f} KiB) -> "
      f"{len(matmul_task_grid(l2.np))} tasks")

# --------------------------------------------------------------- 3. execute
rng = np.random.default_rng(0)
A = rng.standard_normal((n, n)).astype(np.float32)
B = rng.standard_normal((n, n)).astype(np.float32)
C = np.zeros((n, n), np.float32)

eng = Engine(hier, n_workers=4, tcl="L2", schedule="srrc")


def make_tasks(p):
    a_r, b_r, c_r = p.regions
    side = round(np.sqrt(p.np))
    return [(a_r[i * side + k], b_r[k * side + j], c_r[i * side + j])
            for (i, j, k) in matmul_task_grid(p.np)]


def compute(task):
    a, b, c = task
    C[c] += A[a] @ B[b]


res = eng.run(matmul_domain(n, n, n, 4), compute, make_tasks=make_tasks)
err = np.max(np.abs(C - A @ B))
print(f"executed {res.n_tasks} tasks in {res.times.total * 1e3:.1f} ms "
      f"(max err {err:.2e})")
print(f"stage breakdown: decomp {res.times.decomposition * 1e3:.2f} ms, "
      f"sched {res.times.scheduling * 1e3:.2f} ms, "
      f"exec {res.times.execution * 1e3:.2f} ms")

# ------------------------------------------------------------ 4. TPU view
# The same decomposition targeting TPU v5e: plan_run on the chip hierarchy
# turns the np search output into a Pallas BlockSpec plan (DESIGN.md §2).
spec = chip_spec("tpu_v5e")
mm = plan_run(spec.hierarchy(),
              Workload(matmul=(8192, 8192, 8192), dtype_bytes=2),
              PlanPolicy(spec=spec)).tile_plan()
print(f"\nTPU v5e matmul plan: blocks {mm.bm}x{mm.bk}x{mm.bn}, "
      f"grid {mm.grid}, est VMEM {mm.est_vmem_bytes / 2 ** 20:.1f} MiB "
      f"of {spec.usable_vmem / 2 ** 20:.0f} MiB budget")

# ------------------------------------------- 5. the whole hierarchy at once
# 2 hosts x 4 chips, 65 GiB of training state: the DCN level splits the
# state across hosts, the ICI level picks the (divisor-quantized) FSDP
# degree, and the VMEM leaf is the per-chip tile plan -- one plan_run.
hp = plan_run(spec.hierarchy(mesh_devices=4, hosts=2),
              Workload(state_bytes=65 << 30, matmul=(4096, 4096, 4096)),
              PlanPolicy(spec=spec))
print("\nhierarchical plan (2 hosts x 4 chips, 65 GiB state):")
for line in hp.describe():
    print("  " + line)
print("serialized:", hp.to_json()[:60] + "...")
