"""Quickstart: the paper's cache-conscious run-time decomposition in 60
lines -- decompose, schedule, execute, and the TPU tile-plan view.

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""

import numpy as np

from repro.core import (
    Decomposer,
    Engine,
    matmul_domain,
    matmul_task_grid,
    read_linux_hierarchy,
)
from repro.core.autotile import plan_attention, plan_matmul
from repro.hw import chip_spec

# ---------------------------------------------------------------- 1. detect
# Platform-independent memory hierarchy (paper §3.1), straight from sysfs.
hier = read_linux_hierarchy()
print("memory hierarchy:")
for lvl in hier.levels():
    line = f"  {lvl.name:5s} {lvl.size / 1024:10.0f} KiB"
    if lvl.cache_line_size:
        line += f"  line={lvl.cache_line_size}B"
    print(line)

# ------------------------------------------------------------- 2. decompose
# MatMult 1024x1024 against the L2 TCL: Algorithm 1 + binary search pick np.
n = 1024
dec = Decomposer(hier, tcl="L2")
plan = dec.decompose(matmul_domain(n, n, n, 4), n_workers=4)
print(f"\ncache-conscious decomposition: np={plan.np} partitions, "
      f"{plan.partition_bytes / 1024:.1f} KiB each "
      f"(TCL={plan.tcl_bytes / 1024:.0f} KiB) -> "
      f"{len(matmul_task_grid(plan.np))} tasks")

# --------------------------------------------------------------- 3. execute
rng = np.random.default_rng(0)
A = rng.standard_normal((n, n)).astype(np.float32)
B = rng.standard_normal((n, n)).astype(np.float32)
C = np.zeros((n, n), np.float32)

eng = Engine(hier, n_workers=4, tcl="L2", schedule="srrc")


def make_tasks(p):
    a_r, b_r, c_r = p.regions
    side = round(np.sqrt(p.np))
    return [(a_r[i * side + k], b_r[k * side + j], c_r[i * side + j])
            for (i, j, k) in matmul_task_grid(p.np)]


def compute(task):
    a, b, c = task
    C[c] += A[a] @ B[b]


res = eng.run(matmul_domain(n, n, n, 4), compute, make_tasks=make_tasks)
err = np.max(np.abs(C - A @ B))
print(f"executed {res.n_tasks} tasks in {res.times.total * 1e3:.1f} ms "
      f"(max err {err:.2e})")
print(f"stage breakdown: decomp {res.times.decomposition * 1e3:.2f} ms, "
      f"sched {res.times.scheduling * 1e3:.2f} ms, "
      f"exec {res.times.execution * 1e3:.2f} ms")

# ------------------------------------------------------------ 4. TPU view
# The same decomposition, targeting TPU v5e VMEM: the np search output IS
# the Pallas BlockSpec plan (DESIGN.md §2).
spec = chip_spec("tpu_v5e")
mm = plan_matmul(8192, 8192, 8192, dtype_bytes=2, spec=spec)
print(f"\nTPU v5e matmul plan: blocks {mm.bm}x{mm.bk}x{mm.bn}, "
      f"grid {mm.grid}, est VMEM {mm.est_vmem_bytes / 2 ** 20:.1f} MiB "
      f"of {spec.usable_vmem / 2 ** 20:.0f} MiB budget")
fa = plan_attention(32768, 32768, 128, dtype_bytes=2, spec=spec)
print(f"TPU v5e attention plan: block_q={fa.block_q}, "
      f"block_kv={fa.block_kv} (32k context streams in "
      f"{fa.grid[1]} VMEM-sized partitions)")
