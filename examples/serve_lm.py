"""Serving example: the plan-driven engine (``repro.serve``, DESIGN.md §7)
over a batch of mixed-length prompts -- page size, KV head sharding, and
the admission budget all come from the hierarchical planner's decode
workload.  Try ``--arch mixtral-8x7b`` for the sliding-window ring cache,
``--arch deepseek-v2-236b`` for the MLA latent cache, or
``--sampling top_k --top_k 40`` for seeded sampling (reduced-size
variants run on this CPU).

Run: ``PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]``
(or, after ``pip install -e .``: ``repro-serve --arch zamba2-1.2b``).
"""

import sys

args = sys.argv[1:] or ["--arch", "llama3.2-1b", "--tokens", "24",
                        "--batch", "4", "--prompt_len", "48",
                        "--mixed", "1"]

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(args))
