"""Batched serving example: prefill a prompt batch, then greedy-decode with
the family-appropriate KV cache (try ``--arch mixtral-8x7b`` for the
sliding-window ring cache or ``--arch deepseek-v2-236b`` for the MLA latent
cache -- reduced-size variants run on this CPU).

Run: ``PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]``
"""

import sys

args = sys.argv[1:] or ["--arch", "llama3.2-1b", "--tokens", "24",
                        "--batch", "4", "--prompt_len", "48"]

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(args))
