"""Paper benchmark demo: SOR stencil under cache-conscious vs horizontal
decomposition on this machine's real caches (Table 3 reproduction).

Run: ``PYTHONPATH=src python examples/sor_stencil.py``
"""

import sys

sys.path.insert(0, ".")

from benchmarks.paper_cpu import HIER, bench_gaussianblur, bench_sor  # noqa: E402

print("detected hierarchy:",
      ", ".join(f"{l.name}={l.size // 1024}KiB" for l in HIER.cache_levels()))

r = bench_sor(n=1536, sweeps=3)
print(f"SOR 1536^2:          cache-conscious {r.cc_s * 1e3:7.1f} ms  "
      f"horizontal {r.hz_s * 1e3:7.1f} ms  speedup {r.speedup:.2f}x "
      f"(np={r.np_cc})")

r = bench_gaussianblur(n=1536, radius=5)
print(f"GaussianBlur 1536-5: cache-conscious {r.cc_s * 1e3:7.1f} ms  "
      f"horizontal {r.hz_s * 1e3:7.1f} ms  speedup {r.speedup:.2f}x "
      f"(np={r.np_cc})")
