"""End-to-end training driver: train a ~100M-param dense LM for a few
hundred steps on the host mesh, with checkpointing and preemption handling.

Run: ``PYTHONPATH=src python examples/train_lm.py [--steps 300]``
(Defaults are sized for this CPU container; on TPU hardware the same script
scales by flipping ``--reduced false --arch deepseek-coder-33b``.)
"""

import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [
    "--arch", "qwen2-0.5b",
    "--steps", "300",
    "--seq_len", "128",
    "--batch", "16",
    "--train.learning_rate", "1e-3",
    "--train.warmup_steps", "30",
    "--train.checkpoint_every", "100",
    "--train.checkpoint_dir", "/tmp/repro_train_lm",
])

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
