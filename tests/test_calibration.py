"""Calibration artifact satellite: ``launch/dryrun.py --calibrate`` writes
``experiments/calibration.json`` and ``ModelConfig.overhead`` defaults
from it when the registered config leaves overhead at 1.0 (explicit
per-arch overheads always win)."""

import json

import pytest

from repro.configs.base import CALIBRATION_ENV


@pytest.fixture
def cal_env(tmp_path, monkeypatch):
    path = tmp_path / "calibration.json"
    monkeypatch.setenv(CALIBRATION_ENV, str(path))
    yield path


def test_write_calibration_folds_worst_cell(cal_env):
    from repro.launch.dryrun import write_calibration

    records = [
        {"arch": "llama3.2-1b", "shape": "train_4k", "mesh": "16x16",
         "calibration_ratio": 0.8, "overhead": 1.0},
        {"arch": "llama3.2-1b", "shape": "decode_32k", "mesh": "16x16",
         "calibration_ratio": 0.5, "overhead": 1.0},
    ]
    write_calibration(records, path=str(cal_env))
    data = json.loads(cal_env.read_text())
    assert data["llama3.2-1b"]["overhead"] == pytest.approx(2.0)
    assert data["llama3.2-1b"]["worst_cell"] == "decode_32k@16x16"
    # Partial re-runs merge: a second arch joins, the first survives.
    write_calibration(
        [{"arch": "qwen2-0.5b", "shape": "train_4k", "mesh": "16x16",
          "calibration_ratio": 0.9, "overhead": 1.0}], path=str(cal_env))
    data = json.loads(cal_env.read_text())
    assert set(data) >= {"llama3.2-1b", "qwen2-0.5b"}


def test_act_scale_folds_and_applies(cal_env):
    """The replicated (activation) term calibrates like ``overhead``:
    ``write_calibration`` folds the worst ``act_ratio`` into ``act_scale``
    and ``activation_footprint`` scales by it."""
    from repro.configs import get_model_config, get_shape
    from repro.launch.dryrun import write_calibration
    from repro.launch.specs import activation_footprint

    cfg = get_model_config("llama3.2-1b")
    shape = get_shape("train_4k")
    base = activation_footprint(cfg, shape, "full")   # no artifact: scale 1
    write_calibration([
        {"arch": "llama3.2-1b", "shape": "train_4k", "mesh": "16x16",
         "calibration_ratio": 1.0, "overhead": 1.0,
         "act_ratio": 0.5, "act_scale": 1.0},
    ], path=str(cal_env))
    data = json.loads(cal_env.read_text())
    assert data["llama3.2-1b"]["act_scale"] == pytest.approx(2.0)
    assert activation_footprint(cfg, shape, "full") == \
        pytest.approx(2.0 * base, rel=0.01)
    # A fit that says the model already covers the residual clamps at 1.0.
    write_calibration([
        {"arch": "qwen2-0.5b", "shape": "train_4k", "mesh": "16x16",
         "calibration_ratio": 1.0, "overhead": 1.0,
         "act_ratio": 3.0, "act_scale": 1.0},
    ], path=str(cal_env))
    data = json.loads(cal_env.read_text())
    assert data["qwen2-0.5b"]["act_scale"] == 1.0
    # A rerun with no train cells (serve shapes fit no activation term)
    # carries the previously calibrated act_scale forward.
    write_calibration([
        {"arch": "llama3.2-1b", "shape": "decode_32k", "mesh": "16x16",
         "calibration_ratio": 0.9, "overhead": 1.0},
    ], path=str(cal_env))
    data = json.loads(cal_env.read_text())
    assert data["llama3.2-1b"]["act_scale"] == pytest.approx(2.0)


def test_model_config_defaults_overhead_from_artifact(cal_env):
    from repro.configs import get_model_config

    cal_env.write_text(json.dumps({
        "llama3.2-1b": {"overhead": 1.7},
        "mixtral-8x7b": {"overhead": 3.0},
    }))
    # Default-overhead arch picks the measured value up...
    assert get_model_config("llama3.2-1b").overhead == 1.7
    # ...an explicitly calibrated registration does not.
    assert get_model_config("mixtral-8x7b").overhead == 1.25


def test_missing_or_broken_artifact_is_harmless(cal_env):
    from repro.configs import get_model_config

    assert get_model_config("llama3.2-1b").overhead == 1.0
    cal_env.write_text("{not json")
    assert get_model_config("llama3.2-1b").overhead == 1.0


def test_artifact_rewrite_is_picked_up_in_process(cal_env):
    """The stat-keyed cache must see a rewrite (e.g. ``dryrun --calibrate``
    running in the same process) without manual invalidation."""
    from repro.configs import get_model_config

    assert get_model_config("llama3.2-1b").overhead == 1.0
    cal_env.write_text(json.dumps({"llama3.2-1b": {"overhead": 1.5}}))
    assert get_model_config("llama3.2-1b").overhead == 1.5
