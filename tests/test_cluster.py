"""repro.cluster: the DCN level as a real placement decision (DESIGN.md
§12).

What is pinned here:

  * Router properties: the ``free_pages`` policy always lands on the
    argmax-free-pages admissible replica, ties break deterministically
    (outstanding load, then lowest id), drained replicas are never
    admitted.  Prefix affinity overrides the policy only after a prefix
    has a home.
  * The worker protocol (both transports): instruction queue in, demuxed
    token streams / results / errors / telemetry ticks out; drain
    requeues not-yet-started work; the straggler sweep drains on routed
    TTFT evidence.
  * Plan admissibility: ``plan_decode`` raises the structured
    ``PlanError`` on a DCN-bearing plan without ``cluster=``;
    ``cluster=N`` realizes N replicas WITHOUT reshaping the per-replica
    page geometry.
  * Token identity: a routed 2-replica cluster emits byte-identical
    per-request streams to a single ``ServeEngine``, for all four served
    families; disaggregated prefill->decode is token-identical too, and
    decode admission is gated on the last page's arrival.
"""

import json
import time
import urllib.request

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import (ClusterServer, DisaggCluster, PageStreamReceiver,
                           EngineSpec, Replica, ReplicaStats, Router,
                           ServeCluster, StubSpec, export_transfer,
                           import_transfer, transfer_order)
from repro.configs import get_model_config
from repro.ft.resilience import StragglerPolicy
from repro.hw.tpu import chip_spec
from repro.launch.mesh import make_host_mesh
from repro.serve import ServeEngine, ServePolicy
from repro.serve.engine import PlanError, plan_decode

#: One arch per served family, as in test_serve_engine: dense attention,
#: MoE (sliding-window), hybrid SSM (Mamba2 + shared attn), xLSTM.
FOUR_FAMILIES = ["llama3.2-1b", "mixtral-8x7b", "zamba2-1.2b", "xlstm-1.3b"]

#: Tiny forced VMEM so the planned page is small and several pages per
#: sequence are exercised (the same knob the paged/prefix tests use).
SMALL = dict(vmem_bytes=16 << 10, vmem_reserved_bytes=0)


def _stats(free, drained=(), queued=None):
    return [ReplicaStats(replica=i, free_pages=f,
                         queued=0 if queued is None else queued[i],
                         drained=i in drained)
            for i, f in enumerate(free)]


# ---------------------------------------------------------------------------
# Router properties
# ---------------------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(f0=st.integers(0, 64), f1=st.integers(0, 64), f2=st.integers(0, 64),
       drain0=st.booleans(), drain1=st.booleans())
def test_free_pages_routes_to_argmax_admissible(f0, f1, f2, drain0, drain1):
    drained = {i for i, d in ((0, drain0), (1, drain1)) if d}
    router = Router(3, "free_pages", affinity=False)
    stats = _stats([f0, f1, f2], drained=drained)
    pick = router.route(stats)
    live = [i for i in range(3) if i not in drained]
    assert pick in live                      # drained never admitted
    assert stats[pick].free_pages == max(stats[i].free_pages for i in live)
    # Deterministic: equal-load ties go to the LOWEST admissible id.
    best = max(stats[i].free_pages for i in live)
    assert pick == min(i for i in live if stats[i].free_pages == best)


def test_free_pages_tie_breaks_on_load_then_id():
    router = Router(2, "free_pages", affinity=False)
    assert router.route(_stats([8, 8])) == 0            # pure tie: lowest id
    assert router.route(_stats([8, 8], queued=[3, 0])) == 1   # load breaks it
    assert router.route(_stats([8, 9], queued=[0, 5])) == 1   # memory first


def test_all_drained_raises():
    router = Router(2, "free_pages")
    router.drain(0)
    with pytest.raises(RuntimeError, match="drained"):
        router.route(_stats([4, 4], drained={1}))


def test_round_robin_cycles_admissible_only():
    router = Router(3, "round_robin", affinity=False)
    stats = _stats([1, 1, 1], drained={1})
    assert [router.route(stats) for _ in range(4)] == [0, 2, 0, 2]


def test_least_loaded_prefers_fewest_outstanding():
    router = Router(3, "least_loaded", affinity=False)
    assert router.route(_stats([0, 0, 0], queued=[2, 0, 1])) == 1


def test_prefix_affinity_sticks_after_first_placement():
    router = Router(2, "free_pages", page_tokens=4)
    toks = list(range(8))                   # two full pages
    assert router.route(_stats([1, 9]), toks) == 1
    # The home replica keeps the prefix even once it is page-poor...
    assert router.route(_stats([9, 1]), toks) == 1
    # ...but a sub-page prompt has no affinity key and follows the policy.
    assert router.route(_stats([9, 1]), list(range(3))) == 0
    # A drained home is rerouted (and re-homed) instead of starved.
    router.drain(1)
    assert router.route(_stats([9, 1], drained={1}), toks) == 0


def test_straggler_sweep_drains_and_undrain_forgets():
    pol = StragglerPolicy(k=1.0, min_samples=2)
    router = Router(3, "round_robin", affinity=False, straggler=pol)
    for _ in range(4):
        router.note_latency(0, 0.01)
        router.note_latency(1, 0.01)
        router.note_latency(2, 5.0)         # the outlier
    assert router.sweep_stragglers() == [2]
    assert 2 in router.drained
    router.undrain(2)
    assert 2 not in router.drained
    assert pol.history.get(2) is None       # fresh samples after re-admit
    assert router.sweep_stragglers() == []


# ---------------------------------------------------------------------------
# Worker protocol (stub engines: no JAX)
# ---------------------------------------------------------------------------


def test_thread_replica_streams_and_ticks():
    rep = Replica(StubSpec(), replica=0, transport="thread")
    try:
        got = []
        call = rep.generate([[1, 2, 3]], 4, on_token=lambda i, t: got.append(t))
        assert call.wait(30) == [[6, 7, 8, 9]]
        assert got == [6, 7, 8, 9]          # streamed == returned
        assert call.first_token_time is not None
        st = rep.stats()
        assert st.tokens == 4 and st.replica == 0
    finally:
        rep.close()


def test_proc_replica_same_protocol_over_spawn():
    rep = Replica(StubSpec(), replica=1, transport="proc")
    try:
        got = []
        call = rep.generate([[5, 5]], 3, on_token=lambda i, t: got.append(t))
        assert call.wait(120) == [[10, 11, 12]]
        assert got == [10, 11, 12]
        for _ in range(200):                # the tick is asynchronous
            if rep.last_stats is not None:
                break
            time.sleep(0.05)
        st = rep.stats()
        assert st.replica == 1 and st.tokens == 3
    finally:
        rep.close()


def test_worker_error_reply_keeps_replica_alive():
    rep = Replica(StubSpec(), replica=0, transport="thread")
    try:
        bad = rep.submit("no_such_op", None)
        with pytest.raises(RuntimeError, match="no_such_op"):
            bad.wait(30)
        assert rep.generate([[1]], 1).wait(30) == [[1]]
    finally:
        rep.close()


def test_drain_requeues_pending_requests():
    slow = Replica(StubSpec(delay_s=0.2), replica=0, transport="thread")
    fast = Replica(StubSpec(), replica=1, transport="thread")
    cluster = ServeCluster([slow, fast], Router(2, "round_robin",
                                                affinity=False))
    try:
        first = cluster.submit([1], 4)      # replica 0, starts immediately
        assert first.replica == 0
        for _ in range(100):
            if first.call.started:
                break
            time.sleep(0.01)
        queued = cluster.submit([2], 2)     # round robin -> 1
        queued2 = cluster.submit([3], 2)    # round robin -> 0: queues
        assert queued2.replica == 0
        moved = cluster.drain_replica(0)
        assert queued2.rid in moved and queued2.replica == 1
        assert queued2.result(30) == [3, 4]
        assert queued.result(30) == [2, 3]
        assert first.result(30) == [1, 2, 3, 4]     # in-flight: finishes
        # Drained replica takes no NEW work.
        after = cluster.submit([4], 1)
        assert after.replica == 1
    finally:
        cluster.close()


def test_cluster_stats_marks_drained():
    cluster = ServeCluster([Replica(StubSpec(), replica=i)
                            for i in range(2)],
                           Router(2, "free_pages", affinity=False))
    try:
        cluster.router.drain(1)
        st = cluster.stats()
        assert [s.drained for s in st] == [False, True]
    finally:
        cluster.close()


# ---------------------------------------------------------------------------
# Plan admissibility (satellite: the structured PlanError)
# ---------------------------------------------------------------------------


def test_dcn_plan_without_cluster_raises_plan_error():
    cfg = get_model_config("llama3.2-1b").reduced()
    spec = chip_spec(**SMALL)
    with pytest.raises(PlanError) as ei:
        plan_decode(cfg, make_host_mesh(), max_len=64, spec=spec,
                    hierarchy=spec.hierarchy(mesh_devices=1, hosts=2))
    assert ei.value.level == "DCN"
    assert ei.value.plan is not None and ei.value.plan.level("DCN") is not None


def test_cluster_plan_width_without_reshaping_replica_geometry():
    cfg = get_model_config("llama3.2-1b").reduced()
    spec = chip_spec(**SMALL)
    mesh = make_host_mesh()
    fleet = plan_decode(cfg, mesh, max_len=64, spec=spec, cluster=2)
    single = plan_decode(cfg, mesh, max_len=64, spec=spec)
    dcn = fleet.level("DCN")
    assert fleet.replicas() == dcn.np == 2
    assert dcn.detail["placement"] == "replicas"
    # The DCN level chooses WIDTH; the per-replica subtree is untouched.
    assert dict(fleet.page_table()) == dict(single.page_table())
    assert fleet.page_plan()["page_tokens"] == \
        single.page_plan()["page_tokens"]
    assert single.replicas() == 1 and single.level("DCN") is None


# ---------------------------------------------------------------------------
# engine.stats() (satellite: consolidated telemetry)
# ---------------------------------------------------------------------------


def test_engine_stats_consolidates_pool_and_prefix():
    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=2, max_len=64, max_slots=1,
                           batching="paged", prefix_cache="radix"),
        spec=chip_spec(**SMALL))
    keys = {"batching", "free_pages", "used_pages", "pages_total",
            "slots_free", "slots_total", "page_tokens", "page_bytes",
            "kv_shard", "tokens", "decode_steps", "prefill_chunks",
            "prefix_nodes", "prefix_pages", "prefix_resident_bytes"}
    before = engine.stats()
    assert keys <= set(before)
    assert before.pages_total if False else before["pages_total"] > 0
    t = engine.page.page_tokens
    rng = np.random.default_rng(0)
    engine.generate([rng.integers(0, cfg.vocab_size, 2 * t + 1,
                                  dtype=np.int32)], 2)
    after = engine.stats()
    # Live pool telemetry: the radix tree keeps the prompt's completed
    # pages resident, so the pool is visibly less free than the plan.
    assert after["prefix_nodes"] >= 1
    assert after["prefix_pages"] >= 1
    assert after["free_pages"] < after["pages_total"]
    assert after["used_pages"] > 0
    assert after["tokens"] >= 2


# ---------------------------------------------------------------------------
# Token identity (satellite: routed cluster == single engine, 4 families)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FOUR_FAMILIES)
def test_cluster_token_identical_to_single_engine(arch):
    cfg = get_model_config(arch).reduced()
    policy = ServePolicy(max_new_tokens=3, max_len=64, max_slots=1,
                         batching="paged", prefix_cache="radix")
    single = ServeEngine(cfg, make_host_mesh(), policy=policy,
                         spec=chip_spec(**SMALL))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32).tolist()
               for n in (9, 13, 8)]
    ref = [single.generate([p], 3)[0] for p in prompts]

    plan = plan_decode(cfg, make_host_mesh(), max_len=64,
                       spec=chip_spec(**SMALL), cluster=2)
    spec = EngineSpec(arch=arch, max_new_tokens=3, max_slots=1, max_len=64,
                      chip=tuple(SMALL.items()))
    cluster = ServeCluster.from_plan(plan, spec, transport="thread",
                                     policy="free_pages")
    try:
        assert len(cluster.replicas) == plan.replicas() == 2
        streamed = {i: [] for i in range(len(prompts))}
        crs = [cluster.submit(p, 3,
                              on_token=lambda _i, t, j=j: (
                                  streamed[j].clear() if t is None
                                  else streamed[j].append(t)))
               for j, p in enumerate(prompts)]
        got = [cr.result(timeout=600) for cr in crs]
        assert got == ref, arch
        assert [streamed[j] for j in range(len(prompts))] == ref, arch
        # Every replica engine's pool geometry is the plan's page_table.
        for rep in cluster.replicas:
            if rep.engine is not None:
                assert rep.engine.metrics["plan_page_table"] == \
                    dict(single.plan.page_table() or {}), arch
    finally:
        cluster.close()


# ---------------------------------------------------------------------------
# Disaggregation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["ring", "serpentine"])
@pytest.mark.parametrize("p", [1, 2, 3, 4, 7])
def test_transfer_order_covers_every_page_once(p, mode):
    order = transfer_order(p, mode)
    assert sorted(order) == list(range(p)), (p, mode, order)


def test_receiver_gates_admission_on_last_page():
    recv = PageStreamReceiver(3)
    recv.receive(0, {"k": 0})
    recv.receive(2, {"k": 2})
    assert not recv.complete
    with pytest.raises(RuntimeError, match="gated"):
        recv.payloads()                     # page 1 never arrived
    recv.receive(1, {"k": 1})
    assert recv.payloads() == [{"k": 0}, {"k": 1}, {"k": 2}]


def test_disagg_prefill_decode_token_identical():
    cfg = get_model_config("llama3.2-1b").reduced()
    policy = ServePolicy(max_new_tokens=4, max_len=128, max_slots=1,
                         batching="paged", prefix_cache="radix")
    single = ServeEngine(cfg, make_host_mesh(), policy=policy,
                         spec=chip_spec(**SMALL))
    t = single.page.page_tokens
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 2 * t + 3,
                          dtype=np.int32).tolist()
    ref = single.generate([prompt], 4)[0]

    plan = plan_decode(cfg, make_host_mesh(), max_len=128,
                       spec=chip_spec(**SMALL), cluster=2)
    spec = EngineSpec(arch="llama3.2-1b", max_new_tokens=4, max_slots=1,
                      max_len=128, chip=tuple(SMALL.items()))
    dc = DisaggCluster.from_plan(plan, spec, split="1:1",
                                 transport="thread")
    try:
        got = dc.generate(prompt, 4)
        assert got == ref
        # The transferred pages produced a real prefix hit on decode.
        dec = dc.decode[0].engine
        assert dec.metrics["prefix_hit_tokens"] >= 2 * t
        # And the export endpoint round-trips standalone too.
        tr = export_transfer(dc.prefill[0], prompt)
        assert tr.n_pages == 2 and tr.first_token == ref[0]
        assert sorted(tr.order) == list(range(tr.n_pages))
        assert import_transfer(dc.decode[0], tr) == 2 * t
    finally:
        dc.close()


def test_disagg_split_must_partition_planned_fleet():
    cfg = get_model_config("llama3.2-1b").reduced()
    plan = plan_decode(cfg, make_host_mesh(), max_len=64,
                       spec=chip_spec(**SMALL), cluster=2)
    with pytest.raises(ValueError, match="partition"):
        DisaggCluster.from_plan(plan, StubSpec(), split="2:2")


# ---------------------------------------------------------------------------
# HTTP front end (stub cluster: protocol only)
# ---------------------------------------------------------------------------


def test_http_generate_streams_chunked_ndjson():
    cluster = ServeCluster([Replica(StubSpec(), replica=i)
                            for i in range(2)],
                           Router(2, "round_robin", affinity=False))
    srv = ClusterServer(cluster).start()
    try:
        host, port = srv.address
        base = f"http://{host}:{port}"
        with urllib.request.urlopen(f"{base}/healthz", timeout=10) as r:
            assert json.loads(r.read()) == \
                {"ok": True, "replicas": 2, "admissible": 2}
        req = urllib.request.Request(
            f"{base}/generate",
            data=json.dumps({"tokens": [1, 2],
                             "max_new_tokens": 3}).encode(),
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=30) as r:
            assert r.headers.get("Transfer-Encoding") == "chunked"
            lines = [json.loads(x) for x in r.read().splitlines()
                     if x.strip()]
        assert [l["token"] for l in lines if "token" in l] == [3, 4, 5]
        assert lines[-1]["done"] and lines[-1]["tokens"] == [3, 4, 5]
        with urllib.request.urlopen(f"{base}/stats", timeout=10) as r:
            doc = json.loads(r.read())
        assert doc["policy"] == "round_robin"
        assert len(doc["replicas"]) == 2
        assert {"free_pages", "slots_free", "prefix_nodes"} <= \
            set(doc["replicas"][0])
        with urllib.request.urlopen(f"{base}/nope", timeout=10) as r:
            pass
    except urllib.error.HTTPError as e:
        assert e.code == 404
    finally:
        srv.close()
        cluster.close()
