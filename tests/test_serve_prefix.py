"""Acceptance tests for the cross-request radix prefix cache (ISSUE 8,
DESIGN.md §11).

* **Pool refcount guards** -- ``PagePool.free`` is a decref: double
  frees and frees of never-allocated pages raise instead of silently
  corrupting the free list, and a page shared by incref stays OUT of the
  free list until its last reference drops.
* **Radix-tree properties** -- under random insert / match / evict / pool
  -pressure sequences the invariants hold after every op: refcounts equal
  the number of references (simulated slot tables + tree nodes), pool
  flow counters reconcile (``assert_reconciled``), and the resident tree
  never exceeds ``prefix_budget``.
* **Token identity** -- for all four served families, greedy generation
  with ``prefix_cache="radix"`` is token-identical to the cold-cache run
  when the shared prefix ends mid-page (forcing the CoW path on
  attention families) and exactly on a page boundary -- with the engine
  metrics pinning the hit length (``prefix_hit_tokens == N``, rounded
  down to page granularity for recurrent-state families), pages saved
  and the CoW count.
* **Cross-call persistence** -- the radix tree and its device pages
  survive between ``generate`` calls: a second call's request hits a
  prefix inserted by the first call.
* **Plan accessor** -- ``plan.prefix_budget()`` reads the page level's
  recorded HBM leftover and survives JSON round-trips (including plans
  serialized before the field existed).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_model_config
from repro.hw.tpu import chip_spec
from repro.launch.mesh import make_host_mesh
from repro.serve import ServeEngine, ServePolicy

#: Tiny forced VMEM so the planned page is small and sharing/CoW is
#: exercised with short prompts (as in test_serve_paged).
SMALL = dict(vmem_bytes=16 << 10, vmem_reserved_bytes=0)

#: One arch per served family: dense, MoE (sliding-window), hybrid SSM,
#: xLSTM (token-free -- state snapshots only).
FOUR_FAMILIES = ["llama3.2-1b", "mixtral-8x7b", "zamba2-1.2b", "xlstm-1.3b"]

#: Families whose hits restore a recurrent-state snapshot and therefore
#: round DOWN to page boundaries (serve.prefix.STATE_FAMILIES).
STATE_ARCHS = {"zamba2-1.2b", "xlstm-1.3b"}


# ---------------------------------------------------------------------------
# Satellite: PagePool refcount guards
# ---------------------------------------------------------------------------


class TestPoolRefcounts:
    def test_double_free_raises(self):
        from repro.serve.pages import PagePool

        pool = PagePool(5)
        ids = pool.alloc(2)
        pool.free(ids)
        with pytest.raises(ValueError, match="double free"):
            pool.free([ids[0]])

    def test_free_of_never_allocated_page_raises(self):
        from repro.serve.pages import PagePool

        pool = PagePool(5)
        pool.alloc(1)
        with pytest.raises(ValueError, match="double free|never-allocated"):
            pool.free([3])                # page 3 was never handed out

    def test_null_page_free_raises(self):
        from repro.serve.pages import PagePool

        with pytest.raises(ValueError, match="null page"):
            PagePool(5).free([0])

    def test_shared_page_survives_first_free(self):
        from repro.serve.pages import PagePool

        pool = PagePool(5)
        (pid,) = pool.alloc(1)
        pool.incref(pid)                  # second mapping (rc=2)
        before = pool.free_pages
        pool.free([pid])                  # decref: still referenced
        assert pool.free_pages == before
        assert pool.refcount(pid) == 1
        assert pool.used_pages == 1       # physically still used
        pool.free([pid])                  # last reference: really freed
        assert pool.refcount(pid) == 0
        assert pool.used_pages == 0
        with pytest.raises(ValueError, match="double free"):
            pool.free([pid])
        pool.assert_reconciled()

    def test_incref_of_free_page_raises(self):
        from repro.serve.pages import PagePool

        pool = PagePool(5)
        with pytest.raises(ValueError, match="free page"):
            pool.incref(1)                # never allocated
        with pytest.raises(ValueError, match="invalid page"):
            pool.incref(0)                # the null page


# ---------------------------------------------------------------------------
# Satellite: radix-tree property test
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       page_tokens=st.sampled_from([4, 8]),
       pool_pages=st.integers(6, 24),
       budget_pages=st.integers(1, 8),
       vocab=st.sampled_from([2, 3]))
def test_radix_tree_invariants(seed, page_tokens, pool_pages, budget_pages,
                               vocab):
    """Random insert/match/evict sequences against a simulated slot
    population.  After EVERY operation:

      * ``pool.total_refs`` equals the number of slot-table references
        plus the tree's page references (every mapping is one refcount);
      * pool flow counters reconcile (``assert_reconciled``: cumulative
        alloc - release == used, free list duplicate-free, refcounts
        consistent with the free list);
      * the resident tree never exceeds the ``prefix_budget`` it was
        given (evicting down to the budget on every insert).

    The tiny vocabulary makes random prompts collide on prefixes, so the
    hit path (increfs + CoW allocation) is genuinely exercised."""
    from repro.serve.pages import PagePool
    from repro.serve.prefix import RadixPrefixCache

    rng = np.random.default_rng(seed)
    t = page_tokens
    page_bytes = t * 16
    pool = PagePool(pool_pages + 1)       # +1: reserved null page 0
    cache = RadixPrefixCache(t, page_bytes, budget_pages * page_bytes,
                             pool, has_state=False)
    tables = {}                           # sid -> simulated slot pages
    next_sid = 0

    def check(inflight=0):
        # ``inflight``: references held by a request mid-prefill, before
        # its page table is published into ``tables``.
        pool.assert_reconciled()
        slot_refs = sum(len(v) for v in tables.values())
        assert pool.total_refs == slot_refs + cache.n_pages + inflight, \
            "refcounts out of sync with references"
        assert cache.resident_bytes <= cache.budget_bytes, \
            "resident tree exceeded prefix_budget"
        assert cache.n_pages * page_bytes <= cache.resident_bytes + 1e-9

    for _ in range(60):
        op = rng.random()
        if op < 0.5:
            # "Run a request": match, then allocate the suffix pages the
            # way chunked prefill would, publish on completion.
            plen = int(rng.integers(1, 5 * t))
            toks = rng.integers(0, vocab, plen).astype(np.int64)
            hit = cache.admit(toks)
            pages = list(hit.pages) if hit else []
            check(inflight=len(pages))
            aborted = False
            while len(pages) * t < plen + 1:
                ids = pool.alloc(1)
                if ids is None:
                    cache.release_pages(need=1)
                    ids = pool.alloc(1)
                if ids is None:
                    # Pool exhausted mid-prefill: recompute preemption --
                    # drop every reference this request took.
                    pool.free(pages)
                    aborted = True
                    break
                pages.extend(ids)
            check(inflight=0 if aborted else len(pages))
            if aborted:
                continue
            cache.insert(toks, pages)
            tables[next_sid] = pages
            next_sid += 1
        elif op < 0.8 and tables:
            # Finish a request: its slot's references drop; pages the
            # tree also holds stay resident.
            sid = rng.choice(list(tables))
            pool.free(tables.pop(sid))
        else:
            # Pool pressure / explicit eviction.
            cache.release_pages(need=int(rng.integers(1, 4)))
        check()

    # Drain: finish every request, then evict the whole tree -- the pool
    # must reconcile back to empty (alloc == release, no leaked refs).
    for pages in tables.values():
        pool.free(pages)
    tables.clear()
    cache.clear()
    check()
    assert pool.used_pages == 0
    assert pool.total_refs == 0
    assert pool.pages_allocated == pool.pages_released


# ---------------------------------------------------------------------------
# Token identity: prefix-hit generation == cold-cache generation
# ---------------------------------------------------------------------------


def _engine(cfg, prefix, max_len, max_slots=1):
    return ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(max_new_tokens=4, max_len=max_len,
                           max_slots=max_slots, batching="paged",
                           prefix_cache=prefix),
        spec=chip_spec(**SMALL))


def _shared_prefix_prompts(cfg, T, geometry, rng):
    """Two prompts sharing ``N`` tokens: ``N = 2.5 pages`` (mid-page --
    the divergence point is inside a completed page, forcing CoW on
    attention families) or ``N = 2 pages`` (exact boundary).  Tails are
    long enough that the FIRST request's divergent page completes (only
    completed pages enter the tree) and differ at their first token."""
    n = 2 * T + (T // 2 if geometry == "mid_page" else 0)
    shared = rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
    tails = [rng.integers(0, cfg.vocab_size, T // 2 + 2, dtype=np.int32)
             for _ in range(2)]
    tails[1][0] = (tails[0][0] + 1) % cfg.vocab_size
    return n, [np.concatenate([shared, t]) for t in tails]


@pytest.mark.parametrize("geometry", ["mid_page", "page_boundary"])
@pytest.mark.parametrize("arch", FOUR_FAMILIES)
def test_prefix_hit_token_identity(arch, geometry):
    cfg = get_model_config(arch).reduced()
    rng = np.random.default_rng(0xA11CE)
    probe = _engine(cfg, "off", max_len=64)
    T = probe.page.page_tokens
    max_len = 4 * T + 8
    n, prompts = _shared_prefix_prompts(cfg, T, geometry, rng)
    # max_slots=1 serializes the two requests through one slot, so the
    # second admission sees the first request's published prefix.
    cold = _engine(cfg, "off", max_len).generate(prompts, max_new_tokens=4)
    warm_eng = _engine(cfg, "radix", max_len)
    warm = warm_eng.generate(prompts, max_new_tokens=4)
    assert warm == cold, f"{arch}/{geometry}: prefix hit changed tokens"

    m = warm_eng.metrics
    assert m["prefix_hits"] == 1
    # Attention families reuse the shared prefix exactly (CoW inside the
    # divergent page); recurrent-state families round down to the page
    # boundary where a state snapshot exists.
    expect = (n // T) * T if arch in STATE_ARCHS else n
    assert m["prefix_hit_tokens"] == expect, \
        f"{arch}/{geometry}: hit {m['prefix_hit_tokens']} != {expect}"
    assert m["pages_saved"] > 0
    if geometry == "mid_page" and arch not in STATE_ARCHS:
        assert m["cow_copies"] == 1, "mid-page divergence must CoW"
    else:
        assert m["cow_copies"] == 0
    # The suffix is the only prefill the second request ran.
    plen = len(prompts[0])
    assert m["prefill_tokens"] == plen + (plen - expect)


def test_prefix_cache_persists_across_generate_calls():
    cfg = get_model_config("llama3.2-1b").reduced()
    rng = np.random.default_rng(0xBEEF)
    eng = _engine(cfg, "radix", max_len=136)
    T = eng.page.page_tokens
    shared = rng.integers(0, cfg.vocab_size, 3 * T, dtype=np.int32)
    tails = [rng.integers(0, cfg.vocab_size, T - 2, dtype=np.int32)
             for _ in range(2)]
    tails[1][0] = (tails[0][0] + 1) % cfg.vocab_size
    a, b = [np.concatenate([shared, t]) for t in tails]
    out_a = eng.generate([a], max_new_tokens=4)
    assert eng.metrics["prefix_hits"] == 0
    out_b = eng.generate([b], max_new_tokens=4)
    assert eng.metrics["prefix_hits"] == 1
    assert eng.metrics["prefix_hit_tokens"] == 3 * T
    # And the hit run emits exactly what a cold engine emits.
    cold = _engine(cfg, "off", max_len=136)
    assert cold.generate([a], max_new_tokens=4) == out_a
    assert cold.generate([b], max_new_tokens=4) == out_b


def test_identical_prompt_rehit_cows_final_page():
    """A fully-cached prompt still computes its LAST token (the logits
    source): the hit caps at ``prompt_len - 1`` and CoWs the final
    matched page instead of replaying the whole prompt."""
    cfg = get_model_config("llama3.2-1b").reduced()
    rng = np.random.default_rng(3)
    eng = _engine(cfg, "radix", max_len=136)
    T = eng.page.page_tokens
    prompt = rng.integers(0, cfg.vocab_size, 3 * T, dtype=np.int32)
    out1 = eng.generate([prompt], max_new_tokens=4)
    out2 = eng.generate([prompt], max_new_tokens=4)
    assert out1 == out2
    m = eng.metrics
    assert m["prefix_hits"] == 1
    assert m["prefix_hit_tokens"] == 3 * T - 1
    assert m["cow_copies"] == 1


# ---------------------------------------------------------------------------
# Plan accessor
# ---------------------------------------------------------------------------


def test_prefix_budget_accessor_and_roundtrip():
    from repro.core.plan import HierarchicalPlan

    cfg = get_model_config("llama3.2-1b").reduced()
    eng = _engine(cfg, "off", max_len=64)
    plan = eng.plan
    ptab = plan.page_table()
    assert ptab is not None and "prefix_budget_bytes" in ptab
    budget = plan.prefix_budget()
    assert budget == ptab["prefix_budget_bytes"] and budget > 0
    # JSON round-trip preserves it.
    rt = HierarchicalPlan.from_json(plan.to_json())
    assert rt.prefix_budget() == budget
    # Plans serialized BEFORE the field existed fall back to the
    # pages_total x global-page-bytes product.
    d = rt.to_dict()

    def strip(node):
        if node is None:
            return
        pt = (node.get("detail") or {}).get("page_table")
        if pt is not None:
            pt.pop("prefix_budget_bytes", None)
        strip(node.get("child"))

    strip(d)
    legacy = HierarchicalPlan.from_dict(d)
    page = legacy.page_plan()
    expect = (legacy.page_table()["pages_total"] * page["page_tokens"]
              * page["tok_bytes"] * page["layers"] * page["kv_shard"])
    assert legacy.prefix_budget() == expect


def test_xlstm_prefix_budget_is_none():
    """Token-free families have no page level: the accessor returns None
    and the engine falls back to the scheduler budget."""
    cfg = get_model_config("xlstm-1.3b").reduced()
    eng = _engine(cfg, "off", max_len=64)
    assert eng.plan.prefix_budget() is None
