"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; asserts shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config, get_shape, list_archs
from repro.launch.specs import make_batch
from repro.models import build_model

ARCHS = list_archs()


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def _reduced(arch):
    cfg = get_model_config(arch).reduced()
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_and_grad(arch, rng):
    cfg, model, params = _reduced(arch)
    shape = get_shape("train_4k", smoke=True)
    batch = make_batch(cfg, shape, rng, kind="train")

    def loss_fn(p):
        loss, metrics = model.loss(p, batch, dtype=jnp.float32)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert jnp.isfinite(loss), (arch, loss)
    # Loss should be near ln(vocab) for random params.
    assert 0.5 * np.log(cfg.vocab_size) < float(metrics["nll"]) < 3 * np.log(
        cfg.vocab_size), (arch, float(metrics["nll"]))
    # All grads finite, at least one nonzero.
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_logits_shape(arch, rng):
    cfg, model, params = _reduced(arch)
    shape = get_shape("train_4k", smoke=True)
    batch = make_batch(cfg, shape, rng, kind="train")
    logits, aux = model.forward(params, batch, dtype=jnp.float32)
    assert logits.shape == (shape.global_batch, shape.seq_len, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch, rng):
    cfg, model, params = _reduced(arch)
    shape = get_shape("decode_32k", smoke=True)
    max_len = shape.seq_len + 4
    prompt = make_batch(cfg, shape, rng, kind="train")
    logits0, cache = model.prefill(params, prompt, max_len, dtype=jnp.float32)
    assert logits0.shape == (shape.global_batch, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits0))), arch

    step = make_batch(cfg, shape, rng, kind="decode")
    for _ in range(2):
        logits, cache = model.decode_step(params, cache, step,
                                          dtype=jnp.float32)
        assert logits.shape == (shape.global_batch, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert int(cache["pos"]) == shape.seq_len + 2


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b", "zamba2-1.2b",
                                  "xlstm-1.3b", "deepseek-v2-236b"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode must reproduce the training forward's logits --
    the cache path and the parallel path are the same function."""
    cfg, model, params = _reduced(arch)
    if cfg.moe is not None:
        # No-drop capacity: token dropping is a train-time semantic; the
        # teacher-forced equivalence only holds without drops.
        model.capacity_factor = float(cfg.moe.n_experts)
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    logits_par, _ = model.forward(params, batch, dtype=jnp.float32)

    # Prefill 1 token, then decode the rest step by step.
    cache = None
    logits_steps = []
    first = {"tokens": tokens[:, :1], "labels": tokens[:, :1]}
    lg, cache = model.prefill(params, first, max_len=s + 1, dtype=jnp.float32)
    logits_steps.append(lg)
    for t in range(1, s):
        lg, cache = model.decode_step(
            params, cache, {"tokens": tokens[:, t:t + 1]}, dtype=jnp.float32)
        logits_steps.append(lg)
    logits_dec = jnp.stack(logits_steps, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_par), np.asarray(logits_dec), rtol=2e-2, atol=2e-2)


def test_sliding_window_ring_cache(rng):
    """Mixtral-style SWA: ring cache (size=window) must agree with a full
    cache when the context exceeds the window."""
    cfg = get_model_config("mixtral-8x7b").reduced()
    model = build_model(cfg, remat="none")
    model.capacity_factor = float(cfg.moe.n_experts)   # no-drop (see above)
    params = model.init(jax.random.PRNGKey(1))
    w = cfg.sliding_window
    b, s = 1, w + 8   # exceed the window

    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_par, _ = model.forward(
        params, {"tokens": tokens, "labels": tokens}, dtype=jnp.float32)

    lg, cache = model.prefill(params, {"tokens": tokens[:, :1]},
                              max_len=s + 1, dtype=jnp.float32)
    outs = [lg]
    for t in range(1, s):
        lg, cache = model.decode_step(
            params, cache, {"tokens": tokens[:, t:t + 1]}, dtype=jnp.float32)
        outs.append(lg)
    # Ring cache buffer never exceeds the window.
    assert cache["layers"]["k"].shape[2] <= w + 1
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_par), np.asarray(logits_dec), rtol=2e-2, atol=2e-2)
