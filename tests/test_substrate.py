"""Substrate tests: data pipeline, checkpointing, fault-tolerance helpers,
optimizer, gradient compression."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_checkpoint, save_checkpoint
from repro.configs import TrainConfig
from repro.data import DataPipeline, SyntheticLMDataset
from repro.ft import PreemptionHandler, StepWatchdog, StragglerPolicy
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_gradient,
    decompress_gradient,
    ef_state_init,
    lr_schedule,
)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic_batches(self):
        ds = SyntheticLMDataset(vocab_size=1000, seq_len=64, seed=42)
        a = ds.batch(7, 16)
        b = ds.batch(7, 16)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch(8, 16)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLMDataset(vocab_size=1000, seq_len=64, seed=0)
        b = ds.batch(0, 4)
        assert b["tokens"].shape == (4, 64)
        assert b["labels"].shape == (4, 64)
        # labels[i] == tokens[i+1] within the stream.
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions_global_batch(self):
        ds = SyntheticLMDataset(vocab_size=100, seq_len=16, seed=1)
        full = ds.batch(3, 8)
        parts = []
        pipes = []
        for h in range(4):
            p = DataPipeline(ds, global_batch=8, host_index=h, host_count=4,
                             start_step=3, prefetch=1)
            pipes.append(p)
            step, hb = next(p)
            assert step == 3
            parts.append(hb["tokens"])
        for p in pipes:
            p.close()
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])

    def test_resume_from_step(self):
        ds = SyntheticLMDataset(vocab_size=100, seq_len=16, seed=1)
        p = DataPipeline(ds, global_batch=4, start_step=11, prefetch=1)
        step, hb = next(p)
        p.close()
        assert step == 11
        np.testing.assert_array_equal(hb["tokens"], ds.batch(11, 4)["tokens"])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

class TestCkpt:
    def _tree(self, x=1.0):
        return {"a": np.full((4, 4), x, np.float32),
                "b": {"c": np.arange(6).reshape(2, 3)}}

    def test_atomic_save_and_latest(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 5, self._tree(1.0))
        save_checkpoint(d, 9, self._tree(2.0))
        assert latest_checkpoint(d).endswith("step_00000009")
        # A stale .tmp dir must never be picked up.
        os.makedirs(os.path.join(d, "step_00000099.tmp"))
        assert latest_checkpoint(d).endswith("step_00000009")

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(float(s)), blocking=True)
        names = sorted(os.listdir(tmp_path))
        assert names == ["step_00000003", "step_00000004"]

    def test_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = self._tree(3.5)
        mgr.save(12, tree, blocking=True)
        restored, manifest = mgr.restore_latest(self._tree(0.0))
        assert manifest["step"] == 12
        np.testing.assert_array_equal(restored["a"], tree["a"])
        np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])

    def test_restore_missing_leaf_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"a": np.zeros(2)}, blocking=True)
        with pytest.raises(KeyError):
            mgr.restore_latest({"a": np.zeros(2), "zz": np.zeros(3)})

    def test_namedtuple_state_roundtrip(self, tmp_path):
        params = {"w": jnp.ones((3, 3))}
        opt = adamw_init(params)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(2, (params, opt), blocking=True)
        tpl = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), (params, opt))
        (rp, ro), m = mgr.restore_latest(tpl)
        assert m["step"] == 2
        assert int(ro.step) == 0
        np.testing.assert_array_equal(rp["w"], np.ones((3, 3)))


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

class TestFT:
    def test_preemption_flag(self):
        h = PreemptionHandler()
        assert not h.should_stop
        h.request_stop()
        assert h.should_stop

    def test_watchdog_fires_on_slow_step(self):
        fired = []
        wd = StepWatchdog(deadline_s=0.05,
                          on_timeout=lambda s, dt: fired.append((s, dt)))
        wd.start_step(3)
        time.sleep(0.15)
        wd.end_step()
        assert fired and fired[0][0] == 3

    def test_watchdog_quiet_on_fast_step(self):
        fired = []
        wd = StepWatchdog(deadline_s=0.5,
                          on_timeout=lambda s, dt: fired.append(s))
        wd.start_step(1)
        wd.end_step()
        time.sleep(0.05)
        assert not fired

    def test_straggler_detection(self):
        pol = StragglerPolicy(k=3.0, min_samples=4)
        for t in range(10):
            for host in range(8):
                pol.record(host, 1.0 + (3.0 if host == 5 else 0.0)
                           + 0.01 * t)
        assert pol.stragglers() == [5]
        plan = pol.replacement_plan(spares=[100, 101])
        assert plan == {5: 100}


# ---------------------------------------------------------------------------
# Optimizer + compression
# ---------------------------------------------------------------------------

class TestOptim:
    def test_adamw_minimizes_quadratic(self):
        cfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, grad_clip=10.0)
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw_init(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(params, grads, opt, cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.3

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
        got = float(jnp.linalg.norm(clipped["a"]))
        assert got == pytest.approx(1.0, rel=1e-4)

    def test_lr_schedule_warmup_and_decay(self):
        cfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
        fn = lr_schedule(cfg)
        assert float(fn(jnp.asarray(0))) == pytest.approx(0.0, abs=1e-6)
        assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(fn(jnp.asarray(100))) < 0.11

    def test_bf16_compression_roundtrip(self):
        g = {"w": jnp.linspace(-1, 1, 64, dtype=jnp.float32)}
        wire, scales, _ = compress_gradient(g, "bf16")
        assert wire["w"].dtype == jnp.bfloat16
        out = decompress_gradient(wire, "bf16", scales)
        np.testing.assert_allclose(out["w"], g["w"], atol=1e-2)

    def test_int8_ef_error_feedback_converges(self):
        """Error feedback: accumulated quantized gradients track the true
        sum (residual carried, not lost)."""
        g = {"w": jnp.array([0.001, 0.5, -0.3], jnp.float32)}
        ef = ef_state_init(g)
        total = jnp.zeros(3)
        for _ in range(50):
            wire, scales, ef = compress_gradient(g, "int8_ef", ef)
            assert wire["w"].dtype == jnp.int8
            total = total + decompress_gradient(wire, "int8_ef", scales)["w"]
        np.testing.assert_allclose(np.asarray(total),
                                   np.asarray(g["w"]) * 50, rtol=0.02,
                                   atol=5e-3)
