"""Distributed-runtime tests that need >1 device: run in subprocesses with
``--xla_force_host_platform_device_count`` (the main test process must keep
the single-device view)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(n: int, body: str) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_ag_matmul_ring_matches_reference():
    run_with_devices(4, """
        from repro.dist.overlap import make_ag_matmul
        mesh = jax.make_mesh((4,), ("model",))
        fn = make_ag_matmul(mesh, axis="model")
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 32), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 48), jnp.float32)
        y = fn(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)
        print("ag_matmul ok")
    """)


def test_rs_matmul_ring_matches_reference():
    run_with_devices(4, """
        from repro.dist.overlap import make_rs_matmul
        mesh = jax.make_mesh((4,), ("model",))
        fn = make_rs_matmul(mesh, axis="model")
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 32), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 48), jnp.float32)
        y = fn(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)
        print("rs_matmul ok")
    """)


def test_pipeline_gpipe_matches_sequential():
    run_with_devices(4, """
        from repro.dist.pipeline import make_pipeline
        mesh = jax.make_mesh((4,), ("pod",))

        def stage_fn(params, x):
            return jnp.tanh(x @ params["w"])

        key = jax.random.PRNGKey(0)
        stages = {"w": jax.random.normal(key, (4, 16, 16)) * 0.5}
        mbs = jax.random.normal(jax.random.fold_in(key, 1), (6, 8, 16))
        fn = make_pipeline(mesh, stage_fn, axis="pod")
        out = fn(stages, mbs)

        ref = mbs
        for sidx in range(4):
            ref = jnp.tanh(ref @ stages["w"][sidx])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("pipeline ok")
    """)


def test_train_step_loss_decreases_on_mesh():
    """End-to-end SPMD training sanity: tiny model, 2x2 mesh, loss drops."""
    run_with_devices(4, """
        from repro.configs import get_model_config, get_shape, TrainConfig
        from repro.configs.base import ShapeConfig
        from repro.launch.trainer import make_train_step, init_sharded_state
        from repro.data import SyntheticLMDataset

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
        train = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                            total_steps=60, remat="none")
        ts = make_train_step(cfg, shape, mesh, train)
        params, opt = init_sharded_state(ts, mesh, 0, train)

        ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
        losses = []
        for step in range(40):
            batch = ds.batch(step % 4, 8)
            batch = {k: jax.device_put(v, ts.batch_sharding[k])
                     for k, v in batch.items()}
            params, opt, metrics = ts.fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[::8]
        print("first/last:", losses[0], losses[-1])
    """)


def test_elastic_checkpoint_restore_across_meshes():
    """Save on a (4,1) mesh, restore on (2,2): topology-independent ckpt."""
    import tempfile
    tmp = tempfile.mkdtemp()
    run_with_devices(4, f"""
        from repro.configs import get_model_config, TrainConfig
        from repro.configs.base import ShapeConfig
        from repro.launch.trainer import make_train_step, init_sharded_state
        from repro.ckpt import CheckpointManager

        cfg = get_model_config("qwen2-0.5b").reduced()
        shape = ShapeConfig("tiny", seq_len=16, global_batch=4, kind="train")
        train = TrainConfig(remat="none")

        mesh1 = jax.make_mesh((4, 1), ("data", "model"))
        ts1 = make_train_step(cfg, shape, mesh1, train)
        params, opt = init_sharded_state(ts1, mesh1, 0, train)
        mgr = CheckpointManager({tmp!r}, keep=2)
        mgr.save(7, (params, opt), blocking=True)

        # "Relaunch" on a different topology.
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        ts2 = make_train_step(cfg, shape, mesh2, train)
        p2, o2 = init_sharded_state(ts2, mesh2, 1, train)

        from repro.launch.trainer import _flatten_with_paths
        flat_s = _flatten_with_paths((ts2.param_sharding, ts2.opt_sharding))
        def reshard(key, arr):
            s = flat_s.get(key)
            return jax.device_put(arr, s) if s is not None else jnp.asarray(arr)
        template = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), (p2, o2))
        restored, manifest = mgr.restore_latest(template, reshard=reshard)
        assert manifest["step"] == 7
        rp, ro = restored
        a = np.asarray(jax.tree.leaves(params)[0])
        b = np.asarray(jax.tree.leaves(rp)[0])
        np.testing.assert_array_equal(a, b)
        print("elastic restore ok")
    """)


def test_collectives_equivalence_gspmd_ring_serpentine():
    """Acceptance: gspmd / ring / serpentine agree to fp32 tolerance on a
    4-device host mesh, for both the all-gather and reduce-scatter rings."""
    run_with_devices(4, """
        from repro.dist.overlap import make_ag_matmul, make_rs_matmul
        mesh = jax.make_mesh((4,), ("model",))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 32), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 48), jnp.float32)
        ref = np.asarray(x @ w)                     # the gspmd path
        for make, name in ((make_ag_matmul, "ag"), (make_rs_matmul, "rs")):
            for mode in ("ring", "serpentine"):
                y = np.asarray(make(mesh, axis="model", mode=mode)(x, w))
                np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5,
                                           err_msg=f"{name}/{mode}")
        print("collectives equivalence ok")
    """)


def test_serpentine_odd_size_error_messages():
    """Serpentine needs an even per-chip k chunk (ag) / even n (rs); the
    error must name the mode and the fix."""
    run_with_devices(4, """
        from repro.dist.overlap import make_ag_matmul, make_rs_matmul
        mesh = jax.make_mesh((4,), ("model",))
        ag = make_ag_matmul(mesh, axis="model", mode="serpentine")
        try:
            ag(jnp.zeros((8, 12)), jnp.zeros((12, 8)))   # kb = 3, odd
            raise SystemExit("expected ValueError for odd k chunk")
        except ValueError as e:
            assert "serpentine" in str(e) and "even" in str(e), e
            assert "mode='ring'" in str(e), e
        rs = make_rs_matmul(mesh, axis="model", mode="serpentine")
        try:
            rs(jnp.zeros((8, 8)), jnp.zeros((8, 5)))     # n = 5, odd
            raise SystemExit("expected ValueError for odd n")
        except ValueError as e:
            assert "serpentine" in str(e) and "even" in str(e), e
        print("odd-size error messages ok")
    """)


def test_model_forward_equivalence_under_overlap_collectives():
    """The layers-level dispatch: a full model forward under
    with_collectives(ring|serpentine) matches the gspmd forward."""
    run_with_devices(4, """
        from repro.configs import get_model_config
        from repro.configs.base import ShapeConfig
        from repro.dist.sharding import (arch_rules, use_mesh_rules,
                                         with_collectives)
        from repro.launch.specs import make_batch
        from repro.models.model import build_model

        mesh = jax.make_mesh((1, 4), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        model = build_model(cfg, remat="none")
        params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        shape = ShapeConfig("tiny", seq_len=16, global_batch=4, kind="train")
        batch = make_batch(cfg, shape, np.random.default_rng(0),
                           dtype=jnp.float32)
        rules = arch_rules(cfg, mesh)
        outs = {}
        for mode in ("gspmd", "ring", "serpentine"):
            r = with_collectives(rules, mode) if mode != "gspmd" else rules
            def fwd(p, b, r=r):
                with use_mesh_rules(mesh, r):
                    return model.forward(p, b, dtype=jnp.float32)[0]
            outs[mode] = np.asarray(jax.jit(fwd)(params, batch))
        np.testing.assert_allclose(outs["ring"], outs["gspmd"],
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(outs["serpentine"], outs["gspmd"],
                                   rtol=2e-4, atol=2e-4)
        print("model forward equivalence ok")
    """)


def test_train_step_with_serpentine_collectives():
    """Trainer wiring: TrainConfig(collectives="serpentine") trains (grads
    flow through both ppermute directions) and the loss decreases."""
    run_with_devices(4, """
        from repro.configs import get_model_config, TrainConfig
        from repro.configs.base import ShapeConfig
        from repro.data import SyntheticLMDataset
        from repro.launch.trainer import make_train_step, init_sharded_state

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        shape = ShapeConfig("tiny", seq_len=32, global_batch=8, kind="train")
        train = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                            total_steps=60, remat="none",
                            collectives="serpentine")
        ts = make_train_step(cfg, shape, mesh, train)
        assert ts is not None
        params, opt = init_sharded_state(ts, mesh, 0, train)
        ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
        losses = []
        for step in range(20):
            batch = ds.batch(step % 4, 8)
            batch = {k: jax.device_put(v, ts.batch_sharding[k])
                     for k, v in batch.items()}
            params, opt, metrics = ts.fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0], losses
        print("serpentine train:", losses[0], "->", losses[-1])
    """)
