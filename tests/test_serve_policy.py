"""Serve cache-policy tests: the auto policy must pick head sharding when
kv_heads divides the model axis, sequence sharding otherwise, and never
produce duplicate-axis specs (subprocess with a multi-device mesh)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


def test_auto_policy_head_shards_when_divisible():
    _run("""
        from repro.configs import get_model_config
        from repro.configs.base import ShapeConfig
        from repro.launch.trainer import make_serve_steps

        import dataclasses
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        cfg = dataclasses.replace(cfg, n_kv_heads=4, n_heads=4)  # 4 % 4 == 0
        shape = ShapeConfig("d", 64, 4, "decode")
        ss = make_serve_steps(cfg, shape, mesh, dtype=jnp.float32,
                              cache_policy="auto")
        spec = ss.cache_sharding["layers"]["k"].spec
        # (L, B, S, KV, hd): head dim sharded, seq dim not.
        assert spec[2] is None and spec[3] == "model", spec
        print("head-shard ok", spec)
    """)


def test_auto_policy_seq_shards_when_heads_dont_divide():
    _run("""
        import dataclasses
        from repro.configs import get_model_config
        from repro.configs.base import ShapeConfig
        from repro.launch.trainer import make_serve_steps

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        cfg = dataclasses.replace(cfg, n_kv_heads=2, n_heads=4)  # 2 % 4 != 0
        shape = ShapeConfig("d", 64, 4, "decode")
        ss = make_serve_steps(cfg, shape, mesh, dtype=jnp.float32,
                              cache_policy="auto")
        spec = ss.cache_sharding["layers"]["k"].spec
        assert spec[2] == "model" and spec[3] is None, spec
        print("seq-shard ok", spec)
    """)


def test_auto_policy_decode_step_runs_and_matches_baseline():
    """Auto vs baseline placement must produce identical logits."""
    _run("""
        import dataclasses
        import numpy as np
        from repro.configs import get_model_config
        from repro.configs.base import ShapeConfig
        from repro.launch.trainer import make_serve_steps
        from repro.launch.specs import make_batch

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        cfg = dataclasses.replace(cfg, n_kv_heads=2, n_heads=4)
        shape = ShapeConfig("d", 64, 4, "decode")

        outs = {}
        for policy in ("baseline", "auto"):
            rng = np.random.default_rng(0)   # identical prompt per policy
            ss = make_serve_steps(cfg, shape, mesh, dtype=jnp.float32,
                                  cache_policy=policy, max_len_extra=4)
            params = ss.model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
            prompt = make_batch(cfg, shape, rng, kind="train")
            prompt.pop("labels", None)
            logits, cache = ss.prefill(params, prompt)
            step = {"tokens": jnp.ones((4, 1), jnp.int32)}
            logits, cache = ss.decode(params, cache, step)
            outs[policy] = np.asarray(logits)
        np.testing.assert_allclose(outs["auto"], outs["baseline"],
                                   rtol=5e-4, atol=5e-4)
        print("auto == baseline logits")
    """)
