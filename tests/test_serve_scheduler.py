"""Scheduler property tests (ISSUE 4 satellite): the resident KV bytes
never exceed the planned budget under random admit / grow / finish /
evict sequences, and the page accounting always reconciles with a
from-scratch recomputation.  Pure python -- no jax."""

import random

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.kvcache import PageSpec
from repro.serve.scheduler import Request, ServeScheduler


def _recompute_allocated(sched: ServeScheduler) -> int:
    total = 0
    for c in sched._cohorts.values():
        per_slot = c.pages_per_slot * sched.page.page_bytes
        total += sum(per_slot + r.state_bytes for r in c.reqs)
    return total


def _check(sched: ServeScheduler) -> None:
    assert sched.allocated_bytes <= sched.budget_bytes, \
        "resident KV exceeded the planned budget"
    assert sched.allocated_bytes == _recompute_allocated(sched)
    assert sched.peak_bytes <= sched.budget_bytes


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       page_tokens=st.sampled_from([8, 16, 64]),
       budget_pages=st.integers(min_value=4, max_value=64))
def test_resident_kv_never_exceeds_budget(seed, page_tokens, budget_pages):
    rng = random.Random(seed)
    page = PageSpec(page_tokens=page_tokens, token_bytes=32)
    budget = budget_pages * page.page_bytes
    sched = ServeScheduler(budget, page, max_slots=rng.choice([1, 2, 4]))
    rid = 0
    for _ in range(rng.randint(10, 60)):
        op = rng.random()
        running = sched.running()
        if op < 0.35:
            sched.submit(Request(
                rid=rid,
                prompt_len=rng.randint(1, page_tokens * 2),
                max_new=rng.randint(1, 8),
                state_bytes=rng.choice([0, 64, 1024])))
            rid += 1
        elif op < 0.60:
            try:
                sched.admit()
            except ValueError:
                # A lone oversized head request: legitimately refused.
                sched.pending.popleft()
        elif op < 0.80 and running:
            cid = rng.choice(running)
            cap = sched.capacity_tokens(cid) + page_tokens
            sched.reserve(cid, cap)     # may refuse; never overflows
        elif op < 0.92 and running:
            cid = rng.choice(running)
            c = sched._cohorts[cid]
            todo = [r.rid for r in c.reqs if r.rid not in c.done]
            if todo:
                sched.finish(cid, rng.choice(todo))
        elif running:
            sched.evict(rng.choice(running))
        _check(sched)
    # Drain: finishing everything releases every page.
    for cid in list(sched.running()):
        c = sched._cohorts[cid]
        for r in list(c.reqs):
            if r.rid not in c.done:
                sched.finish(cid, r.rid)
        _check(sched)
    assert sched.allocated_bytes == 0


def test_admission_is_fifo_and_groups_by_prompt_shape():
    page = PageSpec(page_tokens=8, token_bytes=1)
    sched = ServeScheduler(10_000, page, max_slots=4)
    for rid, plen in enumerate([8, 8, 16, 8]):
        sched.submit(Request(rid=rid, prompt_len=plen, max_new=2))
    admitted = sched.admit()
    # Head group (len 8) first -- including the queued rid=3 -- then len 16.
    assert [sorted(r.rid for r in batch) for _, batch in admitted] == \
        [[0, 1, 3], [2]]


def test_eviction_requeues_unfinished_at_front():
    page = PageSpec(page_tokens=8, token_bytes=1)
    sched = ServeScheduler(10_000, page, max_slots=2)
    for rid in range(3):
        sched.submit(Request(rid=rid, prompt_len=8, max_new=2))
    (cid, batch), (cid2, _) = sched.admit()
    sched.finish(cid, batch[0].rid)
    revived = sched.evict(cid)
    assert [r.rid for r in revived] == [batch[1].rid]
    assert sched.pending[0].rid == batch[1].rid
    assert sched.allocated_bytes == _recompute_allocated(sched)


def test_oversized_request_is_rejected_not_starved():
    page = PageSpec(page_tokens=8, token_bytes=100)
    sched = ServeScheduler(BUDGET := 1_000, page)
    sched.submit(Request(rid=0, prompt_len=1_000, max_new=1))
    try:
        sched.admit()
    except ValueError as e:
        assert "budget" in str(e)
    else:
        raise AssertionError("oversized request was admitted")
    assert sched.allocated_bytes == 0 and BUDGET == sched.budget_bytes
