"""Scheduler property tests (ISSUE 4 satellite): the resident KV bytes
never exceed the planned budget under random admit / grow / finish /
evict sequences, and the page accounting always reconciles with a
from-scratch recomputation.  Pure python -- no jax."""

import random

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.kvcache import PageSpec
from repro.serve.scheduler import Request, ServeScheduler


def _recompute_allocated(sched: ServeScheduler) -> int:
    total = 0
    for c in sched._cohorts.values():
        per_slot = c.pages_per_slot * sched.page.page_bytes
        total += sum(per_slot + r.state_bytes for r in c.reqs)
    return total


def _check(sched: ServeScheduler) -> None:
    assert sched.allocated_bytes <= sched.budget_bytes, \
        "resident KV exceeded the planned budget"
    assert sched.allocated_bytes == _recompute_allocated(sched)
    assert sched.peak_bytes <= sched.budget_bytes
    # Pool-accounting invariant (ISSUE 5 satellite): the cumulative page
    # flow reconciles with the resident count after EVERY op -- including
    # compaction, which used to release bytes without crediting the flow.
    sched.assert_reconciled()


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       page_tokens=st.sampled_from([8, 16, 64]),
       budget_pages=st.integers(min_value=4, max_value=64))
def test_resident_kv_never_exceeds_budget(seed, page_tokens, budget_pages):
    rng = random.Random(seed)
    page = PageSpec(page_tokens=page_tokens, token_bytes=32)
    budget = budget_pages * page.page_bytes
    sched = ServeScheduler(budget, page, max_slots=rng.choice([1, 2, 4]))
    rid = 0
    for _ in range(rng.randint(10, 60)):
        op = rng.random()
        running = sched.running()
        if op < 0.35:
            sched.submit(Request(
                rid=rid,
                prompt_len=rng.randint(1, page_tokens * 2),
                max_new=rng.randint(1, 8),
                state_bytes=rng.choice([0, 64, 1024])))
            rid += 1
        elif op < 0.60:
            try:
                sched.admit()
            except ValueError:
                # A lone oversized head request: legitimately refused.
                sched.pending.popleft()
        elif op < 0.80 and running:
            cid = rng.choice(running)
            cap = sched.capacity_tokens(cid) + page_tokens
            sched.reserve(cid, cap)     # may refuse; never overflows
        elif op < 0.88 and running:
            cid = rng.choice(running)
            c = sched._cohorts[cid]
            todo = [r.rid for r in c.reqs if r.rid not in c.done]
            if todo:
                sched.finish(cid, rng.choice(todo))
        elif op < 0.94 and running:
            # Compaction: keep a random subset of the cohort's slots (the
            # engine's growth-boundary ``_compact``); dropped slots' pages
            # must be credited back to the flow counters.
            cid = rng.choice(running)
            c = sched._cohorts[cid]
            keep = [r.rid for r in c.reqs
                    if r.rid not in c.done or rng.random() < 0.4]
            sched.shrink_slots(cid, keep)
        elif running:
            sched.evict(rng.choice(running))
        _check(sched)
    # Drain: finishing everything releases every page.
    for cid in list(sched.running()):
        c = sched._cohorts[cid]
        for r in list(c.reqs):
            if r.rid not in c.done:
                sched.finish(cid, r.rid)
        _check(sched)
    assert sched.allocated_bytes == 0


def test_admission_is_fifo_and_groups_by_prompt_shape():
    page = PageSpec(page_tokens=8, token_bytes=1)
    sched = ServeScheduler(10_000, page, max_slots=4)
    for rid, plen in enumerate([8, 8, 16, 8]):
        sched.submit(Request(rid=rid, prompt_len=plen, max_new=2))
    admitted = sched.admit()
    # Head group (len 8) first -- including the queued rid=3 -- then len 16.
    assert [sorted(r.rid for r in batch) for _, batch in admitted] == \
        [[0, 1, 3], [2]]


def test_eviction_requeues_unfinished_at_front():
    page = PageSpec(page_tokens=8, token_bytes=1)
    sched = ServeScheduler(10_000, page, max_slots=2)
    for rid in range(3):
        sched.submit(Request(rid=rid, prompt_len=8, max_new=2))
    (cid, batch), (cid2, _) = sched.admit()
    sched.finish(cid, batch[0].rid)
    revived = sched.evict(cid)
    assert [r.rid for r in revived] == [batch[1].rid]
    assert sched.pending[0].rid == batch[1].rid
    assert sched.allocated_bytes == _recompute_allocated(sched)


def test_oversized_request_is_rejected_not_starved():
    page = PageSpec(page_tokens=8, token_bytes=100)
    sched = ServeScheduler(BUDGET := 1_000, page)
    sched.submit(Request(rid=0, prompt_len=1_000, max_new=1))
    try:
        sched.admit()
    except ValueError as e:
        assert "budget" in str(e)
    else:
        raise AssertionError("oversized request was admitted")
    assert sched.allocated_bytes == 0 and BUDGET == sched.budget_bytes


# ---------------------------------------------------------------------------
# Paged slot scheduler (ISSUE 5): the page pool's free list, the slot
# tables, and the cumulative flow counters must agree after every op.
# ---------------------------------------------------------------------------


def _check_paged(sched, pool) -> None:
    assert pool.used_pages == sched.used_pages_by_slots(), \
        "pool free list out of sync with the slot tables"
    assert pool.pages_allocated - pool.pages_released == pool.used_pages, \
        "page flow counters do not reconcile"
    assert 0 <= pool.free_pages <= pool.pages_total - 1


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       page_tokens=st.sampled_from([8, 16]),
       pool_pages=st.integers(min_value=3, max_value=24))
def test_paged_pool_accounting_reconciles(seed, page_tokens, pool_pages):
    from repro.serve.pages import PagePool, PagedScheduler

    rng = random.Random(seed)
    page = PageSpec(page_tokens=page_tokens, token_bytes=32)
    pool = PagePool(pool_pages + 1)           # +1: the reserved null page
    sched = PagedScheduler(pool, page, n_slots=rng.choice([1, 2, 4]),
                           pages_per_slot=8)
    rid = 0
    for _ in range(rng.randint(10, 60)):
        op = rng.random()
        active = sched.active()
        if op < 0.30:
            sched.submit(Request(rid=rid,
                                 prompt_len=rng.randint(1, page_tokens * 2),
                                 max_new=rng.randint(1, 8)))
            rid += 1
        elif op < 0.55:
            try:
                for slot, req, ids, _hit in sched.admit():
                    assert 0 not in ids       # null page never granted
            except ValueError:
                sched.pending.popleft()       # genuinely oversized head
        elif op < 0.75 and active:
            i = rng.choice(active)
            s = sched.slots[i]
            old_pos = s.pos
            s.pos += rng.randint(1, page_tokens)
            if not sched.ensure_capacity(i):
                if not sched.table_full(i):
                    v = sched.victim(i)
                    if v is not None:
                        sched.evict(v)
                if not sched.ensure_capacity(i):
                    s.pos = old_pos           # stalled: retry later
            # The logical table bound is enforced, not just advisory.
            assert len(s.pages) <= sched.pages_per_slot
        elif op < 0.85 and active:
            i = rng.choice(active)
            sched.reclaim_window(i, window=rng.choice([8, 24]))
        elif active:
            sched.finish(rng.choice(active))
        _check_paged(sched, pool)
    for i in list(sched.active()):            # drain
        sched.finish(i)
        _check_paged(sched, pool)
    assert pool.used_pages == 0
    assert pool.pages_allocated == pool.pages_released
