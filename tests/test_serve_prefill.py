"""Differential-testing net over chunked prefill (DESIGN.md §10).

Four nets, one per failure mode the chunked path could introduce:

* **Chunk-decomposition identity** -- for every served family (incl. the
  newly paged MLA and enc-dec), cutting the prompt into planned-page
  chunks must produce exactly the tokens of the whole-prompt
  (monolithic) run through the same direct-to-pool path.  Prompt lengths
  are chosen so ``prompt_len % page_tokens != 0``: the partial final
  chunk is its own jit bucket and the most likely place for an
  off-by-one.
* **Interleave** -- a resident decode slot keeps emitting tokens while a
  long prompt prefills chunk by chunk (the engine trace shows decode
  events BETWEEN chunk events, at most one chunk per slot between
  consecutive decodes), and prefill never stages KV outside the pool
  (``install_slot`` is gone; the chunks' pages ARE the decode cache).
* **Scheduler properties** -- under randomized admission / preemption /
  chunk / reclaim sequences, page-flow counters reconcile every tick, a
  decode slot stalls only when eviction provably cannot help, and every
  request still completes with its exact token count.
* **One layer body** -- cohort prefill, chunked prefill, cohort decode
  and paged decode all execute the SAME ``_tf_layer`` function object
  (the PR's refactor), and a chunk-written pool reads identically under
  the Pallas paged kernel and the ``kernels/ref.py`` gather.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_model_config
from repro.hw.tpu import chip_spec
from repro.launch.mesh import make_host_mesh
from repro.serve import ServeEngine, ServePolicy

#: Tiny forced VMEM so the planned page (= prefill chunk) is small and the
#: chunk loop actually runs several iterations per prompt.
SMALL = dict(vmem_bytes=16 << 10, vmem_reserved_bytes=0)

#: Every family with a paged decode path (serve.pages.PAGED_FAMILIES).
PAGED_ARCHS = [
    "llama3.2-1b",        # dense
    "mixtral-8x7b",       # moe + sliding window
    "deepseek-v2-236b",   # mla_moe (paged latent cache)
    "whisper-large-v3",   # enc_dec (paged decoder self-KV + cross state)
    "zamba2-1.2b",        # hybrid_ssm (pool + per-slot recurrent state)
    "xlstm-1.3b",         # token-free (state only; chunks cut state scans)
]


def _prompt(cfg, plen, rng):
    if cfg.family == "enc_dec":
        return {
            "enc_embeds": (rng.standard_normal((10, cfg.d_model))
                           .astype(np.float32) * 0.02),
            "tokens": rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
        }
    return rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)


def _engine(cfg, prefill, max_slots=2):
    return ServeEngine(
        cfg, make_host_mesh(),
        policy=ServePolicy(batching="paged", prefill=prefill,
                           max_len=256, max_slots=max_slots),
        spec=chip_spec(**SMALL))


def _chunk_tokens(eng):
    return eng.plan.chunk_tokens() or eng.page.page_tokens


# ---------------------------------------------------------------- identity
@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_chunked_prefill_token_identical_to_monolithic(arch):
    """Chunk boundaries must be invisible: same tokens whether the prompt
    enters the pool whole or page by page, with a partial final chunk."""
    cfg = get_model_config(arch).reduced()
    chunked = _engine(cfg, "chunked")
    t = _chunk_tokens(chunked)
    plen = 2 * t + 3                      # 3 chunks, final one partial
    rng = np.random.default_rng(7)
    prompts = [_prompt(cfg, plen, rng), _prompt(cfg, t - 1, rng)]

    outs_k = chunked.generate(prompts, max_new_tokens=4)
    mono = _engine(cfg, "monolithic")
    outs_m = mono.generate(prompts, max_new_tokens=4)

    assert outs_k == outs_m, arch
    assert all(len(o) == 4 for o in outs_k)
    # The chunked run really chunked: ceil(plen/t) + 1 for the short one.
    assert chunked.metrics["prefill_chunks"] == -(-plen // t) + 1
    assert mono.metrics["prefill_chunks"] == 2


# --------------------------------------------------------------- interleave
def test_decode_interleaves_with_long_prefill_and_zero_copies():
    """While a long prompt streams into the pool, the resident slot's
    decode keeps ticking: the trace has decode events between the long
    prompt's chunk events, never more than one chunk per slot between
    consecutive decode ticks, and the staging copy is gone."""
    cfg = get_model_config("llama3.2-1b").reduced()
    eng = _engine(cfg, "chunked")
    t = _chunk_tokens(eng)
    rng = np.random.default_rng(3)
    # Short prompt first: it finishes prefill in one chunk and decodes
    # while the long prompt is still streaming in.
    prompts = [_prompt(cfg, t - 2, rng), _prompt(cfg, 4 * t, rng)]
    outs = eng.generate(prompts, max_new_tokens=[8, 2])
    assert len(outs[0]) == 8 and len(outs[1]) == 2

    trace = eng.metrics["interleave"]
    long_chunks = [i for i, ev in enumerate(trace)
                   if ev[0] == "chunk" and ev[3] == t]    # full => long slot
    decodes = [i for i, ev in enumerate(trace) if ev[0] == "decode"]
    assert len(long_chunks) == 4
    # Decode ticks strictly between the long prompt's first and last chunk:
    # prefill streams THROUGH live decoding, not ahead of it.
    assert [i for i in decodes if long_chunks[0] < i < long_chunks[-1]], \
        f"no decode tick interleaved mid-prefill: {trace}"
    # Stall bound: at most one chunk per slot between consecutive decode
    # ticks -- a decoder is never held for a multi-chunk prefill burst.
    bounds = [-1] + decodes + [len(trace)]
    for lo, hi in zip(bounds, bounds[1:]):
        slots = [ev[1] for ev in trace[lo + 1:hi] if ev[0] == "chunk"]
        assert len(slots) == len(set(slots)), trace
    # Zero post-prefill copies: the staging/copy entry point is gone -- the
    # pages the chunks wrote ARE the cache decode reads.
    import repro.serve.pages as pages
    assert not hasattr(pages, "install_slot")
    assert eng.metrics["prefill_chunks"] == 5    # 4 long + 1 short


# ------------------------------------------------------------- properties
@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n_slots=st.integers(1, 3),
    page_tokens=st.sampled_from([4, 8]),
    pool_pages=st.integers(2, 12),
    n_req=st.integers(1, 5),
)
def test_scheduler_page_flow_and_stall_bound(seed, n_slots, page_tokens,
                                             pool_pages, n_req):
    """Pure-python simulation of the engine's tick discipline over the
    real ``PagedScheduler``: random prompt/new lengths, chunked
    admission, at most one chunk per prefilling slot per tick, youngest
    -victim preemption and per-tick decode.  Invariants, EVERY tick:

      * ``pool.used_pages == sched.used_pages_by_slots()`` and
        ``pages_allocated - pages_released == used_pages`` (no leak, no
        double-free, under preemption included);
      * a decode slot stalls only when eviction provably cannot help
        (no strictly-younger victim exists);

    and at termination every request has its exact token count and the
    pool is empty."""
    from repro.serve.kvcache import PageSpec
    from repro.serve.pages import PagePool, PagedScheduler
    from repro.serve.scheduler import Request

    rng = np.random.default_rng(seed)
    page = PageSpec(page_tokens=page_tokens, token_bytes=16)
    pool = PagePool(pool_pages + 1)       # +1: reserved null page 0
    sched = PagedScheduler(pool, page, n_slots, pages_per_slot=16, window=0)
    reqs = [Request(rid=i, prompt_len=int(rng.integers(1, 4 * page_tokens)),
                    max_new=int(rng.integers(1, 6)))
            for i in range(n_req)]
    # A request that can never fit the pool alone would rightly stall the
    # oldest slot forever; the engine sizes pools to the plan, so skip.
    if max(page.pages_for(r.prompt_len + r.max_new) for r in reqs) \
            > pool_pages:
        return
    for r in reqs:
        sched.submit(r)

    emitted = {r.rid: 0 for r in reqs}
    prefills = {}
    ticks = 0
    while sched.has_work():
        ticks += 1
        assert ticks < 10_000, "scheduler livelock"
        stalled = set()

        def grow(slot, upto=None):
            while not sched.ensure_capacity(slot, upto=upto):
                if sched.table_full(slot):
                    raise AssertionError("table sized to never fill here")
                victim = sched.victim(slot)
                if victim is None:
                    # Stall is legal ONLY when no younger slot exists to
                    # evict -- the oldest request always progresses.
                    assert all(
                        s is None or s.rid <= sched.slots[slot].rid
                        for i, s in enumerate(sched.slots) if i != slot)
                    stalled.add(slot)
                    return False
                vreq = sched.evict(victim)
                emitted[vreq.rid] = 0     # recompute preemption
                prefills.pop(victim, None)
            return True

        for i in sorted(sched.active(),
                        key=lambda j: sched.slots[j].rid):
            if sched.slots[i] is None or i in prefills:
                continue
            grow(i)
        for slot, req, _pages, _hit in sched.admit(chunked=True):
            prefills[slot] = 0
        # chunk phase: at most ONE chunk per prefilling slot per tick.
        for slot in sorted(prefills):
            s = sched.slots[slot]
            if s is None or slot not in prefills:
                continue
            done = prefills[slot]
            c = min(page_tokens, s.req.prompt_len - done)
            if not grow(slot, upto=done + c):
                continue
            done += c
            prefills[slot] = done
            s.pos = done
            if done >= s.req.prompt_len:
                del prefills[slot]
                emitted[s.req.rid] += 1   # prefill samples the first token
                if emitted[s.req.rid] >= s.req.max_new:
                    sched.finish(slot)
        # decode phase: every live, non-stalled, non-prefilling slot
        # decodes THIS tick -- prefill never starves a decoder.
        for i in list(sched.active()):
            if i in stalled or i in prefills or sched.slots[i] is None:
                continue
            s = sched.slots[i]
            s.pos += 1
            emitted[s.rid] += 1
            if emitted[s.rid] >= s.req.max_new:
                sched.finish(i)
        # flow invariants, every tick
        assert pool.used_pages == sched.used_pages_by_slots()
        assert pool.pages_allocated - pool.pages_released == pool.used_pages
    assert all(emitted[r.rid] == r.max_new for r in reqs)
    assert pool.used_pages == 0


# ------------------------------------------------------------ one body
def test_single_layer_body_across_all_paths(monkeypatch):
    """Cohort prefill, chunked prefill, cohort decode and paged decode all
    execute the ONE module-level ``_tf_layer`` -- no forked layer bodies.
    A spy swapped in for the module global must see every path."""
    import jax
    import jax.numpy as jnp

    import repro.models.model as M
    from repro.serve.pages import init_paged_cache

    cfg = get_model_config("llama3.2-1b").reduced()
    model = M.build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)

    calls = []
    orig = M._tf_layer

    def spy(lp, x, cfg_, kind, attn, capacity_factor):
        calls.append(kind)
        return orig(lp, x, cfg_, kind, attn, capacity_factor)

    monkeypatch.setattr(M, "_tf_layer", spy)

    def ran(tag, fn):
        before = len(calls)
        out = fn()
        assert len(calls) > before, f"{tag} bypassed _tf_layer"
        return out

    _, cache = ran("cohort prefill", lambda: model.prefill(
        params, {"tokens": jnp.asarray(toks)[None]}, max_len=12,
        dtype=jnp.float32))
    ran("cohort decode", lambda: model.decode_step(
        params, cache, {"tokens": jnp.asarray([[3]], jnp.int32)},
        dtype=jnp.float32))

    pcache = init_paged_cache(cfg, model, 2, 6, 4, 4, jnp.float32)
    pcache["table"] = jnp.zeros((2, 4), jnp.int32).at[0, :3].set(
        jnp.arange(1, 4))
    _, pcache = ran("chunked prefill", lambda: model.prefill_chunk(
        params, pcache, {"tokens": jnp.asarray(toks)[None],
                         "pos0": jnp.int32(0), "slot": jnp.int32(0)},
        dtype=jnp.float32))
    pcache["pos"] = jnp.asarray([8, 0], jnp.int32)
    ran("paged decode", lambda: model.decode_step_paged(
        params, pcache, {"tokens": jnp.asarray([[3], [0]], jnp.int32)},
        dtype=jnp.float32))


def test_chunk_written_pool_reads_same_under_kernel_and_ref():
    """The pages a chunked prefill writes are one cache, two readers: the
    Pallas paged kernel and the ``kernels/ref.py`` gather must agree on a
    decode step over the chunk-written pool."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.ref import paged_attention_ref
    from repro.models.model import build_model
    from repro.serve.pages import init_paged_cache

    cfg = get_model_config("llama3.2-1b").reduced()
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(5)
    T, NP, plen = 4, 4, 11                # 3 chunks, partial final one
    toks = rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)

    cache = init_paged_cache(cfg, model, 2, NP + 2, T, NP, jnp.float32)
    cache["table"] = jnp.zeros((2, NP), jnp.int32).at[0, :3].set(
        jnp.arange(1, 4))
    done = 0
    while done < plen:
        c = min(T, plen - done)
        _, cache = model.prefill_chunk(
            params, cache,
            {"tokens": jnp.asarray(toks[done:done + c])[None],
             "pos0": jnp.int32(done), "slot": jnp.int32(0)},
            dtype=jnp.float32)
        done += c

    k_pool = cache["pool"]["k"][0]        # layer 0: (P, T, KV, D)
    v_pool = cache["pool"]["v"][0]
    q = jnp.asarray(rng.standard_normal(
        (2, cfg.n_heads, cfg.head_dim)).astype(np.float32))
    lengths = jnp.asarray([plen, 0], jnp.int32)
    out_k = paged_attention(q, k_pool, v_pool, cache["table"], lengths,
                            page_tokens=T)
    out_r = paged_attention_ref(q, k_pool, v_pool, cache["table"], lengths)
    np.testing.assert_allclose(np.asarray(out_k[0]), np.asarray(out_r[0]),
                               rtol=2e-4, atol=2e-5)
