"""Hierarchical planner tests (``repro.plan``, the one-API redesign).

Covers the ISSUE-3 acceptance surface: the 4-level DCN -> ICI/HBM -> VMEM
-> VREG plan on a 2-host hierarchy, JSON round-trip identity, equivalence
of the legacy entry points (``mesh_decomposition``, ``plan_matmul``,
``Decomposer.decompose``) with the planner sub-plans they now wrap, the
FSDP degree quantization, and a hand-computed 2-host nested search.
"""

import dataclasses

import pytest
from jax.sharding import AbstractMesh

from repro.configs import get_model_config
from repro.core import Decomposer, find_optimal_np, matmul_domain, phi_simple
from repro.core.autotile import plan_matmul
from repro.core.hierarchy import paper_system_a, tpu_hierarchy
from repro.dist.pipeline import dcn_stages
from repro.dist.sharding import arch_rules, mesh_decomposition, mesh_plan
from repro.plan import (
    HierarchicalPlan,
    PlanPolicy,
    Workload,
    leaf_matmul_plan,
    plan_run,
    quantize_divisor,
)

GiB = 1 << 30


def _hier(hosts=2, chips=8, hbm_gb=16):
    return tpu_hierarchy(
        hbm_bytes=hbm_gb * GiB, vmem_bytes=96 << 20,
        mesh_devices=chips, hosts=hosts)


class TestHierarchyWithDCN:
    def test_level_names_and_sizes(self):
        h = _hier(hosts=2, chips=8)
        assert [l.name for l in h.levels()] == \
            ["DCN", "ICI", "HBM", "VMEM", "VREG"]
        ici = h.find("ICI")
        # One ICI copy per host, 8 chips each; DCN holds both.
        assert ici.siblings == [list(range(8)), list(range(8, 16))]
        assert ici.size == 8 * 16 * GiB
        assert h.size == 2 * ici.size
        assert h.find("HBM").n_cores == 16

    def test_single_host_unchanged(self):
        h = tpu_hierarchy(hbm_bytes=16 * GiB, vmem_bytes=96 << 20,
                          mesh_devices=8)
        assert [l.name for l in h.levels()] == ["ICI", "HBM", "VMEM", "VREG"]
        assert h.siblings == [list(range(8))]

    def test_hosts_require_mesh(self):
        with pytest.raises(ValueError):
            tpu_hierarchy(hbm_bytes=1, vmem_bytes=1, hosts=2)


class TestQuantizeDivisor:
    def test_rounds_to_smallest_divisor(self):
        assert quantize_divisor(5, 16) == 8
        assert quantize_divisor(5, 8) == 8
        assert quantize_divisor(3, 12) == 3   # already a divisor
        assert quantize_divisor(5, 12) == 6
        assert quantize_divisor(1, 8) == 1
        assert quantize_divisor(8, 8) == 8
        assert quantize_divisor(9, 8) == 8   # saturates at the extent
        assert quantize_divisor(3, 6) == 3

    def test_unbounded_extent_passthrough(self):
        assert quantize_divisor(5, 0) == 5

    def test_multiple_of_outer_partitions(self):
        # Inner partitions must refine the outer level's: a divisor that
        # does not contain the outer np would straddle a host boundary.
        assert quantize_divisor(3, 6, multiple_of=2) == 6
        assert quantize_divisor(3, 12, multiple_of=4) == 4
        assert quantize_divisor(1, 8, multiple_of=2) == 2
        # No qualifying divisor -> fall back to the unconstrained rule.
        assert quantize_divisor(3, 6, multiple_of=7) == 3


class TestPlanTree:
    """Acceptance: plan_run on tpu_hierarchy(hosts=2, mesh_devices=8)."""

    def test_four_levels(self):
        hp = plan_run(_hier(), Workload(state_bytes=65 * GiB,
                                        matmul=(512, 512, 512)))
        levels = hp.levels()
        assert [lp.level for lp in levels] == ["DCN", "ICI", "VMEM", "VREG"]
        assert [lp.kind for lp in levels] == ["mesh", "mesh", "tile", "leaf"]
        # The ICI node consumed HBM as its TCL.
        assert hp.level("ICI").detail["tcl_level"] == "HBM"
        assert [lp.phi for lp in levels[:3]] == \
            ["phi_mesh", "phi_mesh", "phi_tpu"]

    def test_json_round_trip_identity(self):
        hp = plan_run(_hier(), Workload(state_bytes=65 * GiB,
                                        matmul=(512, 512, 512)))
        assert HierarchicalPlan.from_json(hp.to_json()) == hp
        # And the reconstructed leaf still yields the same tile plan.
        rt = HierarchicalPlan.from_json(hp.to_json())
        assert rt.tile_plan() == hp.tile_plan()

    def test_describe_mentions_dcn_and_quantization(self):
        hp = plan_run(_hier(), Workload(state_bytes=65 * GiB))
        text = "\n".join(hp.describe())
        assert "DCN[mesh]" in text
        assert "quantized=" in text


class TestHandComputedNestedSearch:
    """65 GiB state over 2 hosts x 8 chips of 16 GiB HBM, hand-computed:

    DCN: budget = one host's ICI domain = 8 x 16 = 128 GiB >= 65 GiB, so
         np=1 (replicated across hosts).
    ICI: workers threaded from DCN (1), budget = 16 GiB; smallest np with
         65/np <= 16 is np*=5; quantized to the 8-chip divisor -> 8.
    """

    def test_per_level_np(self):
        hp = plan_run(_hier(hosts=2, chips=8), Workload(state_bytes=65 * GiB))
        dcn, ici = hp.level("DCN"), hp.level("ICI")
        assert (dcn.np_raw, dcn.np) == (1, 1)
        assert ici.n_workers == 1                  # threaded from DCN's np
        assert (ici.np_raw, ici.np) == (5, 8)
        assert ici.budget_bytes == 16 * GiB
        assert dcn.budget_bytes == 128 * GiB

    def test_dcn_partitions_when_host_overflows(self):
        # 4 chips/host -> 64 GiB hosts: the DCN level itself must split the
        # 65 GiB state (np=2), and that np seeds the ICI search's workers.
        hp = plan_run(_hier(hosts=2, chips=4), Workload(state_bytes=65 * GiB))
        dcn, ici = hp.level("DCN"), hp.level("ICI")
        assert (dcn.np_raw, dcn.np) == (2, 2)
        assert ici.n_workers == 2
        assert (ici.np_raw, ici.np) == (5, 8)

    def test_ici_degree_refines_dcn_partitions(self):
        # Oversubscribed 20 GiB hosts of 3 x 16 GiB chips, 33 GiB state:
        # DCN np=2 (16.5 GiB/host fits), ICI np*=3 (11 GiB/chip fits) --
        # but 3 global shards cannot refine 2 host shards, so the
        # quantizer must pick the next divisor that contains them: 6.
        h = tpu_hierarchy(hbm_bytes=16 * GiB, vmem_bytes=96 << 20,
                          mesh_devices=3, hosts=2, ici_bytes=20 * GiB)
        hp = plan_run(h, Workload(state_bytes=33 * GiB))
        dcn, ici = hp.level("DCN"), hp.level("ICI")
        assert dcn.np == 2
        assert ici.np_raw == 3
        assert ici.np == 6

    def test_overhead_scales_the_search(self):
        fits = plan_run(_hier(), Workload(state_bytes=15 * GiB))
        tight = plan_run(_hier(), Workload(state_bytes=15 * GiB,
                                           overhead=2.0))
        assert fits.level("ICI").np_raw == 1
        assert tight.level("ICI").np_raw == 2     # 30 GiB effective footprint
        assert tight.level("ICI").detail["overhead"] == 2.0


class TestWrapperEquivalence:
    """The legacy entry points are thin wrappers over plan_run."""

    def test_mesh_decomposition_matches_ici_subplan(self):
        h = tpu_hierarchy(hbm_bytes=16 * GiB, vmem_bytes=96 << 20,
                          mesh_devices=16)
        dec = mesh_decomposition(h, sharded_bytes=65 * GiB, max_np=16)
        lp = plan_run(h, Workload(state_bytes=65 * GiB),
                      PlanPolicy(max_np={"ICI": 16})).level("ICI")
        assert dec.np == lp.np_raw == 5
        assert dec.budget_bytes == lp.budget_bytes
        assert dec.granule_bytes == lp.granule_bytes
        assert dec.fits == lp.fits

    def test_mesh_decomposition_matches_on_two_host_hierarchy(self):
        # The acceptance budget-flip property holds through the DCN walk:
        # the ICI sub-plan of the 2-host hierarchy reproduces the FSDP
        # choice of the flat mesh_decomposition over the same 16 chips.
        h2 = _hier(hosts=2, chips=8)
        flat = tpu_hierarchy(hbm_bytes=16 * GiB, vmem_bytes=96 << 20,
                             mesh_devices=16)
        for state in (1 * GiB, 65 * GiB, 300 * GiB):
            dec = mesh_decomposition(flat, sharded_bytes=state, max_np=16)
            lp = plan_run(h2, Workload(state_bytes=state)).level("ICI")
            assert dec.np == lp.np_raw, state
            assert dec.replicated == lp.replicated, state

    def test_plan_matmul_equals_planner_leaf(self):
        for shape in ((512, 512, 512), (2048, 1024, 4096), (1000, 3000, 500)):
            m, k, n = shape
            direct = plan_matmul(m, k, n, dtype_bytes=2)
            hp = plan_run(_hier(), Workload(matmul=shape, dtype_bytes=2))
            assert hp.tile_plan() == direct, shape
            assert leaf_matmul_plan(m, k, n, dtype_bytes=2) == direct, shape

    def test_decomposer_matches_direct_search(self):
        hier = paper_system_a()
        domain = matmul_domain(1024, 1024, 1024, element_size=4)
        plan = Decomposer(hier, tcl="L2").decompose(domain, n_workers=4)
        l2 = hier.find("L2")
        direct = find_optimal_np(l2.per_core_size(), l2.cache_line_size,
                                 list(domain), 4, phi_simple)
        assert plan.np == direct

    def test_decomposer_int_tcl_matches_direct_search(self):
        domain = matmul_domain(2000, 2000, 2000, element_size=4)
        plan = Decomposer(paper_system_a(), tcl=128 << 10).decompose(
            domain, n_workers=8)
        assert plan.np == 400                      # paper §4.4.4 anchor


class TestRulesConsumeThePlan:
    MESH = AbstractMesh((("data", 4), ("model", 4)))

    def _hier(self, hbm_gb):
        return tpu_hierarchy(hbm_bytes=int(hbm_gb * GiB),
                             vmem_bytes=96 << 20, mesh_devices=16)

    def test_meta_records_raw_and_quantized_degree(self):
        cfg = get_model_config("llama3.2-1b")
        tight = arch_rules(cfg, self.MESH, hierarchy=self._hier(0.25))
        assert tight.meta["mesh_np"] >= 1
        assert tight.meta["fsdp_degree"] >= tight.meta["mesh_np"]
        assert tight.meta["fsdp_capacity"] % tight.meta["fsdp_degree"] == 0
        assert tight.meta["plan"].level("ICI") is not None

    def test_explicit_plan_is_consumed_not_replanned(self):
        cfg = get_model_config("llama3.2-1b")
        hp = mesh_plan(self.MESH, state_bytes=1, hierarchy=self._hier(64),
                       max_np=4)
        rules = arch_rules(cfg, self.MESH, plan=hp)
        assert rules.meta["plan"] is hp
        assert rules.param_rules["embed"] is None   # np=1 plan -> replicated

    def test_mesh_plan_threads_spec_into_tile_search(self):
        from repro.hw import chip_spec

        spec = chip_spec("tpu_v5e", mxu=256)
        hp = mesh_plan(self.MESH, matmul=(8192, 8192, 8192), spec=spec)
        t = hp.tile_plan()
        assert t.bm % 256 == 0 and t.bk % 256 == 0 and t.bn % 256 == 0

    def test_quantized_degree_on_six_chip_axis(self):
        # np*=5 on a 6-chip extent quantizes to 6, not a power of two.
        h = tpu_hierarchy(hbm_bytes=16 * GiB, vmem_bytes=96 << 20,
                          mesh_devices=6)
        lp = plan_run(h, Workload(state_bytes=80 * GiB)).level("ICI")
        assert (lp.np_raw, lp.np) == (5, 6)


class TestPipelineMapsOntoDCN:
    def test_dcn_stages(self):
        hp = plan_run(_hier(hosts=2, chips=4), Workload(state_bytes=65 * GiB))
        assert dcn_stages(hp) == 2
        flat = plan_run(tpu_hierarchy(hbm_bytes=16 * GiB,
                                      vmem_bytes=96 << 20, mesh_devices=8),
                        Workload(state_bytes=GiB))
        assert dcn_stages(flat) == 1
        assert dcn_stages(None) == 1

    def test_make_pipeline_rejects_stage_mismatch(self):
        from repro.dist.pipeline import make_pipeline

        hp = plan_run(_hier(hosts=2, chips=4), Workload(state_bytes=65 * GiB))
        mesh = AbstractMesh((("pod", 4),))
        with pytest.raises(ValueError, match="DCN sub-plan prescribes 2"):
            make_pipeline(mesh, lambda p, x: x, axis="pod", plan=hp)


class TestOverheadField:
    def test_model_config_carries_overhead(self):
        assert get_model_config("llama3.2-1b").overhead == 1.0
        assert get_model_config("mixtral-8x7b").overhead == 1.25
        cfg = dataclasses.replace(get_model_config("llama3.2-1b"),
                                  overhead=1.5)
        assert cfg.reduced().overhead == 1.5
