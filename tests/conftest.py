"""Test bootstrap: make ``repro`` importable without the PYTHONPATH=src hack
and provide a minimal in-repo ``hypothesis`` stand-in when the real package
is absent (the container has no network; hard constraint: no pip installs).

The stub implements exactly the surface this suite uses -- ``given``,
``settings(max_examples, deadline)``, ``strategies.integers``,
``strategies.sampled_from``, ``strategies.booleans``, ``strategies.floats``
-- as a deterministic pseudo-random sweep.  It trades hypothesis's shrinking
and example database for zero dependencies; failures print the drawn
arguments so a repro is one copy-paste away.
"""

import functools
import inspect
import os
import random
import sys
import types

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    try:
        import repro  # noqa: F401
    except ImportError:
        sys.path.insert(0, _SRC)

# Hermetic tests: the repo commits measurement artifacts
# (experiments/calibration.json, experiments/tuning.json) that deliberately
# shift planner output when present.  The suite must assert the *analytic*
# behavior regardless of which artifacts happen to be checked in, so point
# both env overrides at a path that never exists; artifact-dependent tests
# (test_calibration.py, test_tune.py) monkeypatch these per-test to real
# tmp files, which takes precedence over this default.
os.environ.setdefault("REPRO_CALIBRATION", os.devnull + ".absent")
os.environ.setdefault("REPRO_TUNING", os.devnull + ".absent")


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def settings(max_examples=50, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 50)
                rng = random.Random(0xC0DE)
                for i in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception:
                        print(f"[hypothesis-stub] falsifying example "
                              f"#{i}: {drawn}", file=sys.stderr)
                        raise
            # Hide the drawn parameters from pytest's fixture resolution:
            # only non-strategy params (self, real fixtures) remain visible.
            sig = inspect.signature(fn)
            kept = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=kept)
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.floats = floats
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()
