"""Acceptance: the lowered serving program realizes exactly the decode
plan's choices -- the KV head sharding of the cache layout and the
page-aligned capacity -- for both branches of the mesh-level decision
(subprocess with an 8-device host platform, like test_serve_policy)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


def test_plan_kv_shard_realized_in_cache_sharding():
    """Memory pressure at the mesh level (tiny forced HBM) makes the decode
    plan shard KV heads over the full model axis; the lowered cache layout
    must match, and a decode step must run."""
    _run("""
        import dataclasses
        import numpy as np
        from repro.configs import get_model_config
        from repro.configs.base import ShapeConfig
        from repro.hw.tpu import chip_spec
        from repro.launch.specs import make_batch
        from repro.serve import make_serve_steps, plan_decode

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        cfg = dataclasses.replace(cfg, n_kv_heads=4, n_heads=4)
        shape = ShapeConfig("d", 64, 4, "decode")

        # Tiny HBM: the mesh search must demand np > 1 -> kv_shard = axis.
        # (The reduced model's KV is ~150 KiB per data shard at np=1 plus a
        # ~100 KiB replicated reserve; 160 KiB only fits at np=4.)
        small = chip_spec(hbm_bytes=160 << 10)
        hp = plan_decode(cfg, mesh, max_len=72, batch=4, dtype_bytes=4,
                         spec=small)
        ici = hp.level("ICI")
        assert ici.np_raw > 1, ici
        assert hp.kv_shard() == 4, hp.kv_shard()

        ss = make_serve_steps(cfg, shape, mesh, dtype=jnp.float32,
                              max_len_extra=8, decode_plan=hp)
        spec = ss.cache_sharding["layers"]["k"].spec
        # (L, B, S, KV, hd): the plan's head sharding, no seq fallback.
        assert spec[3] == "model" and spec[2] is None, spec

        # And it runs: prefill + one decode step under the plan layout.
        rng = np.random.default_rng(0)
        params = ss.model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        prompt = make_batch(cfg, shape, rng, kind="train")
        prompt.pop("labels", None)
        logits, cache = ss.prefill(params, prompt)
        logits, cache = ss.decode(
            params, cache, {"tokens": jnp.ones((4, 1), jnp.int32)})
        assert np.isfinite(np.asarray(logits)).all()
        print("sharded ok", spec)
    """)


def test_plan_replicated_kv_when_memory_fits():
    """With room to spare the decode plan keeps np = 1: the cache stays
    unsharded over heads AND the legacy auto seq fallback is disabled
    (the plan does not model it)."""
    _run("""
        import dataclasses
        from repro.configs import get_model_config
        from repro.configs.base import ShapeConfig
        from repro.serve import make_serve_steps, plan_decode

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_model_config("llama3.2-1b").reduced()
        cfg = dataclasses.replace(cfg, n_kv_heads=2, n_heads=4)
        shape = ShapeConfig("d", 64, 4, "decode")
        hp = plan_decode(cfg, mesh, max_len=72, batch=4, dtype_bytes=4)
        assert hp.kv_shard() == 1, hp.kv_shard()
        ss = make_serve_steps(cfg, shape, mesh, dtype=jnp.float32,
                              max_len_extra=8, decode_plan=hp)
        spec = ss.cache_sharding["layers"]["k"].spec
        assert spec[2] is None and spec[3] is None, spec
        print("replicated ok", spec)
    """)


def test_plan_page_matches_engine_capacity():
    """The page level of the decode tree IS the engine's allocation granule
    (single process, host devices)."""
    _run("""
        import numpy as np
        from repro.configs import get_model_config
        from repro.hw.tpu import chip_spec
        from repro.launch.mesh import make_host_mesh
        from repro.serve import ServeEngine, ServePolicy

        cfg = get_model_config("llama3.2-1b").reduced()
        small = chip_spec(vmem_bytes=16 << 10, vmem_reserved_bytes=0)
        engine = ServeEngine(cfg, make_host_mesh(),
                             policy=ServePolicy(max_new_tokens=12,
                                                max_len=64),
                             spec=small)
        page = engine.plan.page_plan()
        assert page is not None and engine.page.page_tokens == \
            page["page_tokens"]
        outs = engine.generate(
            [np.random.default_rng(0).integers(0, 256, 9, dtype=np.int32)])
        assert len(outs[0]) == 12
        caps = engine.metrics["capacities"]
        assert caps and all(c % page["page_tokens"] == 0 for c in caps)
        print("page ok", page["page_tokens"], caps)
    """)
