"""Config system tests: registry completeness, published-number spot checks,
CLI overrides, reduced-config invariants, and the grouped-GQA equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    RunConfig,
    SHAPES,
    TrainConfig,
    apply_overrides,
    get_model_config,
    get_shape,
    list_archs,
    parse_cli,
)

ASSIGNED = [
    "zamba2-1.2b", "qwen2-0.5b", "deepseek-coder-33b", "stablelm-1.6b",
    "llama3.2-1b", "qwen2-vl-7b", "mixtral-8x7b", "deepseek-v2-236b",
    "xlstm-1.3b", "whisper-large-v3",
]


class TestRegistry:
    def test_all_assigned_archs_registered(self):
        assert sorted(list_archs()) == sorted(ASSIGNED)

    def test_published_numbers(self):
        c = get_model_config("mixtral-8x7b")
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (32, 4096, 32, 8)
        assert c.moe.n_experts == 8 and c.moe.top_k == 2
        assert c.sliding_window == 4096
        # Param count within 2% of the published 46.7B / 12.9B active.
        assert abs(c.param_count() - 46.7e9) / 46.7e9 < 0.02
        assert abs(c.active_param_count() - 12.9e9) / 12.9e9 < 0.02

        d = get_model_config("deepseek-v2-236b")
        assert d.mla.kv_lora_rank == 512 and d.moe.n_experts == 160
        assert abs(d.param_count() - 236e9) / 236e9 < 0.03

        z = get_model_config("zamba2-1.2b")
        assert z.ssm.state_dim == 64 and z.ssm.attn_every == 6

    def test_four_shapes(self):
        assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                               "long_500k"}
        assert SHAPES["train_4k"].global_batch == 256
        assert SHAPES["long_500k"].seq_len == 524288
        assert get_shape("decode_32k").kind == "decode"

    def test_unknown_arch_raises(self):
        with pytest.raises(KeyError):
            get_model_config("gpt-17")

    def test_subquadratic_flags(self):
        runs = {a for a in ASSIGNED
                if get_model_config(a).is_subquadratic}
        assert runs == {"zamba2-1.2b", "xlstm-1.3b", "mixtral-8x7b"}


class TestCLI:
    def test_parse_and_apply_overrides(self):
        overrides, rest = parse_cli(
            ["--train.learning_rate", "1e-4", "--shape.seq_len=128", "pos"])
        assert rest == ["pos"]
        run = RunConfig(model=get_model_config("qwen2-0.5b"),
                        shape=get_shape("train_4k"))
        run = apply_overrides(run, overrides)
        assert run.train.learning_rate == pytest.approx(1e-4)
        assert run.shape.seq_len == 128
        # Untouched fields survive.
        assert run.model.d_model == 896

    def test_reduced_configs_stay_in_family(self):
        for a in ASSIGNED:
            c = get_model_config(a)
            r = c.reduced()
            assert r.family == c.family
            assert r.d_model <= 64 and r.vocab_size <= 256
            if c.moe:
                assert r.moe.n_experts == 4
            if c.ssm:
                assert r.ssm.attn_every <= 2


class TestGroupedAttention:
    def test_grouped_equals_repeat_full(self):
        """grouped_attention must be numerically identical to
        repeat_kv + full_attention (the cell-2 optimization's safety net)."""
        from repro.models.layers import (
            full_attention,
            grouped_attention,
            repeat_kv,
        )

        key = jax.random.PRNGKey(0)
        b, sq, sk, h, kv, d = 2, 1, 64, 8, 2, 16
        q = jax.random.normal(key, (b, sq, h, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kv, d),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kv, d),
                              jnp.float32)
        q_pos = jnp.asarray([sk - 1])
        k_pos = jnp.arange(sk)
        ref = full_attention(q, repeat_kv(k, h // kv), repeat_kv(v, h // kv),
                             q_pos, k_pos, causal=True,
                             kv_len=jnp.asarray(sk))
        out = grouped_attention(q, k, v, q_pos, k_pos, causal=True,
                                kv_len=jnp.asarray(sk))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
