"""ServeEngine acceptance tests (ISSUE 4).

* Greedy decode is token-identical to the legacy ``launch/serve.py`` loop
  (prefill + argmax decode over ``make_serve_steps``) for all four
  served model families: dense, MoE, hybrid-SSM, xLSTM.
* Continuous batching sustains mixed prompt lengths with the resident KV
  bytes never exceeding the planned budget (the engine asserts it every
  tick; the test additionally checks the recorded peak).
* The engine is plan-driven end to end: page size and cache capacities
  come from ``plan_run``'s decode-workload tree (the sharding side of the
  acceptance criterion is covered by the subprocess test in
  ``test_serve_plan_sharding.py``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.serve import (
    SamplingConfig,
    ServeEngine,
    ServePolicy,
    kv_token_bytes,
    make_serve_steps,
)

#: One arch per served model family (the "all four model families" of the
#: satellite checklist): dense attention, MoE (sliding-window ring cache),
#: hybrid SSM (Mamba2 + shared attention), and pure-recurrent xLSTM.
FOUR_FAMILIES = ["llama3.2-1b", "mixtral-8x7b", "zamba2-1.2b", "xlstm-1.3b"]


def _legacy_greedy(cfg, mesh, prompts, n_new):
    """The pre-engine serving loop (ex ``launch/serve.py``): one batch, one
    full-capacity cache, argmax decode."""
    plen = len(prompts[0])
    shape = ShapeConfig("legacy", plen, len(prompts), "decode")
    ss = make_serve_steps(cfg, shape, mesh, dtype=jnp.float32,
                          max_len_extra=n_new + 1)
    params = ss.model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"tokens": jnp.stack([jnp.asarray(p) for p in prompts])}
    logits, cache = ss.prefill(params, batch)
    out = [[] for _ in prompts]
    for _ in range(n_new):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for b in range(len(prompts)):
            out[b].append(int(nxt[b, 0]))
        logits, cache = ss.decode(params, cache, {"tokens": nxt})
    return out


@pytest.mark.parametrize("arch", FOUR_FAMILIES)
def test_engine_greedy_matches_legacy_loop(arch):
    cfg = get_model_config(arch).reduced()
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    B, plen, n_new = 2, 12, 4
    prompts = [rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
               for _ in range(B)]
    legacy = _legacy_greedy(cfg, mesh, prompts, n_new)
    engine = ServeEngine(cfg, mesh, policy=ServePolicy(
        max_new_tokens=n_new, max_len=plen + n_new + 1))
    assert engine.generate(prompts) == legacy, arch


def test_mixed_prompt_lengths_stay_inside_budget():
    """Continuous batching over mixed prompt lengths under a budget small
    enough to force several admission waves; every request completes and
    the recorded resident peak never crosses the planned budget."""
    cfg = get_model_config("llama3.2-1b").reduced()
    tok_bytes, _, _ = kv_token_bytes(cfg, 4)
    budget = tok_bytes * 40 * 2          # ~two sequences of ~40 tokens
    engine = ServeEngine(cfg, make_host_mesh(), policy=ServePolicy(
        max_new_tokens=5, max_len=64, max_slots=2,
        kv_budget_bytes=budget))
    rng = np.random.default_rng(0)
    lens = (8, 8, 16, 16, 8)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in lens]
    outs = engine.generate(prompts)
    assert [len(o) for o in outs] == [5] * len(lens)
    assert engine.metrics["peak_resident_bytes"] <= budget
    assert engine.metrics["cohorts"] >= 3     # mixed lengths => >= 3 cohorts


def test_page_growth_and_eviction_under_pressure():
    """A small forced VMEM shrinks the planned page; decode grows the cache
    page by page, and when the budget cannot hold two growing cohorts the
    younger one is preempted (recompute eviction) and still completes."""
    from repro.hw.tpu import chip_spec

    cfg = get_model_config("llama3.2-1b").reduced()
    tok_bytes, _, _ = kv_token_bytes(cfg, 4)
    small = chip_spec(vmem_bytes=16 << 10, vmem_reserved_bytes=0)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)

    engine = ServeEngine(cfg, mesh, policy=ServePolicy(
        max_new_tokens=40, max_len=64), spec=small)
    assert engine.page.page_tokens < 64       # the plan shrank the page
    outs = engine.generate([rng.integers(0, 256, 8, dtype=np.int32)])
    assert len(outs[0]) == 40
    caps = engine.metrics["capacities"]
    assert len(caps) > 1, "decode never grew the cache"
    assert all(c % engine.page.page_tokens == 0 for c in caps), \
        "capacities are not whole pages"

    budget = tok_bytes * 64
    engine = ServeEngine(cfg, mesh, policy=ServePolicy(
        max_new_tokens=30, max_len=64, max_slots=1,
        kv_budget_bytes=budget), spec=small)
    outs = engine.generate(
        [rng.integers(0, 256, 8, dtype=np.int32) for _ in range(2)])
    assert [len(o) for o in outs] == [30, 30]
    assert engine.metrics["evictions"] >= 1
    assert engine.metrics["peak_resident_bytes"] <= budget


def test_compaction_frees_finished_slots_at_growth():
    """A slot that finishes early is sliced out of the cohort at the next
    growth boundary (its pages release before new ones are reserved), and
    the surviving request's greedy tokens are unchanged -- decode rows are
    batch-independent."""
    from repro.hw.tpu import chip_spec

    cfg = get_model_config("llama3.2-1b").reduced()
    mesh = make_host_mesh()
    small = chip_spec(vmem_bytes=16 << 10, vmem_reserved_bytes=0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, 8, dtype=np.int32) for _ in range(2)]

    solo = ServeEngine(cfg, mesh, policy=ServePolicy(
        max_new_tokens=30, max_len=64), spec=small)
    ref = solo.generate([prompts[1]])[0]

    engine = ServeEngine(cfg, mesh, policy=ServePolicy(
        max_new_tokens=30, max_len=64), spec=small)
    outs = engine.generate(prompts, max_new_tokens=[6, 30])
    assert [len(o) for o in outs] == [6, 30]
    assert outs[1] == ref                      # compaction changed nothing
    # Growth happened after the early finisher left, so the freed slot's
    # pages never inflated the peak: one surviving slot at final capacity.
    assert len(engine.metrics["capacities"]) > 1
    final_cap = engine.metrics["capacities"][-1]
    assert engine.scheduler.peak_bytes <= \
        engine.page.page_bytes * (engine.page.pages_for(final_cap) + 2)


def test_engine_consumes_plan_page_size():
    """Plan-driven end to end: the engine's page granule equals the decode
    plan's page level, and every cache capacity it allocates is a whole
    number of those pages."""
    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(cfg, make_host_mesh(), policy=ServePolicy(
        max_new_tokens=4, max_len=48))
    page = engine.plan.page_plan()
    assert page is not None
    assert engine.page.page_tokens == page["page_tokens"]
    rng = np.random.default_rng(0)
    engine.generate([rng.integers(0, 256, 9, dtype=np.int32)])
    assert engine.metrics["capacities"], "no capacity was recorded"
    assert all(c % page["page_tokens"] == 0
               for c in engine.metrics["capacities"])


def test_seeded_sampling_is_deterministic():
    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(cfg, make_host_mesh(), policy=ServePolicy(
        max_new_tokens=4, max_len=32))
    p = [np.random.default_rng(0).integers(0, 256, 8, dtype=np.int32)]
    for scfg in (SamplingConfig("temperature", temperature=0.7, seed=3),
                 SamplingConfig("top_k", top_k=5, seed=3)):
        a = engine.generate(p, sampling=scfg)
        b = engine.generate(p, sampling=scfg)
        assert a == b and len(a[0]) == 4, scfg.kind
    greedy = engine.generate(p)
    assert greedy == engine.generate(p)


def test_eos_stops_a_slot_early():
    cfg = get_model_config("llama3.2-1b").reduced()
    engine = ServeEngine(cfg, make_host_mesh(), policy=ServePolicy(
        max_new_tokens=6, max_len=32))
    p = [np.random.default_rng(0).integers(0, 256, 8, dtype=np.int32)]
    full = engine.generate(p)[0]
    # First token that did not already occur earlier in the continuation
    # (an earlier duplicate would stop the rerun at the duplicate).
    i = next((i for i in range(1, len(full))
              if full[i] not in full[:i]), None)
    if i is None:
        pytest.skip("degenerate continuation: every token repeats")
    stopped = engine.generate(
        p, sampling=SamplingConfig("greedy", eos_id=full[i]))[0]
    assert stopped == full[:i + 1]
