"""Tuning cache + sweep tests (DESIGN.md §9).

The planner's precedence is analytic < tuned: a measured winner in
``experiments/tuning.json`` overrides the analytic block exactly when the
``(kernel, arch, bucket, fingerprint)`` key matches this process's
hardware, and every tuned block re-passes the planner's own VMEM filter.
Tests write synthetic artifacts through the ``REPRO_TUNING`` env override
(tests/conftest.py pins it to a nonexistent path otherwise, so the suite
is hermetic to whatever artifact is committed).
"""

import json

import jax  # noqa: F401  (hw_fingerprint must see an initialized backend)
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.autotile import (
    _attn_vmem_bytes,
    _matmul_vmem_bytes,
    plan_attention,
    plan_matmul,
)
from repro.core.plan import PAGE_BUFFERING, PlanPolicy, Workload, plan_run
from repro.hw.tpu import chip_spec
from repro.tune.cache import (
    TUNING_ENV,
    TuningEntry,
    bucket_attention,
    bucket_matmul,
    bucket_paged,
    hw_fingerprint,
    load_tuning,
    lookup_tuned,
    record_tuned,
)
from repro.tune.sweep import run_sweeps, sweep_attention, sweep_matmul

SPEC = chip_spec()


def _write(path, *entries):
    record_tuned(list(entries), path=str(path))
    return str(path)


def _entry(kernel, bucket, block, analytic_block, fingerprint=None,
           speedup=1.5):
    return TuningEntry(
        kernel=kernel, arch=SPEC.name, bucket=bucket,
        fingerprint=fingerprint or hw_fingerprint(), block=block,
        analytic_block=analytic_block, median_us=100.0,
        analytic_us=100.0 * speedup, speedup=speedup)


@pytest.fixture
def tune_path(tmp_path, monkeypatch):
    p = tmp_path / "tuning.json"
    monkeypatch.setenv(TUNING_ENV, str(p))
    return p


class TestCacheRoundTrip:
    def test_record_load_lookup(self, tune_path):
        e = _entry("flash_attention", "q128kv128d64b4",
                   {"block_q": 64, "block_kv": 128},
                   {"block_q": 128, "block_kv": 128})
        _write(tune_path, e)
        entries = load_tuning()
        assert e.key in entries
        got = lookup_tuned("flash_attention", SPEC.name, "q128kv128d64b4")
        assert got is not None
        assert got["block"] == {"block_q": 64, "block_kv": 128}
        assert got["speedup"] == 1.5

    def test_merge_preserves_other_keys(self, tune_path):
        _write(tune_path, _entry("matmul_cc", "m512k512n512b4",
                                 {"bm": 128, "bk": 512, "bn": 512},
                                 {"bm": 512, "bk": 512, "bn": 512}))
        _write(tune_path, _entry("flash_attention", "q128kv128d64b4",
                                 {"block_q": 64, "block_kv": 128}, {}))
        assert len(load_tuning()) == 2

    def test_corrupt_artifact_is_empty_never_raises(self, tune_path):
        tune_path.write_text("{not json")
        assert load_tuning() == {}
        assert lookup_tuned("matmul_cc", SPEC.name, "m1k1n1b2") is None

    def test_stat_keyed_reload(self, tune_path):
        _write(tune_path, _entry("matmul_cc", "b1",
                                 {"bm": 8, "bk": 8, "bn": 8}, {}))
        assert len(load_tuning()) == 1
        data = json.loads(tune_path.read_text())
        data["entries"] = {}
        tune_path.write_text(json.dumps(data))
        assert load_tuning() == {}


class TestPlannerConsultsTuned:
    """The acceptance loop: with a tuned cache present, the planner returns
    a different (measured-faster) block than the analytic fallback."""

    def test_attention_returns_tuned_block(self, tune_path):
        analytic = plan_attention(128, 128, 64, dtype_bytes=4,
                                  use_tuned=False)
        tuned_block = {"block_q": max(8, analytic.block_q // 2),
                       "block_kv": analytic.block_kv}
        assert tuned_block["block_q"] != analytic.block_q
        _write(tune_path, _entry(
            "flash_attention", bucket_attention(128, 128, 64, 4),
            tuned_block,
            {"block_q": analytic.block_q, "block_kv": analytic.block_kv},
            speedup=1.25))
        p = plan_attention(128, 128, 64, dtype_bytes=4)
        assert p.source == "tuned"
        assert p.block_q == tuned_block["block_q"] != analytic.block_q
        assert _attn_vmem_bytes(p.block_q, p.block_kv, 64,
                                4) <= SPEC.usable_vmem

    def test_matmul_plan_run_returns_tuned_with_provenance(self, tune_path):
        analytic = plan_matmul(512, 512, 512, dtype_bytes=4)
        tuned_block = {"bm": max(8, analytic.bm // 2), "bk": analytic.bk,
                       "bn": analytic.bn}
        assert tuned_block["bm"] != analytic.bm
        _write(tune_path, _entry(
            "matmul_cc", bucket_matmul(512, 512, 512, 4), tuned_block,
            {"bm": analytic.bm, "bk": analytic.bk, "bn": analytic.bn},
            speedup=1.4))
        hp = plan_run(SPEC.hierarchy(),
                      Workload(matmul=(512, 512, 512), dtype_bytes=4),
                      PlanPolicy(spec=SPEC))
        tile = hp.tile_plan()
        assert tile.source == "tuned"
        assert (tile.bm, tile.bk, tile.bn) == (
            tuned_block["bm"], tuned_block["bk"], tuned_block["bn"])
        vmem = next(lp for lp in hp.levels() if lp.kind == "tile")
        assert vmem.detail["tuning"]["speedup"] == 1.4
        assert any("src=tuned" in line for line in hp.describe())

    def test_tuned_block_clamped_to_smaller_problem(self, tune_path):
        # Bucket m1024... covers m=513..1024: a winner measured at 1024 must
        # clamp to the smaller problem's padded dims, never exceed them.
        _write(tune_path, _entry(
            "matmul_cc", bucket_matmul(600, 600, 600, 4),
            {"bm": 1024, "bk": 1024, "bn": 1024}, {}))
        p = plan_matmul(600, 600, 600, dtype_bytes=4)
        assert p.source == "tuned"
        assert p.bm <= ((600 + 127) // 128) * 128
        assert _matmul_vmem_bytes(p.bm, p.bk, p.bn, 4) <= SPEC.usable_vmem

    def test_page_level_returns_tuned_page(self, tune_path):
        tok_bytes = 2 * 2 * 16 * 4          # K+V x n_kv x d x f32, 1 layer
        wl = Workload(kv_bytes_per_token=tok_bytes, kv_layers=1,
                      kv_heads=2, max_tokens=64)
        hp0 = plan_run(SPEC.hierarchy(), wl,
                       PlanPolicy(spec=SPEC, use_tuned=False))
        analytic_pt = hp0.page_plan()["page_tokens"]
        tuned_pt = max(8, analytic_pt // 2)
        assert tuned_pt != analytic_pt
        _write(tune_path, _entry(
            "paged_attention", bucket_paged(tok_bytes, 64),
            {"page_tokens": tuned_pt}, {"page_tokens": analytic_pt},
            speedup=2.0))
        hp = plan_run(SPEC.hierarchy(), wl, PlanPolicy(spec=SPEC))
        page = hp.page_plan()
        assert page["page_tokens"] == tuned_pt
        assert page["source"] == "tuned"
        assert PAGE_BUFFERING * page["page_bytes"] <= SPEC.usable_vmem
        assert any("src=tuned" in line for line in hp.describe())

    def test_ssd_chunk_returns_tuned(self, tune_path):
        from repro.models.mamba2 import choose_chunk
        from repro.tune.cache import bucket_ssd

        analytic = choose_chunk(256, 2, 32, 32, dtype_bytes=4,
                                use_tuned=False)
        tuned = max(16, analytic // 2)
        assert tuned != analytic
        _write(tune_path, _entry(
            "ssd_scan", bucket_ssd(256, 2, 32, 32, 4), {"chunk": tuned},
            {"chunk": analytic}))
        assert choose_chunk(256, 2, 32, 32, dtype_bytes=4) == tuned


class TestFallbackToAnalytic:
    def test_fingerprint_mismatch_falls_back(self, tune_path):
        analytic = plan_attention(128, 128, 64, dtype_bytes=4,
                                  use_tuned=False)
        _write(tune_path, _entry(
            "flash_attention", bucket_attention(128, 128, 64, 4),
            {"block_q": max(8, analytic.block_q // 2),
             "block_kv": analytic.block_kv}, {},
            fingerprint="tpu:TPU v5e"))        # measured elsewhere
        p = plan_attention(128, 128, 64, dtype_bytes=4)
        assert p.source == "analytic"
        assert p.block_q == analytic.block_q

    def test_missing_artifact_falls_back(self, monkeypatch, tmp_path):
        monkeypatch.setenv(TUNING_ENV, str(tmp_path / "absent.json"))
        p = plan_attention(128, 128, 64, dtype_bytes=4)
        assert p.source == "analytic"

    def test_over_budget_tuned_entry_rejected(self, tune_path):
        # A (corrupt or foreign) entry whose blocks blow the VMEM budget
        # must never override the analytic choice.
        _write(tune_path, _entry(
            "flash_attention", bucket_attention(65536, 65536, 256, 4),
            {"block_q": 65536, "block_kv": 65536}, {}))
        p = plan_attention(65536, 65536, 256, dtype_bytes=4)
        assert p.source == "analytic"
        assert _attn_vmem_bytes(p.block_q, p.block_kv, 256,
                                4) <= SPEC.usable_vmem

    def test_misaligned_tuned_entry_rejected(self, tune_path):
        _write(tune_path, _entry(
            "matmul_cc", bucket_matmul(512, 512, 512, 4),
            {"bm": 100, "bk": 512, "bn": 512}, {}))   # not 8-aligned
        p = plan_matmul(512, 512, 512, dtype_bytes=4)
        assert p.source == "analytic"


class TestSweepVmemFilter:
    """No swept candidate exceeds the level budget (ISSUE satellite)."""

    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(8, 4096), k=st.integers(8, 4096),
           n=st.integers(8, 4096), db=st.sampled_from([1, 2, 4]))
    def test_matmul_candidates_fit(self, m, k, n, db):
        r = sweep_matmul(m, k, n, dtype_bytes=db, dry=True)
        assert r.candidates, "the analytic center must always survive"
        for c in r.candidates:
            assert c.est_vmem_bytes <= r.budget_bytes
            assert _matmul_vmem_bytes(c.block["bm"], c.block["bk"],
                                      c.block["bn"], db) <= r.budget_bytes

    @settings(max_examples=25, deadline=None)
    @given(q=st.integers(8, 16384), kv=st.integers(8, 16384),
           d=st.sampled_from([64, 128, 256]))
    def test_attention_candidates_fit(self, q, kv, d):
        r = sweep_attention(q, kv, d, dtype_bytes=2, dry=True)
        assert r.candidates
        for c in r.candidates:
            assert c.est_vmem_bytes <= r.budget_bytes
            assert c.block["block_q"] % 8 == 0
            assert c.block["block_kv"] % 8 == 0

    def test_dry_run_all_kernels(self, tune_path):
        results = run_sweeps(dry=True, quick=True)
        assert [r.kernel for r in results] == [
            "matmul_cc", "flash_attention", "paged_attention", "ssd_scan"]
        for r in results:
            assert r.candidates
            assert all(c.est_vmem_bytes <= r.budget_bytes
                       for c in r.candidates)
        # dry mode must not write the artifact
        assert not tune_path.exists()


class TestEndToEndSweep:
    """One real (timed, interpret-mode) sweep: the winner lands in the
    artifact and the planner picks it up -- the acceptance loop with actual
    measurement instead of a synthetic entry."""

    def test_paged_sweep_records_and_planner_consults(self, tune_path):
        from repro.tune.sweep import sweep_paged

        r = sweep_paged(max_tokens=64, n_kv=2, group=2, head_dim=16,
                        slots=2, dtype_bytes=4, warmup=1, iters=2)
        assert r.entry is not None
        assert r.entry.median_us > 0
        assert r.entry.speedup >= 1.0     # winner is never slower by def'n
        record_tuned([r.entry], path=str(tune_path))
        tok_bytes = r.workload["tok_bytes"]
        wl = Workload(kv_bytes_per_token=tok_bytes, kv_layers=1,
                      kv_heads=2, max_tokens=64)
        hp = plan_run(SPEC.hierarchy(), wl, PlanPolicy(spec=SPEC))
        page = hp.page_plan()
        assert page["page_tokens"] == r.entry.block["page_tokens"]
        if r.entry.block != r.entry.analytic_block:
            assert page["source"] == "tuned"


class TestCommittedArtifact:
    """The committed experiments/tuning.json satisfies the acceptance
    criteria on the hardware it was measured on: at least one kernel's
    winner differs from its analytic center and measured faster."""

    def test_committed_artifact_valid(self, monkeypatch):
        import os

        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "experiments", "tuning.json")
        if not os.path.exists(path):
            pytest.skip("experiments/tuning.json not committed yet")
        monkeypatch.setenv(TUNING_ENV, path)
        entries = load_tuning()
        assert entries, "committed artifact has no entries"
        improved = [e for e in entries.values()
                    if e["speedup"] > 1.0 and e["block"] != e["analytic_block"]]
        assert improved, ("no committed winner beats its analytic center -- "
                          "the perf trajectory records no measured gain")
        for e in entries.values():
            assert e["kernel"] in ("matmul_cc", "flash_attention",
                                   "paged_attention", "ssd_scan")
            assert e["median_us"] > 0

    def test_committed_artifact_drives_planner_on_this_hw(self, monkeypatch):
        import os

        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "experiments", "tuning.json")
        if not os.path.exists(path):
            pytest.skip("experiments/tuning.json not committed yet")
        monkeypatch.setenv(TUNING_ENV, path)
        fp = hw_fingerprint()
        mine = {k: e for k, e in load_tuning().items()
                if e["fingerprint"] == fp and e["block"] != e["analytic_block"]
                and e["speedup"] > 1.0}
        if not mine:
            pytest.skip(f"no improved entry for this hardware ({fp})")
        # At least one measured-faster winner must actually flow out of the
        # planner for the shape it was swept at.
        hits = 0
        for e in mine.values():
            w = e["workload"]
            if e["kernel"] == "flash_attention":
                p = plan_attention(w["q_len"], w["kv_len"], w["head_dim"],
                                   dtype_bytes=w["dtype_bytes"])
                hits += p.source == "tuned"
            elif e["kernel"] == "matmul_cc":
                p = plan_matmul(w["m"], w["k"], w["n"],
                                dtype_bytes=w["dtype_bytes"])
                hits += p.source == "tuned"
            elif e["kernel"] == "paged_attention":
                hp = plan_run(
                    SPEC.hierarchy(),
                    Workload(kv_bytes_per_token=w["tok_bytes"], kv_layers=1,
                             kv_heads=w["n_kv"],
                             max_tokens=w["max_tokens"]),
                    PlanPolicy(spec=SPEC))
                hits += hp.page_plan()["source"] == "tuned"
            elif e["kernel"] == "ssd_scan":
                from repro.models.mamba2 import choose_chunk

                c = choose_chunk(w["seq_len"], w["n_heads"], w["head_dim"],
                                 w["state_dim"],
                                 dtype_bytes=w["dtype_bytes"])
                hits += c == e["block"]["chunk"]
        assert hits >= 1, "no tuned winner flowed out of the planner"
