"""Autotile planner tests: plans must fit the VMEM budget, be hardware
aligned, and degrade gracefully on degenerate shapes."""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.autotile import (
    _attn_vmem_bytes,
    _matmul_vmem_bytes,
    plan_attention,
    plan_matmul,
    plan_matmul_horizontal,
)
from repro.hw import chip_spec


SPEC = chip_spec("tpu_v5e")


class TestMatmulPlan:
    def test_typical_llm_matmul_fits(self):
        p = plan_matmul(4096, 4096, 4096, dtype_bytes=2, spec=SPEC)
        assert p.est_vmem_bytes <= SPEC.usable_vmem
        assert p.bm % 8 == 0 and p.bn % 8 == 0 and p.bk % 8 == 0

    def test_mxu_alignment_for_large_dims(self):
        p = plan_matmul(8192, 8192, 8192, dtype_bytes=2, spec=SPEC)
        assert p.bm % 128 == 0 and p.bk % 128 == 0 and p.bn % 128 == 0

    def test_grid_covers_problem(self):
        p = plan_matmul(1000, 3000, 500, dtype_bytes=4, spec=SPEC)
        gi, gj, gk = p.grid
        assert gi * p.bm >= p.m and gj * p.bn >= p.n and gk * p.bk >= p.k

    def test_horizontal_is_one_slab_per_worker(self):
        p = plan_matmul_horizontal(4096, 4096, 4096, n_workers=8)
        assert p.bm == 512 and p.bk == 4096 and p.bn == 4096
        assert p.strategy == "horizontal"

    def test_cache_conscious_beats_horizontal_footprint(self):
        cc = plan_matmul(8192, 8192, 8192, dtype_bytes=2, spec=SPEC)
        hz = plan_matmul_horizontal(8192, 8192, 8192, dtype_bytes=2, n_workers=8)
        assert cc.est_vmem_bytes <= SPEC.usable_vmem < hz.est_vmem_bytes


@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(min_value=8, max_value=16384),
    k=st.integers(min_value=8, max_value=16384),
    n=st.integers(min_value=8, max_value=16384),
    dtype_bytes=st.sampled_from([1, 2, 4]),
)
def test_matmul_plan_always_fits_or_is_minimal(m, k, n, dtype_bytes):
    p = plan_matmul(m, k, n, dtype_bytes=dtype_bytes, spec=SPEC)
    fits = p.est_vmem_bytes <= SPEC.usable_vmem
    minimal = p.bm <= 128 and p.bk <= 128 and p.bn <= 128
    assert fits or minimal
    # Blocks never exceed the padded problem dims.
    assert p.bm <= ((m + 127) // 128) * 128 + 128
    assert p.n_tasks >= 1


class TestAttentionPlan:
    def test_long_context_blocks_fit(self):
        p = plan_attention(32768, 32768, 128, dtype_bytes=2, spec=SPEC)
        assert _attn_vmem_bytes(p.block_q, p.block_kv, 128, 2) <= SPEC.usable_vmem
        assert p.block_q % 8 == 0
        assert p.block_kv % 8 == 0

    def test_decode_shape(self):
        # q_len=1 decode against a long cache.
        p = plan_attention(1, 524288, 64, dtype_bytes=2, spec=SPEC)
        assert p.block_q >= 1
        assert p.block_kv <= 524288

    @settings(max_examples=40, deadline=None)
    @given(
        q=st.integers(min_value=1, max_value=65536),
        kv=st.integers(min_value=1, max_value=65536),
        d=st.sampled_from([64, 128, 256]),
    )
    def test_plan_fits_budget(self, q, kv, d):
        p = plan_attention(q, kv, d, dtype_bytes=2, spec=SPEC)
        assert _attn_vmem_bytes(p.block_q, p.block_kv, d, 2) <= SPEC.usable_vmem
